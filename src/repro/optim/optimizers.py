"""Minimal pure-JAX optimizers (no optax available offline).

PaME itself needs none (its update is a sigma-scheduled gradient step), but
the baselines and the standard (non-DFL) training mode of the launcher do.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "momentum", "adam", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[object], object]
    update: Callable[[object, object, object], Tuple[object, object]]
    # update(grads, state, params) -> (updates, new_state)


def apply_updates(params: object, updates: object) -> object:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr: float) -> Optimizer:
    return Optimizer(
        init=lambda params: (),
        update=lambda g, s, p: (jax.tree_util.tree_map(lambda x: -lr * x, g), s),
    )


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params):
        new_m = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        return jax.tree_util.tree_map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
) -> Optimizer:
    class AdamState(NamedTuple):
        mu: object
        nu: object
        count: jax.Array

    def init(params):
        z = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params
        )
        return AdamState(z(), z(), jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return updates, AdamState(mu, nu, count)

    return Optimizer(init, update)
