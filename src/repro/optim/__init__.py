from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    sgd,
    momentum,
    adam,
    apply_updates,
)
