"""Production meshes and per-arch logical views.

Physical meshes (TPU v5e):
  single-pod : (data=16, model=16)           = 256 chips
  multi-pod  : (pod=2, data=16, model=16)    = 512 chips

Logical view: every arch sees the same devices as (node, fsdp, model).
DFL nodes live on `node`; each node's replica is `model`-way tensor
parallel and `fsdp`-way weight-sharded.  `fsdp` grows (and `node` shrinks)
for archs whose per-node state (params + grads + PME buffer, ~3x params in
bf16) would not fit 16 chips x 16 GB.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4; Auto is the implicit default before it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

from repro.models.config import ModelConfig

__all__ = [
    "make_production_mesh", "make_logical_mesh", "fsdp_degree",
    "mesh_axis_kwargs", "HBM_PER_CHIP",
]


def mesh_axis_kwargs(n_axes: int) -> dict:
    """kwargs pinning every mesh axis to Auto on jax versions that have
    explicit axis types; empty (the same behavior) on older versions."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}

HBM_PER_CHIP = 16e9          # v5e
PER_CHIP_PARAM_BUDGET = 8e9  # leave headroom for activations/caches
MODEL_AXIS = 16
STATE_MULTIPLier = 3.0       # params + grads + PME aggregate (no opt state)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def fsdp_degree(cfg: ModelConfig, total_chips: int, model_axis: int = MODEL_AXIS) -> int:
    """Smallest power-of-two fsdp that fits ~3x params in bf16 per node."""
    param_bytes = cfg.param_count() * 2  # bf16
    need = STATE_MULTIPLier * param_bytes / (model_axis * PER_CHIP_PARAM_BUDGET)
    fsdp = 1 if need <= 1 else 2 ** math.ceil(math.log2(need))
    max_fsdp = total_chips // (model_axis * 2)  # keep >= 2 DFL nodes
    return int(max(1, min(fsdp, max_fsdp)))


def make_logical_mesh(
    cfg: ModelConfig,
    *,
    multi_pod: bool = False,
    production: Optional[Mesh] = None,
) -> Mesh:
    """(node, fsdp, model) view over the production device set."""
    prod = production or make_production_mesh(multi_pod=multi_pod)
    devs = np.asarray(prod.devices).reshape(-1)
    total = devs.size
    fsdp = fsdp_degree(cfg, total)
    node = total // (fsdp * MODEL_AXIS)
    return Mesh(
        devs.reshape(node, fsdp, MODEL_AXIS),
        ("node", "fsdp", "model"),
        **mesh_axis_kwargs(3),
    )
