"""End-to-end DFL training driver.

Trains any registered architecture with PaME across m simulated nodes:

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --variant smoke --steps 100 --batch 8 --seq 128 --nodes 8

On a real TPU slice the same driver shards the node-stacked state over the
(node, fsdp, model) logical mesh; on CPU (tests/examples) everything runs
on one device.  Substrate exercised: synthetic non-IID corpus -> NodeBatcher
-> jitted pame_step -> metrics log + checkpointing.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.pame import (
    PaMEConfig,
    PaMEState,
    make_topology_arrays,
    pame_init,
    pame_step,
)
from repro.core.topology import build_topology
from repro.data.synthetic import SyntheticTokens
from repro.models.model import init_params, train_loss


def build_everything(args):
    cfg = get_config(args.arch, args.variant)
    if args.seq and cfg.arch_type == "vlm":
        assert args.seq > cfg.n_patches, "seq must exceed n_patches for vlm"
    m = args.nodes
    topo = build_topology(args.topology, m, p=0.5, seed=args.seed)
    pcfg = PaMEConfig(
        nu=args.nu, p=args.p, gamma=args.gamma, sigma0=args.sigma0,
        kappa_lo=args.kappa_lo, kappa_hi=args.kappa_hi,
        mask_mode="bernoulli",
    )
    topo_arrays = make_topology_arrays(topo, pcfg, seed=args.seed)

    corpus = SyntheticTokens.make(m, 65536, cfg.vocab, seed=args.seed)

    def make_batch(step: int):
        rng = np.random.default_rng(1000 + step)
        starts = rng.integers(0, corpus.tokens.shape[1] - args.seq - 1, (m, args.batch))
        toks = np.stack(
            [
                np.stack([corpus.tokens[i, s : s + args.seq] for s in starts[i]])
                for i in range(m)
            ]
        )
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
        if cfg.arch_type == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (m, args.batch, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
            )
        return batch

    def grad_fn(p, b, k):
        del k
        return jax.value_and_grad(lambda pp: train_loss(pp, cfg, b))(p)

    params0 = init_params(jax.random.PRNGKey(args.seed), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0
    )
    state = pame_init(jax.random.PRNGKey(args.seed + 1), stacked, m, pcfg)

    step_fn = jax.jit(lambda s, b: pame_step(s, b, grad_fn, topo_arrays, pcfg))
    return cfg, state, step_fn, make_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="per-node batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--topology", default="erdos_renyi")
    ap.add_argument("--nu", type=float, default=0.5)
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=1.001)
    ap.add_argument("--sigma0", type=float, default=20.0)
    ap.add_argument("--kappa-lo", type=int, default=3)
    ap.add_argument("--kappa-hi", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, state, step_fn, make_batch = build_everything(args)
    start = 0
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        from repro.checkpoint.store import latest_step

        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, state, last)
            start = last
            print(f"[train] resumed from step {last}")

    t0 = time.time()
    for k in range(start, args.steps):
        state, metrics = step_fn(state, make_batch(k))
        if (k + 1) % args.log_every == 0 or k == args.steps - 1:
            print(
                f"[train] step={k+1} loss={float(metrics['loss_mean']):.4f}"
                f" consensus={float(metrics['consensus']):.3e}"
                f" comm_nodes={int(metrics['comm_nodes'])}"
                f" sigma={float(metrics['sigma_mean']):.2f}"
                f" ({(time.time()-t0)/(k-start+1):.2f}s/step)",
                flush=True,
            )
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k + 1, state)
    print("[train] done")


if __name__ == "__main__":
    main()
