"""End-to-end DFL training driver.

Trains any registered architecture with any registered DFL algorithm
across m simulated nodes:

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --variant smoke --steps 100 --batch 8 --seq 128 --nodes 8 \
        --algo pame            # or dpsgd / dfedsam / choco / beer / anq_nids

Every algorithm runs through the scan-fused execution engine
(`repro.core.engine`): `--chunk` steps per dispatch with donated state and
device-side metric buffers, gossip routed through the sparse
neighbor-exchange mixer by default (`--mixing dense` for the bit-compatible
escape hatch), and per-step wire-cost accounting (Eq. 8 via the registry's
`wire_bits`) logged alongside the loss.

Dynamic-network scenarios (`--scenario flaky_links|churn|stragglers|harsh`
or explicit `--churn/--straggler/--edge-drop` probabilities) realize a
fresh doubly-stochastic mixing matrix every step inside the scan: links
fail, nodes drop out (state frozen for the step), stragglers miss the
exchange window, and only realized edges are charged on the wire.

On a real TPU slice the same driver shards the node-stacked state over the
(node, fsdp, model) logical mesh; on CPU (tests/examples) everything runs
on one device.  Substrate exercised: synthetic non-IID corpus ->
vectorized batch gather -> registry-bound step inside `lax.scan` chunks ->
metrics log + checkpointing.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import engine
from repro.core.algorithms import (
    AnqNidsHp,
    BeerHp,
    ChocoHp,
    DFedSAMHp,
    DPSGDHp,
    PaMEHp,
    get_algorithm,
    list_algorithms,
)
from repro.core.faults import FaultModel
from repro.core.scenarios import get_scenario, list_scenarios
from repro.core.temporal import TemporalScenario
from repro.core.topology import build_topology
from repro.data.synthetic import SyntheticTokens
from repro.models.model import init_params, train_loss


def _hps_from_args(name: str, args):
    if name == "pame":
        p_leaf = None
        if getattr(args, "p_leaf", None):
            p_leaf = tuple(float(x) for x in args.p_leaf.split(","))
        return PaMEHp(
            nu=args.nu, p=args.p, gamma=args.gamma, sigma0=args.sigma0,
            kappa_lo=args.kappa_lo, kappa_hi=args.kappa_hi,
            mask_mode="bernoulli",
            partition=getattr(args, "partition", "flat"), p_leaf=p_leaf,
        )
    return {
        "dpsgd": lambda: DPSGDHp(lr=args.lr),
        "dfedsam": lambda: DFedSAMHp(lr=args.lr, rho=args.rho),
        "choco": lambda: ChocoHp(lr=args.lr),
        "beer": lambda: BeerHp(lr=args.lr),
        "anq_nids": lambda: AnqNidsHp(lr=args.lr),
    }[name]()


def batch_stream_rng(seed: int, step: int) -> np.random.Generator:
    """The per-step batch-window RNG: independent across steps AND runs.

    Seeding from the (seed, step) pair keeps every step's draw independent
    while giving different --seed runs genuinely different data streams —
    seeding from the step alone made every run sample identical windows,
    so cross-run mean±std understated the data variance.
    """
    return np.random.default_rng((int(seed), 1000 + int(step)))


def _parse_rate_pair(spec):
    """Parse "down[,up]" Markov-rate flags (e.g. --burst 0.1,0.3)."""
    if spec is None:
        return None
    parts = [float(x) for x in spec.split(",")]
    if len(parts) == 1:
        parts.append(0.5)
    if len(parts) != 2:
        raise ValueError(f"expected RATE or RATE_DOWN,RATE_UP, got {spec!r}")
    return tuple(parts)


def _scenario_from_args(args):
    """Resolve the --scenario preset, with per-probability overrides.

    Any temporal flag (--burst/--session/--staleness/--resample) upgrades
    the run to a `TemporalScenario`: explicit Markov rates win, and the
    i.i.d. churn/edge-drop probabilities lower to their degenerate Markov
    equivalents (leave=c, rejoin=1−c reproduces i.i.d. churn bitwise —
    see repro.core.temporal).
    """
    burst = _parse_rate_pair(args.burst)
    session = _parse_rate_pair(args.session)
    scen = get_scenario(args.scenario)
    overrides = {
        field: value
        for field, value in (
            ("churn", args.churn),
            ("straggler", args.straggler),
            ("edge_drop", args.edge_drop),
        )
        if value is not None
    }
    if overrides:
        scen = dataclasses.replace(scen, name=f"{scen.name}+custom", **overrides)
    scen = dataclasses.replace(scen, seed=args.seed)
    if not (burst or session or args.staleness > 0 or args.resample > 0):
        return scen
    if burst is None:
        burst = (scen.edge_drop, 1.0 - scen.edge_drop) \
            if scen.edge_drop > 0 else (0.0, 0.5)
    if session is None:
        session = (scen.churn, 1.0 - scen.churn) \
            if scen.churn > 0 else (0.0, 0.5)
    return TemporalScenario(
        name=f"{scen.name}+temporal",
        burst_down=burst[0], burst_up=burst[1],
        leave=session[0], rejoin=session[1],
        straggler=scen.straggler, staleness=args.staleness,
        resample_every=args.resample, mobility_keep=args.mobility_keep,
        seed=args.seed,
    )


def _faults_from_args(args):
    """Resolve the message-level fault flags into a FaultModel (or None).

    --loss-rate draws i.i.d. per-direction message drops; --loss-burst
    runs a Gilbert–Elliott lossy-link chain per directed slot; --crash
    is a transient node-crash chain (state frozen while down — the local
    checkpoint the node rejoins from); --msg-delay delays delivery only
    (local compute never waits).  All compose with the base --scenario.
    """
    burst = _parse_rate_pair(args.loss_burst)
    crash = _parse_rate_pair(args.crash)
    delay_p, delay_d = 0.0, 0
    if args.msg_delay is not None:
        parts = args.msg_delay.split(",")
        delay_p = float(parts[0])
        delay_d = int(parts[1]) if len(parts) > 1 else 2
    if args.loss_rate is None and burst is None and crash is None \
            and args.msg_delay is None:
        return None
    return FaultModel(
        name="cli",
        loss=args.loss_rate or 0.0,
        burst_down=burst[0] if burst else 0.0,
        burst_up=burst[1] if burst else 0.5,
        crash=crash[0] if crash else 0.0,
        rejoin=crash[1] if crash else 0.5,
        delay=delay_p,
        max_delay=delay_d,
        repair=args.repair,
        seed=args.seed,
    )


def build_everything(args):
    cfg = get_config(args.arch, args.variant)
    if args.seq and cfg.arch_type == "vlm":
        assert args.seq > cfg.n_patches, "seq must exceed n_patches for vlm"
    m = args.nodes
    topo = build_topology(args.topology, m, p=0.5, seed=args.seed)

    corpus = SyntheticTokens.make(m, 65536, cfg.vocab, seed=args.seed)
    node_ids = np.arange(m)[:, None, None]
    offsets = np.arange(args.seq)

    def make_batch(step: int):
        rng = batch_stream_rng(args.seed, step)
        starts = rng.integers(0, corpus.tokens.shape[1] - args.seq - 1, (m, args.batch))
        # one fancy-indexed gather for all m x batch windows — the nested
        # python-loop version dominated step time on smoke configs
        toks = corpus.tokens[node_ids, starts[..., None] + offsets]
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
        if cfg.arch_type == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (m, args.batch, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
            )
        return batch

    def grad_fn(p, b, k):
        del k
        return jax.value_and_grad(lambda pp: train_loss(pp, cfg, b))(p)

    alg = get_algorithm(args.algo)
    hps = _hps_from_args(args.algo, args)
    scen = _scenario_from_args(args)
    faults = _faults_from_args(args)
    params0 = init_params(jax.random.PRNGKey(args.seed), cfg)
    batch0 = make_batch(0) if alg.needs_batch0 else None
    if args.seeds > 1:
        # vmap-over-lanes batched run: one jitted scan trains all seed
        # replicas together (lane s starts from PRNGKey(seed + 1 + s),
        # the key the unbatched run for that seed would use)
        bound = alg.bind_batched(
            grad_fn, topo, [hps],
            seeds=[args.seed + 1 + i for i in range(args.seeds)],
            mixing=args.mixing, seed=args.seed, scenario=scen,
            faults=faults,
        )
        state = bound.init(params0, m, batch0)
    else:
        bound = alg.bind(
            grad_fn, topo, hps,
            mixing=args.mixing, seed=args.seed, scenario=scen,
            faults=faults,
        )
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0
        )
        state = bound.init(jax.random.PRNGKey(args.seed + 1), stacked, batch0)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params0))
    return cfg, bound, state, make_batch, n_params, params0


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--algo", default="pame", choices=list(list_algorithms()))
    ap.add_argument("--mixing", default="sparse", choices=["sparse", "dense"],
                    help="gossip contraction: padded neighbor gather vs dense")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="per-node batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--topology", default="erdos_renyi")
    ap.add_argument("--scenario", default="static", choices=list(list_scenarios()),
                    help="dynamic-network preset: per-step link churn, node "
                         "dropout, stragglers (see repro.core.scenarios)")
    ap.add_argument("--churn", type=float, default=None,
                    help="override: P[node fully offline per step]")
    ap.add_argument("--straggler", type=float, default=None,
                    help="override: P[node misses the exchange per step]")
    ap.add_argument("--edge-drop", type=float, default=None,
                    help="override: P[link fails per step]")
    ap.add_argument("--burst", default=None, metavar="DOWN[,UP]",
                    help="Gilbert-Elliott per-link burst rates: P[good->bad]"
                         "[,P[bad->good]] per step (temporal scenario)")
    ap.add_argument("--session", default=None, metavar="LEAVE[,REJOIN]",
                    help="geometric node sessions: P[up->down][,P[down->up]]"
                         " per step (temporal scenario)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="bounded staleness D: stragglers keep participating"
                         " through their <=D-step-old params from the scan-"
                         "carried snapshot ring (0 = miss the round)")
    ap.add_argument("--resample", type=int, default=0,
                    help="mobility: redraw the active edge subset every N "
                         "steps (0 = off)")
    ap.add_argument("--mobility-keep", type=float, default=0.7,
                    help="P[base edge active within a mobility epoch]")
    ap.add_argument("--loss-rate", type=float, default=None,
                    help="message-level faults: P[a directed message is "
                         "dropped] per step (asymmetric per direction)")
    ap.add_argument("--loss-burst", default=None, metavar="DOWN[,UP]",
                    help="Gilbert-Elliott lossy-link chain per directed "
                         "slot: P[good->lossy][,P[lossy->good]] per step")
    ap.add_argument("--crash", default=None, metavar="RATE[,REJOIN]",
                    help="transient node crashes: P[up->crashed]"
                         "[,P[crashed->recovered]] per step; crashed state "
                         "freezes (local-checkpoint catch-up on rejoin)")
    ap.add_argument("--msg-delay", default=None, metavar="P[,D]",
                    help="delayed delivery: P[a node's outgoing messages "
                         "are late][,staleness bound D (default 2)]; "
                         "message-only — local compute never waits")
    ap.add_argument("--repair", dest="repair", action="store_true",
                    default=True,
                    help="surrogate algorithms resync desynced per-receiver "
                         "replicas via full-surrogate retransmission, "
                         "charged on the wire (default)")
    ap.add_argument("--no-repair", dest="repair", action="store_false",
                    help="disable replica repair: lost innovations desync "
                         "surrogates permanently")
    ap.add_argument("--seeds", type=int, default=1,
                    help="train N seed replicas as lanes of ONE batched "
                         "jitted scan (vmap-over-lanes engine); the log "
                         "reports mean loss ± std across lanes")
    ap.add_argument("--chunk", type=int, default=16,
                    help="steps per scan dispatch (engine chunk length)")
    ap.add_argument("--lr", type=float, default=0.05, help="baseline step size")
    ap.add_argument("--rho", type=float, default=0.01, help="DFedSAM ascent radius")
    ap.add_argument("--nu", type=float, default=0.5)
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--partition", default="flat", choices=["flat", "tree"],
                    help="PaME message format over the model pytree: 'flat' "
                         "prices one concatenated vector; 'tree' gives each "
                         "leaf its own segment — per-leaf rates and per-leaf "
                         "Eq.-(8) wire accounting")
    ap.add_argument("--p-leaf", default=None, metavar="R1,R2,...",
                    help="per-leaf transmission rates (tree partition), one "
                         "per pytree leaf in tree_flatten order; default "
                         "broadcasts --p")
    ap.add_argument("--gamma", type=float, default=1.001)
    ap.add_argument("--sigma0", type=float, default=20.0)
    ap.add_argument("--kappa-lo", type=int, default=3)
    ap.add_argument("--kappa-hi", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=None,
                    help="log cadence in steps (chunk-aligned; default=chunk)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory "
                         "(default: $REPRO_COMPILE_CACHE; unset = off). "
                         "Warm runs skip compilation for identical programs.")
    return ap


def main(argv=None) -> None:
    args = make_parser().parse_args(argv)

    cache_dir = engine.setup_compilation_cache(args.compile_cache)
    if cache_dir:
        print(f"[train] compilation cache at {cache_dir}", flush=True)

    cfg, bound, state, make_batch, n_params, params0 = build_everything(args)
    lanes = bound.lanes if args.seeds > 1 else None
    # per-leaf Eq.-(8) accounting when the algorithm partitions over the
    # model pytree (--partition tree); flat formats price sum(sizes)
    wire_per_step = bound.wire_bits_for(params0)
    scen_tag = bound.scenario.name if bound.dynamic else "static"
    if bound.faulty:
        fm = bound.faults
        scen_tag += (
            f"+faults(loss={fm.loss}, burst={fm.burst_down}/{fm.burst_up}, "
            f"crash={fm.crash}/{fm.rejoin}, delay={fm.delay}<= {fm.max_delay}, "
            f"repair={fm.repair})"
        )
    part_tag = f"partition={args.partition} " if args.algo == "pame" else ""
    print(
        f"[train] algo={args.algo} mixing={args.mixing} {part_tag}"
        f"nodes={args.nodes} scenario={scen_tag} "
        + (f"seeds={args.seeds} (batched lanes) " if lanes else "")
        + f"params={n_params/1e6:.2f}M wire_bits/step={wire_per_step:.3e} "
        f"({wire_per_step/8e6:.2f} MB/step network-wide"
        f"{'; full graph — realized bits logged per step' if bound.dynamic else ''})",
        flush=True,
    )

    carries_aux = bound.temporal or getattr(bound, "faulty", False)
    aux = bound.aux_init(state) if carries_aux else None
    start = 0
    resumed_bits = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        from repro.checkpoint.store import latest_step

        last = latest_step(args.ckpt_dir)
        if last is not None:
            # the auxiliary carry (fault/temporal Markov state + staleness
            # ring) is checkpointed alongside the state, so a resumed run
            # continues the exact chains — the crash-rejoin catch-up path
            # restores from the same store.  The payload also carries the
            # realized cumulative wire bits: re-deriving them as
            # wire_per_step * start would charge the static full-graph
            # rate for steps that actually ran under dynamic/fault
            # accounting.
            payload = {"state": state, "cum_bits": np.zeros((), np.float64)}
            if carries_aux:
                payload["aux"] = aux
            try:
                restored = restore_checkpoint(args.ckpt_dir, payload, last)
                resumed_bits = float(restored["cum_bits"])
            except ValueError:
                # legacy checkpoint (no cum_bits leaf): restore the old
                # payload shape and fall back to the static estimate
                if carries_aux:
                    restored = restore_checkpoint(
                        args.ckpt_dir, {"state": state, "aux": aux}, last
                    )
                else:
                    restored = {"state": restore_checkpoint(
                        args.ckpt_dir, state, last)}
            state = restored["state"]
            if carries_aux:
                aux = restored["aux"]
            start = last
            print(f"[train] resumed from step {last}")

    runner = engine.make_scan_runner(
        bound.step, chunk_size=args.chunk, step_takes_index=bound.dynamic,
        carries_aux=carries_aux, lanes=lanes,
    )
    log_every = max(args.log_every or args.chunk, 1)
    t0 = time.time()
    k = start
    cum_bits = resumed_bits if resumed_bits is not None else wire_per_step * start
    stale_hist = None
    next_ckpt = (start // args.ckpt_every + 1) * args.ckpt_every
    while k < args.steps:
        length = min(args.chunk, args.steps - k)
        k0 = k
        # copy_state=False: we rebind to the returned state, so the engine
        # can donate our buffers without the per-chunk protective deep copy.
        # k_start keeps batches and scenario realizations aligned with the
        # *global* step index across chunk dispatches.
        state, metrics, info = runner(
            state, make_batch, length, copy_state=False, k_start=k0, aux=aux
        )
        aux = info["aux"]
        k += info["steps_dispatched"]
        if "wire_bits" in metrics:  # realized (surviving-edge) accounting
            # batched rows are [steps, L]: report the per-lane average so
            # the log stays comparable with a single-seed run
            cum_bits += float(np.sum(metrics["wire_bits"])) / (lanes or 1)
        else:
            cum_bits += wire_per_step * info["steps_dispatched"]
        if "stale_hist" in metrics:  # per-run staleness occupancy histogram
            rows = np.asarray(metrics["stale_hist"])
            row = rows.reshape(-1, rows.shape[-1]).sum(axis=0)
            stale_hist = row if stale_hist is None else stale_hist + row
        if (k // log_every) != (k0 // log_every) or k >= args.steps:
            lm = np.asarray(metrics["loss_mean"])
            loss = float(np.mean(lm))
            extra = ""
            if lanes:  # spread of the seed replicas at the last step
                extra += f" loss_std={float(np.std(lm[-1])):.4f}"
            last = lambda key: float(np.mean(np.asarray(metrics[key])[-1]))
            if "consensus" in metrics:
                extra += f" consensus={last('consensus'):.3e}"
            if "comm_nodes" in metrics:
                extra += f" comm_nodes={last('comm_nodes'):.0f}"
            if "alive_nodes" in metrics:
                extra += f" alive={last('alive_nodes'):.0f}"
            if "stale_nodes" in metrics:
                extra += f" stale={last('stale_nodes'):.0f}"
            if "crashed_nodes" in metrics:
                extra += f" crashed={last('crashed_nodes'):.0f}"
            if "dropped_msgs" in metrics:
                extra += f" dropped={last('dropped_msgs'):.0f}"
            if "mean_drift" in metrics:
                extra += f" drift={last('mean_drift'):.3f}"
            if "surrogate_desync" in metrics:
                extra += f" desync={last('surrogate_desync'):.3e}"
            if "sigma_mean" in metrics:
                extra += f" sigma={last('sigma_mean'):.2f}"
            print(
                f"[train] step={k} loss={loss:.4f}{extra}"
                f" wire_gbits={cum_bits/1e9:.4f}"
                f" ({(time.time()-t0)/(k-start):.2f}s/step)",
                flush=True,
            )
        if args.ckpt_dir and k >= next_ckpt:
            payload = {"state": state,
                       "cum_bits": np.asarray(cum_bits, np.float64)}
            if carries_aux:
                payload["aux"] = aux
            save_checkpoint(args.ckpt_dir, k, payload)
            next_ckpt = (k // args.ckpt_every + 1) * args.ckpt_every
    if stale_hist is not None:
        total = max(float(stale_hist.sum()), 1.0)
        cells = " ".join(
            f"tau={t}:{int(c)}({c / total:.0%})"
            for t, c in enumerate(stale_hist)
        )
        print(f"[train] staleness histogram (participant-steps): {cells}")
    print("[train] done")


if __name__ == "__main__":
    main()
