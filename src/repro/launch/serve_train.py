"""Serve-while-train driver: training rounds interleaved with inference.

Every node fields a stream of decode requests while it trains.  Arrivals
(Poisson or Markov-modulated bursts, ``repro.serve.events``) pace the
gossip rounds — a backlogged node defers its exchange like a paper
straggler but keeps taking local steps — and between training dispatches
each node serves real batched greedy decode traffic against its *current
local* parameters (``repro.serve.serving``), with per-node latency /
throughput / staleness-of-served-model logged.

Elastic membership: ``--join STEP:N[:DEGREE]`` grows the node set
mid-run (``repro.serve.membership``) — genuinely new nodes attach to
uniform existing nodes, the Metropolis–Hastings weights are re-derived
over the grown graph (doubly stochastic ⇒ mean-preserving, checked at
every join), and each joiner catches up by cloning a trained neighbor
from the latest checkpoint (``--ckpt-dir``) or, absent one, the live
state.  Crash faults are refused when membership changes are scheduled —
their ``rejoin`` path assumes fixed m (see
``membership.check_membership_faults``).

Chaos timeline: ``--chaos "leave@20:2,partition@40:bridge,heal@80,
join@90:1"`` composes graceful departures (mass handoff to neighbors,
mean-preserving and conformance-asserted), scheduled network partitions
(persistent cross-component cuts realizing a block-doubly-stochastic
matrix per component, healed with drift reconciliation), and joins in
one run, with in-run invariant monitors (row/col stochasticity defect,
per-component mean preservation) at every event boundary.  An empty
timeline is bitwise identical to the plain serve_train path.  Serving
failover: ``--serve-policy consensus`` answers every request from the
node's *component's* PME-averaged model instead of its local copy.

    PYTHONPATH=src python -m repro.launch.serve_train --arch stablelm-1.6b \
        --steps 60 --nodes 8 --join 30:4 --arrival bursty \
        --prompt-len 8 --gen 4 --serve-batch 2
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.configs import get_config
from repro.core import engine
from repro.core.algorithms import get_algorithm, list_algorithms
from repro.core.faults import FaultModel
from repro.core import scenarios as scen_mod
from repro.core.scenarios import get_scenario, list_scenarios
from repro.core.topology import build_topology
from repro.data.synthetic import SyntheticTokens
from repro.launch.train import _hps_from_args, batch_stream_rng
from repro.models.model import init_params, train_loss
from repro.serve import events as ev_mod
from repro.serve import membership as mb_mod
from repro.serve.serving import ServeLoop


def _pacing_from_args(args) -> ev_mod.ServePacing:
    proc = ev_mod.get_arrival(args.arrival)
    overrides = {}
    if args.rate is not None:
        overrides["rate"] = args.rate
    if args.burst_rate is not None:
        overrides["burst_rate"] = args.burst_rate
    if overrides:
        proc = dataclasses.replace(proc, name=f"{proc.name}+custom",
                                   **overrides)
    proc = dataclasses.replace(proc, seed=args.seed)
    return ev_mod.ServePacing(
        process=proc, capacity=args.serve_capacity,
        defer_threshold=args.defer_threshold,
    )


def _make_batch_fn(args, cfg, m):
    """Per-node LM batch stream for the current node count.

    ``SyntheticTokens.make`` draws node corpora sequentially, so the
    first m_old shards are bitwise stable when m grows at a join — the
    incumbent nodes keep their data streams.
    """
    corpus = SyntheticTokens.make(m, 65536, cfg.vocab, seed=args.seed)
    node_ids = np.arange(m)[:, None, None]
    offsets = np.arange(args.seq)

    def make_batch(step: int):
        rng = batch_stream_rng(args.seed, step)
        starts = rng.integers(
            0, corpus.tokens.shape[1] - args.seq - 1, (m, args.batch)
        )
        toks = corpus.tokens[node_ids, starts[..., None] + offsets]
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
        if cfg.arch_type == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (m, args.batch, cfg.n_patches, cfg.vision_dim),
                jnp.dtype(cfg.dtype),
            )
        return batch

    return make_batch


def _bind_for(args, cfg, topo, pacing, faults, partitions=()):
    """(Re)bind the algorithm over the current topology — called at
    start and after every membership change (recompile is the price of a
    new node count; the compilation cache amortizes repeats).  Chaos
    partition windows fold into the scenario here, so the in-scan
    realization cuts cross-component edges while a window is open."""

    def grad_fn(p, b, k):
        del k
        return jax.value_and_grad(lambda pp: train_loss(pp, cfg, b))(p)

    alg = get_algorithm(args.algo)
    hps = _hps_from_args(args.algo, args)
    scen = get_scenario(args.scenario)
    scen = dataclasses.replace(scen, seed=args.seed)
    if partitions:
        scen = dataclasses.replace(scen, partitions=tuple(partitions))
    bound = alg.bind(
        grad_fn, topo, hps, mixing=args.mixing, seed=args.seed,
        scenario=None if scen.is_static else scen,
        faults=faults, pacing=pacing,
    )
    runner = engine.make_scan_runner(
        bound.step, chunk_size=args.chunk,
        step_takes_index=bound.dynamic, carries_aux=bound.carries_aux,
    )
    return bound, runner


def _join_conformance(topo_new: "object", m_old: int, kind="join") -> dict:
    """The membership conformance suite, run at every join/leave: the
    re-derived mixing matrix must stay doubly stochastic and
    mean-preserving over the changed node set."""
    w = topo_new.mixing
    rows_ok = bool(np.allclose(w.sum(axis=1), 1.0, atol=1e-9))
    cols_ok = bool(np.allclose(w.sum(axis=0), 1.0, atol=1e-9))
    x = np.random.default_rng(0).standard_normal((topo_new.m, 7))
    mean_ok = bool(np.allclose((w @ x).mean(axis=0), x.mean(axis=0),
                               atol=1e-9))
    ok = rows_ok and cols_ok and mean_ok
    if not ok:
        raise AssertionError(
            f"{kind} conformance FAILED at m={m_old}->{topo_new.m}: "
            f"rows={rows_ok} cols={cols_ok} mean={mean_ok}"
        )
    return {"rows": rows_ok, "cols": cols_ok, "mean": mean_ok}


def _params_mean(bound, state) -> np.ndarray:
    """Host copy of the global parameter mean (concatenated leaves) —
    the quantity graceful departures must preserve."""
    return np.concatenate([
        np.asarray(jnp.mean(leaf.astype(jnp.float32), axis=0)).ravel()
        for leaf in jax.tree_util.tree_leaves(bound.spec.params_of(state))
    ])


def _leave_conformance(pre_mean: np.ndarray, bound, state, m_old: int,
                       m_new: int) -> None:
    """Departure invariant: the survivors' parameter mean equals the
    pre-departure global mean to float32 tolerance (the β-weighted
    deviation handoff is mean-preserving by construction)."""
    post_mean = _params_mean(bound, state)
    scale = max(float(np.max(np.abs(pre_mean))), 1.0)
    if not np.allclose(post_mean, pre_mean, atol=1e-5 * scale, rtol=1e-5):
        worst = float(np.max(np.abs(post_mean - pre_mean)))
        raise AssertionError(
            f"leave conformance FAILED at m={m_old}->{m_new}: survivor "
            f"mean drifted by {worst:.3e} (float32 tolerance exceeded)"
        )


def _active_comp(bound, k):
    """Host copy of the step's component-id vector (None when the bind
    schedules no partitions — a single global component)."""
    arrays = getattr(bound, "scen_arrays", None)
    if arrays is None or arrays.part_comp is None:
        return None
    return np.asarray(scen_mod.active_components(arrays, jnp.int32(k)))


def _chaos_monitor(bound, k: int, tag: str) -> None:
    """In-run invariant monitor for chaos runs: realizes step k's matrix
    host-side and asserts the paper's Assumption-1 invariants — row/col
    stochasticity defect at float32 tolerance, zero cross-component mass
    while a partition window is open, and per-component (hence global)
    mean preservation."""
    if not bound.dynamic or getattr(bound, "temporal", False):
        return
    arrays = bound.scen_arrays
    r = scen_mod.realize(bound.scenario, arrays, jnp.int32(k))
    w = np.asarray(scen_mod.realization_matrix(arrays, r), np.float64)
    row_defect = float(np.max(np.abs(w.sum(axis=1) - 1.0)))
    col_defect = float(np.max(np.abs(w.sum(axis=0) - 1.0)))
    assert row_defect < 1e-4 and col_defect < 1e-4, (
        f"{tag}: stochasticity defect rows={row_defect:.2e} "
        f"cols={col_defect:.2e} at k={k}"
    )
    comp = _active_comp(bound, k)
    x = np.random.default_rng(1).standard_normal((w.shape[0], 5))
    if comp is not None and comp.max() > 0:
        cross = float(w[comp[:, None] != comp[None, :]].sum())
        assert cross == 0.0, (
            f"{tag}: {cross:.2e} cross-component mass inside an open "
            f"partition window at k={k}"
        )
        for c in np.unique(comp):
            sel = comp == c
            pre = x[sel].mean(axis=0)
            post = (w @ x)[sel].mean(axis=0)
            assert np.allclose(post, pre, atol=1e-5), (
                f"{tag}: component {c} mean not preserved at k={k}"
            )
    else:
        assert np.allclose((w @ x).mean(axis=0), x.mean(axis=0),
                           atol=1e-5), f"{tag}: global mean not preserved"
    print(
        f"[serve-train] monitor@{k} {tag}: stochasticity defect "
        f"{max(row_defect, col_defect):.1e}, mean-preserving (green)",
        flush=True,
    )


def _comp_drift(bound, state, comp) -> float:
    """Max ℓ2 gap between any component's parameter mean and the global
    mean — the drift a heal event hands back to gossip to reconcile."""
    x = np.concatenate([
        np.asarray(leaf).reshape(leaf.shape[0], -1).astype(np.float32)
        for leaf in jax.tree_util.tree_leaves(bound.spec.params_of(state))
    ], axis=1)
    gmean = x.mean(axis=0)
    return max(
        float(np.linalg.norm(x[comp == c].mean(axis=0) - gmean))
        for c in np.unique(comp)
    )


def _serve_report(tag, stats, es=None):
    """One per-node serving log line: decode throughput from the serve
    loop, queueing latency / staleness-of-served-model from the event
    clock (Little's law: wait_i / served_i rounds)."""
    for i, s in sorted(stats.items()):
        extra = ""
        if es is not None:
            served = max(int(np.asarray(es.served)[i]), 1)
            lat = float(np.asarray(es.wait)[i]) / served
            extra = (
                f" queue={int(np.asarray(es.queue)[i])}"
                f" latency={lat:.2f} rounds (model-staleness)"
            )
        print(
            f"{tag} node={i} prefill={s['prefill_ms']:.0f}ms "
            f"decode={s['decode_ms']:.0f}ms "
            f"tokens/s={s['tokens_per_s']:.1f}{extra}",
            flush=True,
        )


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--algo", default="pame", choices=list(list_algorithms()))
    ap.add_argument("--mixing", default="sparse", choices=["sparse", "dense"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4, help="per-node batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--topology", default="erdos_renyi")
    ap.add_argument("--scenario", default="static",
                    choices=list(list_scenarios()))
    ap.add_argument("--chunk", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    # training hps (shared with launch.train's _hps_from_args)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--nu", type=float, default=0.5)
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=1.001)
    ap.add_argument("--sigma0", type=float, default=20.0)
    ap.add_argument("--kappa-lo", type=int, default=3)
    ap.add_argument("--kappa-hi", type=int, default=7)
    # serving: arrivals pace the rounds, decode traffic is served between
    # training dispatches
    ap.add_argument("--arrival", default="bursty",
                    choices=list(ev_mod.list_arrivals()),
                    help="request arrival preset (repro.serve.events)")
    ap.add_argument("--rate", type=float, default=None,
                    help="override: quiet-state arrivals/node/round")
    ap.add_argument("--burst-rate", type=float, default=None,
                    help="override: burst-state arrivals/node/round")
    ap.add_argument("--serve-capacity", type=int, default=4,
                    help="requests a node can serve per round")
    ap.add_argument("--defer-threshold", type=int, default=8,
                    help="backlog beyond which a node defers its gossip")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=4,
                    help="tokens generated per served request batch")
    ap.add_argument("--serve-batch", type=int, default=2,
                    help="requests batched into one decode call")
    ap.add_argument("--serve-every", type=int, default=None,
                    help="serve a decode round every N training steps "
                         "(chunk-aligned; default=chunk)")
    ap.add_argument("--serve-nodes", type=int, default=2,
                    help="nodes served per decode round (round-robin)")
    # elastic membership
    ap.add_argument("--join", default=None, metavar="STEP:N[:DEG],...",
                    help="membership joins: N new nodes at STEP, each "
                         "attached to DEG uniform existing nodes "
                         "(default --join-degree); catch-up clones a "
                         "trained neighbor from --ckpt-dir or live state")
    ap.add_argument("--join-degree", type=int, default=2)
    ap.add_argument("--chaos", default=None, metavar="KIND@STEP[:ARG],...",
                    help="chaos timeline composed with --join: leave@S:N "
                         "(N highest-id nodes depart gracefully), "
                         "partition@S:P|bridge (split into P components), "
                         "heal@S, join@S:N[:DEG].  Empty timeline keeps "
                         "the plain serve_train path bitwise identical")
    ap.add_argument("--serve-policy", default="local",
                    choices=["local", "consensus"],
                    help="what each node serves FROM: its own local model "
                         "(freshest) or its connected component's "
                         "PME-averaged model (coherent failover during "
                         "splits and departures)")
    # faults (to compose — and to demonstrate the crash+join refusal)
    ap.add_argument("--loss-rate", type=float, default=None,
                    help="P[a directed message is dropped] per step")
    ap.add_argument("--crash", default=None, metavar="RATE[,REJOIN]",
                    help="fixed-m transient crashes; refused when --join "
                         "is scheduled (membership.check_join_faults)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compile-cache", default=None, metavar="DIR")
    return ap


def _faults_from_args(args):
    crash = None
    if args.crash is not None:
        parts = [float(x) for x in args.crash.split(",")]
        crash = (parts[0], parts[1] if len(parts) > 1 else 0.5)
    if args.loss_rate is None and crash is None:
        return None
    return FaultModel(
        name="cli",
        loss=args.loss_rate or 0.0,
        crash=crash[0] if crash else 0.0,
        rejoin=crash[1] if crash else 0.5,
        seed=args.seed,
    )


def main(argv=None):
    args = make_parser().parse_args(argv)
    cache_dir = engine.setup_compilation_cache(args.compile_cache)
    if cache_dir:
        print(f"[serve-train] compilation cache at {cache_dir}", flush=True)

    timeline = mb_mod.parse_chaos_spec(args.chaos, args.join_degree)
    events = deque(sorted(
        timeline + tuple(
            mb_mod.ChaosEvent(step=e.step, kind="join", n=e.n_new,
                              degree=e.degree)
            for e in mb_mod.parse_join_spec(args.join, args.join_degree)
        ),
        key=lambda e: e.step,
    ))
    faults = _faults_from_args(args)
    if events:
        mb_mod.check_membership_faults(faults, tuple(events), m0=args.nodes)
    windows = mb_mod.chaos_partitions(tuple(events), args.steps,
                                      seed=args.seed)
    pacing = _pacing_from_args(args)

    cfg = get_config(args.arch, args.variant)
    m = args.nodes
    topo = build_topology(args.topology, m, p=0.5, seed=args.seed)
    bound, runner = _bind_for(args, cfg, topo, pacing, faults, windows)
    make_batch = _make_batch_fn(args, cfg, m)

    params0 = init_params(jax.random.PRNGKey(args.seed), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0
    )
    batch0 = make_batch(0) if bound.spec.needs_batch0 else None
    state = bound.init(jax.random.PRNGKey(args.seed + 1), stacked, batch0)
    aux = bound.aux_init(state) if bound.carries_aux else None

    serve = ServeLoop(
        cfg, prompt_len=args.prompt_len, gen=args.gen,
        batch=args.serve_batch, seed=args.seed,
    )
    n_params = sum(
        int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params0)
    )
    ev_summary = [
        f"{e.kind}@{e.step}" + (f":{e.n}" if e.n else "") for e in events
    ]
    print(
        f"[serve-train] algo={args.algo} nodes={m} "
        f"arrival={pacing.process.name} "
        f"(rate={pacing.process.rate}/{pacing.process.burst_rate} "
        f"cap={pacing.capacity} defer>{pacing.defer_threshold}) "
        f"events={ev_summary or 'none'} "
        f"serve-policy={args.serve_policy} "
        f"params={n_params / 1e6:.2f}M",
        flush=True,
    )
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)

    serve_every = max(args.serve_every or args.chunk, 1)
    t0 = time.time()
    k = 0
    serve_cursor = 0  # round-robin over nodes
    next_serve = serve_every
    next_ckpt = args.ckpt_every
    deferred_total = 0.0
    while k < args.steps:
        boundary = args.steps
        if events:
            boundary = min(boundary, events[0].step)
        if k >= boundary:  # event scheduled at or before the current step
            boundary = min(args.steps, k + args.chunk)
        length = min(args.chunk, boundary - k)
        if length > 0:
            state, metrics, info = runner(
                state, make_batch, length, copy_state=False, k_start=k,
                aux=aux,
            )
            aux = info.get("aux") if bound.carries_aux else None
            k += info["steps_dispatched"]
            loss = float(np.mean(np.asarray(metrics["loss_mean"])))
            extra = ""
            if "deferred_nodes" in metrics:
                d = float(np.sum(np.asarray(metrics["deferred_nodes"])))
                deferred_total += d
                extra += (
                    f" deferred={d:.0f}/{length * m} node-rounds"
                    f" queue={float(np.asarray(metrics['queue_depth'])[-1]):.1f}"
                )
            if "comp_mean_gap" in metrics:
                gap = float(np.asarray(metrics["comp_mean_gap"])[-1])
                extra += f" comp-gap={gap:.2e}"
            print(
                f"[serve-train] step={k} m={m} loss={loss:.4f}{extra}"
                f" ({(time.time() - t0) / max(k, 1):.2f}s/step)",
                flush=True,
            )

        if k >= next_serve or k >= args.steps:
            ids = [(serve_cursor + i) % m
                   for i in range(min(args.serve_nodes, m))]
            serve_cursor = (serve_cursor + args.serve_nodes) % m
            comp = None
            if args.serve_policy == "consensus":
                comp = _active_comp(bound, max(k - 1, 0))
            stats = serve.serve_round(
                bound.spec.params_of(state), ids,
                policy=args.serve_policy, comp=comp,
            )
            es = aux.events if (aux is not None and bound.paced) else None
            _serve_report(f"[serve-train] serve@{k}", stats, es)
            next_serve += serve_every

        if args.ckpt_dir and k >= next_ckpt:
            payload = {"state": state}
            if aux is not None:
                payload["aux"] = aux
            save_checkpoint(args.ckpt_dir, k, payload)
            next_ckpt = (k // args.ckpt_every + 1) * args.ckpt_every

        while events and k >= events[0].step:
            ev = events.popleft()
            # future partition windows re-resolve against the current
            # topology at every rebind (check_membership_faults already
            # forbade membership changes inside an open window)
            future = tuple(w for w in windows if w.start >= k)

            if ev.kind == "partition":
                print(
                    f"[serve-train] partition@{k}: graph split into "
                    f"{ev.n} components (cross-component edges cut "
                    "until heal)",
                    flush=True,
                )
                _chaos_monitor(bound, k, f"partition@{ev.step}")
                continue

            if ev.kind == "heal":
                comp = _active_comp(bound, max(ev.step - 1, 0))
                drift = (
                    _comp_drift(bound, state, comp)
                    if comp is not None and comp.max() > 0 else 0.0
                )
                print(
                    f"[serve-train] heal@{k}: partition re-merged; "
                    f"component mean drift {drift:.3e} handed back to "
                    "gossip to reconcile",
                    flush=True,
                )
                _chaos_monitor(bound, k, f"heal@{ev.step}")
                continue

            if ev.kind == "leave":
                if ev.n == 0:
                    continue
                m_old = m
                # LIFO departure: the highest-id nodes retire, so state
                # rows stay contiguous and survivors keep their shards
                leavers = tuple(range(m - ev.n, m))
                pre_mean = _params_mean(bound, state)
                state = mb_mod.retire_state(state, topo, leavers)
                topo = mb_mod.shrunk_topology(topo, leavers)
                m = topo.m
                conf = _join_conformance(topo, m_old, kind="leave")
                old_events = (
                    aux.events if (aux is not None and bound.paced)
                    else None
                )
                bound, runner = _bind_for(args, cfg, topo, pacing, faults,
                                          future)
                make_batch = _make_batch_fn(args, cfg, m)
                if bound.carries_aux:
                    aux = bound.aux_init(state)
                    if bound.paced and old_events is not None:
                        # survivors keep their cumulative QPS/latency
                        aux = aux._replace(events=ev_mod.shrink_events(
                            old_events, list(range(m))))
                else:
                    aux = None
                _leave_conformance(pre_mean, bound, state, m_old, m)
                print(
                    f"[serve-train] leave@{k}: m={m_old}->{m} "
                    f"retired={list(leavers)} deviation mass handed to "
                    f"neighbors (mean-preserving) conformance: "
                    f"doubly-stochastic={conf['rows'] and conf['cols']} "
                    f"mean-preserving={conf['mean']} (green)",
                    flush=True,
                )
                continue

            # ev.kind == "join"
            if ev.n == 0:
                continue
            m_old = m
            topo = mb_mod.grown_topology(
                topo, ev.n, degree=ev.degree, seed=args.seed
            )
            m = topo.m
            donors = mb_mod.default_donors(topo, m_old)
            conf = _join_conformance(topo, m_old)
            # checkpoint catch-up: clone the donors' rows from the latest
            # checkpoint when one exists, else from the live state —
            # bitwise identical for a donor whose state has not moved
            # since the save (pinned by tests/test_membership.py)
            source = None
            src_tag = "live"
            if args.ckpt_dir:
                last = latest_step(args.ckpt_dir)
                if last is not None:
                    tmpl = {"state": state}
                    if aux is not None:
                        tmpl["aux"] = aux
                    try:
                        source = restore_checkpoint(
                            args.ckpt_dir, tmpl, last)["state"]
                        src_tag = f"ckpt@{last}"
                    except Exception:
                        source = None  # stale/mismatched ckpt: live donors
            state = mb_mod.expand_state(state, m_old, donors,
                                        source_state=source)
            old_events = (
                aux.events if (aux is not None and bound.paced) else None
            )
            bound, runner = _bind_for(args, cfg, topo, pacing, faults,
                                      future)
            make_batch = _make_batch_fn(args, cfg, m)
            if bound.carries_aux:
                aux = bound.aux_init(state)
                if bound.paced and old_events is not None:
                    # carry cumulative QPS/latency accounting through
                    # the join; fresh rows for the new nodes
                    aux = aux._replace(
                        events=ev_mod.expand_events(old_events, ev.n)
                    )
            else:
                aux = None
            print(
                f"[serve-train] join@{k}: m={m_old}->{m} "
                f"donors={donors.tolist()} catch-up={src_tag} "
                f"conformance: doubly-stochastic="
                f"{conf['rows'] and conf['cols']} "
                f"mean-preserving={conf['mean']} (green)",
                flush=True,
            )

    # run-level serving summary
    if aux is not None and bound.paced:
        es = aux.events
        arrived = np.asarray(es.arrived)
        served = np.asarray(es.served)
        wait = np.asarray(es.wait)
        lat = wait / np.maximum(served, 1)
        elapsed = max(time.time() - t0, 1e-9)
        qps = float(served.sum()) / elapsed
        print(
            f"[serve-train] served {int(served.sum())}/{int(arrived.sum())} "
            f"requests ({qps:.1f} req/s wall) "
            f"mean latency={float(lat.mean()):.2f} rounds "
            f"deferred={deferred_total:.0f} node-rounds",
            flush=True,
        )
        worst = int(np.argmax(lat))
        print(
            f"[serve-train] per-node latency (rounds): "
            + " ".join(f"{i}:{v:.1f}" for i, v in enumerate(lat))
            + f" (worst node {worst})",
            flush=True,
        )
    print("[serve-train] done")
    return state


if __name__ == "__main__":
    main()
