import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be run as its own process (the XLA_FLAGS line above has to execute
before jax initialises devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per combo it records compiled.memory_analysis(), cost_analysis() (flops /
bytes are PER DEVICE on the partitioned module) and the collective-op
bytes parsed from the post-SPMD HLO text — the three §Roofline inputs.
Results accumulate incrementally in benchmarks/artifacts/dryrun.json so
interrupted sweeps resume.
"""
import argparse
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_names, get_config
from repro.configs.shapes import (
    INPUT_SHAPES,
    cache_capacity,
    config_for_shape,
    input_specs,
)
from repro.core.pame import (
    PaMEConfig,
    PaMEState,
    make_topology_arrays,
    pame_step,
)
from repro.core.topology import build_topology
from repro.launch.mesh import make_logical_mesh, make_production_mesh
from repro.models.config import ModelConfig
from repro.models.model import decode_step, init_params, prefill, train_loss
from repro import sharding as shd

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind (per-device view).

    all-reduce counts x2 (reduce-scatter + all-gather equivalent traffic).
    """
    out: Dict[str, int] = {}
    for shape_txt, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(shape_txt)
        if kind == "all-reduce":
            b *= 2
        out[kind] = out.get(kind, 0) + b
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def build_train(cfg: ModelConfig, m: int, exchange: str = "dense"):
    topo = build_topology("ring", m) if m > 2 else build_topology("complete", max(m, 2))
    pcfg = PaMEConfig(
        nu=0.5, p=0.2, gamma=1.001, sigma0=5.0,
        mask_mode="bernoulli", homogeneous_kappa=4, exchange=exchange,
    )
    topo_arrays = make_topology_arrays(topo, pcfg)

    def grad_fn(p, b, k):
        del k
        return jax.value_and_grad(lambda pp: train_loss(pp, cfg, b))(p)

    def step(state, batch, param_shardings=None):
        return pame_step(
            state, batch, grad_fn, topo_arrays, pcfg,
            param_shardings=param_shardings,
        )

    return step


def train_state_specs(cfg: ModelConfig, m: int) -> PaMEState:
    pshapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((m,) + s.shape, s.dtype), pshapes
    )
    return PaMEState(
        params=stacked,
        sigma=jax.ShapeDtypeStruct((m,), jnp.float32),
        step=jax.ShapeDtypeStruct((), jnp.int32),
        key=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


# ---------------------------------------------------------------------------
# one combo
# ---------------------------------------------------------------------------
def probe_depths(cfg: ModelConfig) -> tuple:
    """Two reduced depths (full width!) compiled *unrolled* so XLA cost
    analysis counts every layer; the roofline reader extrapolates linearly
    to the real depth (lax.scan bodies are otherwise counted once)."""
    if cfg.arch_type == "hybrid":
        return (cfg.attn_every, 2 * cfg.attn_every)
    if cfg.arch_type == "moe":
        fd = cfg.first_dense_layers
        return (fd + 2, fd + 4)
    return (2, 4)


# named perf variants for the §Perf hillclimb (dryrun --variant NAME);
# model-config overrides + the PaME exchange mode
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "compressed": {"exchange": "compressed"},
    "remat_dots": {"remat_policy": "dots"},
    "compressed+dots": {"exchange": "compressed", "remat_policy": "dots"},
    "chunked2048": {"prefill_chunk": 2048},
    "chunked512": {"prefill_chunk": 512},
    "chunked512+dots": {"prefill_chunk": 512, "remat_policy": "dots"},
    # sharding-rule experiments (applied via repro.sharding.RULE_OVERRIDES)
    "embed_vocab_only": {"_rules": {"embed": ("model", None)}},
    "embed_vocab_only+compressed": {
        "_rules": {"embed": ("model", None)}, "exchange": "compressed",
    },
    # mamba experiments: the (fsdp, model) column-sharded in_proj forces a
    # reshard at the z/xBC/dt split; try unsharded columns instead
    "mamba_nosplit_shard": {
        "_rules": {
            "mamba/in_proj": ("fsdp", None),
            "mamba/out_proj": (None, "fsdp"),
            "mamba/conv_w": (None, None),
            "mamba/conv_b": (None,),
        }
    },
    # proper fix: separate z/x/B/C/dt projections, head-aligned shards
    "mamba_split_proj": {"ssm_split_proj": True},
    # int8 payloads on the compressed wire
    "compressed_q8": {"exchange": "compressed_q8"},
}


def run_combo(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    remat: bool = True,
    probe_layers: Optional[int] = None,
    variant: str = "baseline",
) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    base = get_config(arch)
    cfg = config_for_shape(base, shape)
    overrides = dict(VARIANTS[variant])
    exchange = overrides.pop("exchange", "dense")
    shd.RULE_OVERRIDES.clear()
    shd.RULE_OVERRIDES.update(overrides.pop("_rules", {}))
    if overrides:
        cfg = cfg.replace(**overrides)
    if probe_layers is not None:
        cfg = cfg.replace(n_layers=probe_layers, unroll=True)
    if shape.kind == "train" and remat:
        cfg = cfg.replace(remat=True)
    multi = mesh_kind == "multi"
    prod = make_production_mesh(multi_pod=multi)
    # mesh layout always follows the FULL-depth config so reduced-depth
    # probes land on the same (node, fsdp, model) layout they extrapolate to
    mesh = make_logical_mesh(
        config_for_shape(base, shape), multi_pod=multi, production=prod
    )
    node, fsdp, model = mesh.devices.shape
    t0 = time.time()

    if shape.kind == "train":
        m = node
        step = build_train(cfg, m, exchange=exchange)
        state_specs = train_state_specs(cfg, m)
        batch_specs = input_specs(cfg, shape, m_nodes=m)
        state_sh = shd.state_shardings(state_specs, mesh)
        in_sh = (state_sh, shd.batch_shardings(batch_specs, mesh, node_stacked=True))
        bound = lambda s, b: step(s, b, param_shardings=state_sh.params)
        with mesh:
            lowered = jax.jit(bound, in_shardings=in_sh).lower(state_specs, batch_specs)
    elif shape.kind == "prefill":
        cap = cache_capacity(cfg, shape)
        pshapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        batch_specs = input_specs(cfg, shape)
        fn = lambda p, b: prefill(p, cfg, b, cap)
        in_sh = (
            shd.params_shardings(pshapes, mesh, node_stacked=False),
            shd.batch_shardings(batch_specs, mesh, node_stacked=False),
        )
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(pshapes, batch_specs)
    else:  # decode
        pshapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        specs = input_specs(cfg, shape)
        fn = lambda p, tok, pos, cache: decode_step(p, cfg, tok, pos, cache)
        in_sh = (
            shd.params_shardings(pshapes, mesh, node_stacked=False),
            shd.batch_shardings(specs["token"], mesh, node_stacked=False),
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            shd.cache_shardings(specs["cache"], mesh),
        )
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(
                pshapes, specs["token"], specs["pos"], specs["cache"]
            )

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    colls = parse_collective_bytes(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "probe_layers": probe_layers,
        "n_layers": cfg.n_layers,
        "layout": {"node": node, "fsdp": fsdp, "model": model},
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes": colls,
        "collective_bytes_total": float(sum(colls.values())),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "param_count": base.param_count(),
        "active_param_count": base.active_param_count(),
        "tokens": shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    tag = f"L{probe_layers}" if probe_layers else "full"
    if variant != "baseline":
        tag += f"/{variant}"
    print(
        f"[dryrun] {arch} x {shape_name} x {mesh_kind} [{tag}]"
        f" (node={node},fsdp={fsdp},model={model})"
        f" flops/dev={rec['flops_per_device']:.3e}"
        f" bytes/dev={rec['bytes_per_device']:.3e}"
        f" coll={rec['collective_bytes_total']:.3e}"
        f" temp={mem.temp_size_in_bytes/1e9:.2f}GB"
        f" compile={t_compile:.1f}s",
        flush=True,
    )
    return rec


def results_path() -> str:
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))
    d = os.path.join(root, "benchmarks", "artifacts")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, "dryrun.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument(
        "--probes", action="store_true",
        help="also compile the two reduced-depth UNROLLED probes per combo "
        "(exact per-layer cost for roofline extrapolation)",
    )
    ap.add_argument(
        "--variant", default="baseline", choices=list(VARIANTS),
        help="perf variant for the §Perf hillclimb",
    )
    args = ap.parse_args()

    archs = all_arch_names() if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = (
        list(INPUT_SHAPES) if (args.all or args.shape in (None, "all")) else [args.shape]
    )
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    path = results_path()
    results: Dict[str, Dict] = {}
    if os.path.exists(path):
        with open(path) as f:
            results = json.load(f)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                depth_list = [None]
                if args.probes:
                    depth_list += list(probe_depths(get_config(arch)))
                for depth in depth_list:
                    key = f"{arch}|{shape}|{mesh_kind}" + (
                        f"|L{depth}" if depth else ""
                    )
                    if args.variant != "baseline":
                        key += f"|{args.variant}"
                    if key in results and not args.force:
                        print(f"[dryrun] skip cached {key}", flush=True)
                        continue
                    try:
                        rec = run_combo(
                            arch, shape, mesh_kind,
                            remat=not args.no_remat, probe_layers=depth,
                            variant=args.variant,
                        )
                        results[key] = rec
                        with open(path, "w") as f:
                            json.dump(results, f, indent=1)
                    except Exception as e:  # noqa: BLE001 - continue sweep
                        failures.append((key, repr(e)[:500]))
                        print(f"[dryrun] FAIL {key}: {e!r}", flush=True)
    print(f"[dryrun] done: {len(results)} cached, {len(failures)} failures")
    for k, e in failures:
        print("  FAIL", k, e)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
