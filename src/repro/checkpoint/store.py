"""Checkpointing: pytree -> (msgpack manifest + one .npy per leaf).

No orbax offline; this covers the launcher's needs: atomic step
directories (fsynced tmp dir + rename), structure round-trip via treedef
serialization, dtype/shape/CRC validation on restore with a dedicated
:class:`CheckpointCorruptError` for truncated or bit-rotted files, and
`keep` garbage collection.  Fault-injected training leans on this store:
a crash/rejoin run's state (and auxiliary fault carry) must restore
exactly, so every leaf carries a crc32 checksum in the manifest.

Restoring without an explicit ``step`` walks a *fallback chain*: the
newest step is tried first and, if it turns out corrupt (truncated leaf,
crc mismatch, mangled manifest), the next-older intact checkpoint is
restored instead — a crash mid-rot never strands a chaos run on garbage
when an older good step survives.  Only when every step is corrupt does
the newest step's error propagate.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import zlib
from typing import List, Optional

import jax
import numpy as np

__all__ = [
    "save_checkpoint", "restore_checkpoint", "latest_step", "list_steps",
    "CheckpointCorruptError",
]


class CheckpointCorruptError(RuntimeError):
    """A checkpoint exists but cannot be trusted: missing manifest or
    leaf file, truncated array, or a crc32 mismatch.  Distinct from
    FileNotFoundError (no checkpoint at all) so callers can fall back to
    an older step instead of silently training from garbage."""


def _leaf_paths(tree):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in paths_and_leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name.replace("/", "__") or "leaf", leaf))
    return out


def _crc32_of(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    """Write one atomic step directory: every leaf lands in a tmp dir
    first (each file flushed + fsynced), then a single rename publishes
    the checkpoint — a crash mid-save leaves only a ``.tmp`` directory
    that the next save overwrites, never a half-visible ``step_*``."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(leaf)
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in true_dtype or "float8" in true_dtype:
            # numpy can't persist ml_dtypes natively; store widened (lossless)
            arr = arr.astype(np.float32)
        fname = f"{i:05d}_{name[:80]}.npy"
        fpath = os.path.join(tmp_dir, fname)
        with open(fpath, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"file": fname, "dtype": true_dtype, "shape": list(arr.shape),
             "crc32": _crc32_of(arr)}
        )
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _gc(directory, keep)
    return step_dir


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def list_steps(directory: str) -> List[int]:
    """All published checkpoint steps under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of `tree_like`.

    Validates leaf count, shapes and per-leaf crc32 checksums; a missing
    or unreadable leaf file, a short read, or a checksum mismatch raises
    :class:`CheckpointCorruptError` naming the offending file.  Manifests
    written before checksumming (no ``crc32`` key) still restore — the
    check is simply skipped for those leaves.

    With ``step=None`` the steps are tried newest-first and the first
    *intact* one wins (corrupt steps are skipped with a stderr note);
    the newest step's error propagates only when every step is corrupt,
    so single-checkpoint callers see the same exception they always did.
    An explicit ``step`` never falls back.
    """
    if step is None:
        steps = list_steps(directory)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        newest_err: Optional[CheckpointCorruptError] = None
        for s in reversed(steps):
            try:
                return restore_checkpoint(directory, tree_like, s)
            except CheckpointCorruptError as e:
                if newest_err is None:
                    newest_err = e
                print(
                    f"[checkpoint] step {s} is corrupt ({e}); falling "
                    "back to the next-older checkpoint",
                    file=sys.stderr, flush=True,
                )
        raise newest_err
    step_dir = os.path.join(directory, f"step_{step:09d}")
    manifest_path = os.path.join(step_dir, "manifest.json")
    if not os.path.isdir(step_dir):
        raise FileNotFoundError(f"no checkpoint for step {step} under {directory}")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"{step_dir}: manifest.json is missing"
        ) from e
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"{manifest_path}: manifest is not valid JSON ({e})"
        ) from e
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected {len(leaves)}"
        )
    out = []
    for leaf, meta in zip(leaves, manifest["leaves"]):
        fpath = os.path.join(step_dir, meta["file"])
        try:
            arr = np.load(fpath)
        except FileNotFoundError as e:
            raise CheckpointCorruptError(
                f"{step_dir}: leaf file {meta['file']} is missing"
            ) from e
        except ValueError as e:
            # numpy raises ValueError on truncated/garbled .npy payloads
            raise CheckpointCorruptError(
                f"{fpath}: unreadable or truncated array ({e})"
            ) from e
        if "crc32" in meta and _crc32_of(arr) != meta["crc32"]:
            raise CheckpointCorruptError(
                f"{fpath}: crc32 mismatch — checkpoint is corrupt"
            )
        want = np.asarray(leaf)
        if list(arr.shape) != list(want.shape):
            raise ValueError(f"shape mismatch for {meta['file']}: {arr.shape} vs {want.shape}")
        if arr.dtype != want.dtype:
            # widened ml_dtypes round-trip (bf16 -> f32 -> bf16 is exact)
            arr = np.asarray(jax.numpy.asarray(arr).astype(want.dtype))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
