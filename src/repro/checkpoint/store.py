"""Checkpointing: pytree -> (msgpack manifest + one .npy per leaf).

No orbax offline; this covers the launcher's needs: atomic-ish step
directories, structure round-trip via treedef serialization, dtype/shape
validation on restore, and `keep` garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _leaf_paths(tree):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in paths_and_leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name.replace("/", "__") or "leaf", leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(leaf)
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in true_dtype or "float8" in true_dtype:
            # numpy can't persist ml_dtypes natively; store widened (lossless)
            arr = arr.astype(np.float32)
        fname = f"{i:05d}_{name[:80]}.npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["leaves"].append(
            {"file": fname, "dtype": true_dtype, "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _gc(directory, keep)
    return step_dir


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of `tree_like` (validates shapes/dtypes)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected {len(leaves)}"
        )
    out = []
    for leaf, meta in zip(leaves, manifest["leaves"]):
        arr = np.load(os.path.join(step_dir, meta["file"]))
        want = np.asarray(leaf)
        if list(arr.shape) != list(want.shape):
            raise ValueError(f"shape mismatch for {meta['file']}: {arr.shape} vs {want.shape}")
        if arr.dtype != want.dtype:
            # widened ml_dtypes round-trip (bf16 -> f32 -> bf16 is exact)
            arr = np.asarray(jax.numpy.asarray(arr).astype(want.dtype))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
