from repro.checkpoint.store import save_checkpoint, restore_checkpoint  # noqa: F401
