"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object spans all six arch families.

    Unused family fields stay at their zero defaults.  `arch_type` selects
    the block pattern in `models.model`:
      dense  — [attn, mlp] * n_layers
      moe    — [attn, mlp] * first_dense_layers + [attn, moe] * rest
      ssm    — [mamba] * n_layers
      hybrid — mamba backbone with one *shared* transformer block applied
               every `attn_every` layers (Zamba2)
      vlm    — dense backbone consuming projected patch embeddings + tokens
      audio  — dense backbone over codec tokens (EnCodec vocab)
    """

    name: str
    arch_type: str
    n_layers: int
    d_model: int
    vocab: int

    # --- attention (GQA) ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding-window size; None = full causal

    # --- dense mlp ---
    d_ff: int = 0

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0          # 0 => no query low-rank path
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    d_conv: int = 4
    ssm_split_proj: bool = False  # separate z/x/B/C/dt projections (and
    # per-stream convs) so each output dim shards head-aligned over `model`
    # instead of slicing one fused (misaligned) in_proj — see §Perf E4

    # --- hybrid ---
    attn_every: int = 0

    # --- vlm stub frontend ---
    n_patches: int = 0
    vision_dim: int = 0

    # --- numerics / execution ---
    dtype: str = "float32"          # params & activations
    remat: bool = False             # checkpoint each block in train mode
    remat_policy: str = "full"      # "full" | "dots" (save matmul outputs —
                                    # backward skips recomputing them)
    prefill_chunk: int = 0          # >0: chunk prefill queries (memory cap)
    unroll: bool = False            # python-loop layers instead of lax.scan
                                    # (exact HLO cost analysis; probes only)
    use_flash: bool = False         # route attention through Pallas kernel
    use_ssd_kernel: bool = False    # route SSD intra-chunk through Pallas
    tie_embeddings: bool = True

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def conv_dim(self) -> int:
        # channels passed through the causal depthwise conv: x, B, C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def qk_nope_dim(self) -> int:
        return self.head_dim  # MLA: per-head non-rope dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count N (used for 6·N·D model flops)."""
        d = self.d_model
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        total += d  # final norm
        if self.arch_type in ("vlm",):
            total += self.vision_dim * d
        attn = 0
        if self.use_mla:
            q_in = self.q_lora if self.q_lora else d
            attn += (d * self.q_lora) if self.q_lora else 0
            attn += q_in * self.n_heads * (self.head_dim + self.rope_head_dim)
            attn += d * (self.kv_lora + self.rope_head_dim)
            attn += self.kv_lora * self.n_heads * (self.head_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        elif self.n_heads:
            attn += d * self.n_heads * self.head_dim
            attn += 2 * d * self.n_kv_heads * self.head_dim
            attn += self.n_heads * self.head_dim * d
        mlp_dense = 3 * d * self.d_ff
        moe = 0
        if self.n_experts:
            moe = (
                d * self.n_experts
                + self.n_experts * 3 * d * self.d_ff_expert
                + self.n_shared_experts * 3 * d * self.d_ff_expert
            )
        mamba = 0
        if self.ssm_state:
            di, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            mamba = (
                d * (2 * di + 2 * g * n + h)  # in_proj
                + self.d_conv * self.conv_dim  # conv
                + 3 * h  # A_log, D, dt_bias
                + di  # gated norm
                + di * d  # out_proj
            )
        if self.arch_type == "dense" or self.arch_type in ("vlm", "audio"):
            total += self.n_layers * (attn + mlp_dense + 4 * d)
        elif self.arch_type == "moe":
            total += self.first_dense_layers * (attn + mlp_dense + 4 * d)
            total += (self.n_layers - self.first_dense_layers) * (attn + moe + 4 * d)
        elif self.arch_type == "ssm":
            total += self.n_layers * (mamba + 2 * d)
        elif self.arch_type == "hybrid":
            total += self.n_layers * (mamba + 2 * d)
            total += attn + mlp_dense + 4 * d  # one shared block
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full_moe_layer = (
            self.n_experts * 3 * d * self.d_ff_expert
        )
        active_moe_layer = self.moe_top_k * 3 * d * self.d_ff_expert
        n_moe_layers = self.n_layers - self.first_dense_layers
        return self.param_count() - n_moe_layers * (full_moe_layer - active_moe_layer)
