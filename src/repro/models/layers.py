"""Shared primitives: norms, rope, initializers, projections."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "dense_init",
    "embed_init",
    "rope_freqs",
    "apply_rope",
    "linear",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, fan_in: int = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = fan ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, w)


def rope_freqs(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] int -> (cos, sin) of shape [..., dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., dim/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., dim]; cos/sin broadcastable to [..., dim/2] (interleaved pairs)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    # broadcast cos/sin over the head axis if present
    while cos.ndim < x1.ndim:
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
