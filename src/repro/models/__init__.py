"""Model substrate: composable decoder stacks in pure JAX.

Families: dense GQA (opt. qk-norm / sliding window), MLA (DeepSeek-V2),
MoE (shared + routed top-k), Mamba2 SSD, hybrid (Mamba2 + shared attention),
VLM / audio backbones (frontends stubbed per spec).
"""
from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params,
    train_loss,
    prefill,
    decode_step,
    init_cache,
)
