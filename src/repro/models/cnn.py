"""The paper's own vision models: the small CNN (Example 3) and
ResNet-20 (Example 4), in pure JAX.

Hardware-adaptation note (DESIGN.md §5): BatchNorm is replaced by
GroupNorm.  BN's running statistics are known to break under non-IID
federated data (each node's batch statistics diverge), and GN is the
standard FL substitute; it also keeps the model purely functional.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["cnn_init", "cnn_apply", "resnet20_init", "resnet20_apply", "ce_loss"]


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), dtype) * (2.0 / fan) ** 0.5


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _group_norm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


# ---------------------------------------------------------------------------
# Example 3 CNN: conv32-pool-conv64-pool-fc
# ---------------------------------------------------------------------------
def cnn_init(
    key: jax.Array, in_ch: int = 1, n_classes: int = 10, width: int = 1
) -> dict:
    """`width` multiplies every channel/feature count (width=1 is the
    paper's Example 3; width=2 crosses 1M parameters for the real-workload
    communication benchmarks).  `cnn_apply` reads all shapes from the
    params, so no apply-side change is needed."""
    ks = jax.random.split(key, 4)
    c1, c2, hid = 32 * width, 64 * width, 128 * width
    return {
        "c1": _conv_init(ks[0], 3, 3, in_ch, c1),
        "c2": _conv_init(ks[1], 3, 3, c1, c2),
        "fc1": jax.random.normal(ks[2], (7 * 7 * c2, hid)) * (7 * 7 * c2) ** -0.5,
        "b1": jnp.zeros((hid,)),
        "fc2": jax.random.normal(ks[3], (hid, n_classes)) * hid ** -0.5,
        "b2": jnp.zeros((n_classes,)),
    }


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME"
    )


def cnn_apply(params: dict, images: jax.Array) -> jax.Array:
    x = jax.nn.relu(_conv(images, params["c1"]))
    x = _pool(x)
    x = jax.nn.relu(_conv(x, params["c2"]))
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    return x @ params["fc2"] + params["b2"]


# ---------------------------------------------------------------------------
# Example 4 ResNet-20 (CIFAR variant; widths 16/32/64, GN instead of BN)
# ---------------------------------------------------------------------------
def resnet20_init(key: jax.Array, in_ch: int = 3, n_classes: int = 10) -> dict:
    keys = iter(jax.random.split(key, 64))
    params = {"stem": _conv_init(next(keys), 3, 3, in_ch, 16),
              "stem_s": jnp.ones((16,)), "stem_b": jnp.zeros((16,))}
    widths = [16, 32, 64]
    blocks = []
    cin = 16
    for si, w in enumerate(widths):
        for bi in range(3):
            stride = _block_stride(si, bi)
            blk = {
                "c1": _conv_init(next(keys), 3, 3, cin, w),
                "s1": jnp.ones((w,)), "b1": jnp.zeros((w,)),
                "c2": _conv_init(next(keys), 3, 3, w, w),
                "s2": jnp.ones((w,)), "b2": jnp.zeros((w,)),
            }
            if stride != 1 or cin != w:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, w)
            blocks.append(blk)
            cin = w
    params["blocks"] = blocks
    params["fc"] = jax.random.normal(next(keys), (64, n_classes)) * 64 ** -0.5
    params["fc_b"] = jnp.zeros((n_classes,))
    return params


def _block_stride(stage: int, block: int) -> int:
    return 2 if (stage > 0 and block == 0) else 1


def resnet20_apply(params: dict, images: jax.Array) -> jax.Array:
    x = jax.nn.relu(_group_norm(_conv(images, params["stem"]), params["stem_s"], params["stem_b"]))
    for idx, blk in enumerate(params["blocks"]):
        stride = _block_stride(idx // 3, idx % 3)
        h = jax.nn.relu(_group_norm(_conv(x, blk["c1"], stride), blk["s1"], blk["b1"]))
        h = _group_norm(_conv(h, blk["c2"]), blk["s2"], blk["b2"])
        sc = _conv(x, blk["proj"], stride) if "proj" in blk else x
        x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["fc"] + params["fc_b"]


def ce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
