"""Mamba2 block — state-space duality (SSD), arXiv:2405.21060.

Full-sequence path uses the chunked SSD algorithm:
  intra-chunk:  quadratic attention-like form with decay mask
                L[i,j] = exp(cumA_i - cumA_j) (causal within a chunk);
  inter-chunk:  per-chunk states combined by an associative scan over the
                chunk axis (h_k = decay_k * h_{k-1} + s_k).

Decode path is the O(1) recurrence  h <- h*exp(dtA) + dt * B (x) outer,
y = C.h + D*x.  The intra-chunk contraction is the compute hot spot and has
a Pallas kernel (`repro.kernels.ssd_scan`) selected by cfg.use_ssd_kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear, rms_norm

__all__ = ["SSMCache", "mamba_init", "mamba_apply", "mamba_decode", "init_ssm_cache"]


class SSMCache(NamedTuple):
    conv: jax.Array   # [B, d_conv-1, conv_dim] — rolling pre-conv inputs
    state: jax.Array  # [B, H, P, N] — SSD recurrent state


def mamba_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, di, g, n, h = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_groups,
        cfg.ssm_state,
        cfg.ssm_heads,
    )
    ks = jax.random.split(key, 8)
    common = {
        "A_log": jnp.zeros((h,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ~= 0.12
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }
    if cfg.ssm_split_proj:
        # per-stream projections: z/x shard head-aligned over `model`,
        # B/C/dt stay small; per-stream convs keep channel shards intact
        return {
            **common,
            "in_z": dense_init(ks[0], (d, di), dtype),
            "in_x": dense_init(ks[3], (d, di), dtype),
            "in_B": dense_init(ks[4], (d, g * n), dtype),
            "in_C": dense_init(ks[5], (d, g * n), dtype),
            "in_dt": dense_init(ks[6], (d, h), dtype),
            "conv_x_w": dense_init(ks[1], (cfg.d_conv, di), dtype, fan_in=cfg.d_conv),
            "conv_x_b": jnp.zeros((di,), dtype),
            "conv_B_w": dense_init(ks[7], (cfg.d_conv, g * n), dtype, fan_in=cfg.d_conv),
            "conv_B_b": jnp.zeros((g * n,), dtype),
            "conv_C_w": dense_init(
                jax.random.fold_in(ks[7], 1), (cfg.d_conv, g * n), dtype,
                fan_in=cfg.d_conv,
            ),
            "conv_C_b": jnp.zeros((g * n,), dtype),
        }
    proj_out = 2 * di + 2 * g * n + h
    return {
        **common,
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.d_conv, cfg.conv_dim), dtype, fan_in=cfg.d_conv),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + cfg.conv_dim]
    dt = proj[..., di + cfg.conv_dim :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(cfg: ModelConfig, xbc: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv over time; xbc [B,S,C]."""
    k = cfg.d_conv
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None])


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    x = xbc[..., :di]
    b_ = xbc[..., di : di + g * n]
    c_ = xbc[..., di + g * n :]
    shp = xbc.shape[:-1]
    return (
        x.reshape(shp + (cfg.ssm_heads, cfg.ssm_head_dim)),
        b_.reshape(shp + (g, n)),
        c_.reshape(shp + (g, n)),
    )


def _ssd_chunked(
    cfg: ModelConfig,
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H] (post-softplus)
    a: jax.Array,    # [H] negative
    b_: jax.Array,   # [B, S, G, N]
    c_: jax.Array,   # [B, S, G, N]
    h0: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    l = min(cfg.ssm_chunk, s)
    pad = (-s) % l
    if pad:
        zf = lambda u: jnp.pad(u, ((0, 0), (0, pad)) + ((0, 0),) * (u.ndim - 2))
        x, dt, b_, c_ = zf(x), zf(dt), zf(b_), zf(c_)
    sp = s + pad
    nc = sp // l
    xc = x.reshape(bsz, nc, l, h, p)
    dtc = dt.reshape(bsz, nc, l, h)
    bc = b_.reshape(bsz, nc, l, g, n)
    cc = c_.reshape(bsz, nc, l, g, n)

    rep = h // g  # heads per group
    da = dtc * a[None, None, None]                        # [B,Nc,L,H]
    cum = jnp.cumsum(da, axis=2)                          # within-chunk
    if cfg.use_ssd_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops

        y_intra, chunk_state = ssd_ops.ssd_intra_chunk(xc, dtc, cum, bc, cc, rep)
    else:
        # decay mask L[i,j] = exp(cum_i - cum_j), i >= j
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,Nc,L(i),L(j),H]
        causal = jnp.tril(jnp.ones((l, l), bool))
        lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
        bh = jnp.repeat(bc, rep, axis=3)                      # [B,Nc,L,H,N]
        ch = jnp.repeat(cc, rep, axis=3)
        scores = jnp.einsum("bnlhs,bnmhs->bnlmh", ch, bh)     # C_i . B_j
        w = scores * lmat * dtc[:, :, None, :, :]             # * dt_j
        y_intra = jnp.einsum("bnlmh,bnmhp->bnlhp", w.astype(xc.dtype), xc)
        # chunk state: sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [B,Nc,L,H]
        wstate = (decay_to_end * dtc)[..., None] * bh          # [B,Nc,L,H,N]
        chunk_state = jnp.einsum(
            "bnlhs,bnlhp->bnhps", wstate.astype(xc.dtype), xc
        )                                                      # [B,Nc,H,P,N]

    # inter-chunk recurrence over Nc: h_k = exp(sum chunk dA)_k h_{k-1} + s_k
    # (recurrent state kept in f32 regardless of activation dtype)
    chunk_state = chunk_state.astype(jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # [B,Nc,H] f32

    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, s1 * d2[..., None, None] + s2

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), chunk_state.dtype)
    # prepend h0 as a pseudo-chunk with decay 1
    decays = jnp.concatenate(
        [jnp.ones((bsz, 1, h), chunk_decay.dtype), chunk_decay], axis=1
    )
    states = jnp.concatenate([h0[:, None], chunk_state], axis=1)
    _, run = jax.lax.associative_scan(combine, (decays, states), axis=1)
    prev_states = run[:, :-1]                                  # state BEFORE chunk k
    final_state = run[:, -1]

    # inter-chunk contribution: y_i += C_i . (exp(cum_i) * h_prev)
    ch = jnp.repeat(cc, rep, axis=3)
    inner = jnp.einsum("bnlhs,bnhps->bnlhp", ch.astype(prev_states.dtype), prev_states)
    y_inter = inner * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter.astype(y_intra.dtype)).reshape(bsz, sp, h, p)
    if pad:
        y = y[:, :s]
    return y, final_state


def _project(params: dict, cfg: ModelConfig, x: jax.Array):
    """Returns (z, xs [B,S,H,P], b_ [B,S,G,N], c_, dt_raw, xbc_preconv)."""
    bsz, s, _ = x.shape
    g, n = cfg.ssm_groups, cfg.ssm_state
    if cfg.ssm_split_proj:
        z = linear(x, params["in_z"])
        xs_raw = linear(x, params["in_x"])
        b_raw = linear(x, params["in_B"])
        c_raw = linear(x, params["in_C"])
        dt_raw = linear(x, params["in_dt"])
        xs_c = _causal_conv(cfg, xs_raw, params["conv_x_w"], params["conv_x_b"])
        b_c = _causal_conv(cfg, b_raw, params["conv_B_w"], params["conv_B_b"])
        c_c = _causal_conv(cfg, c_raw, params["conv_C_w"], params["conv_C_b"])
        xs = xs_c.reshape(bsz, s, cfg.ssm_heads, cfg.ssm_head_dim)
        b_ = b_c.reshape(bsz, s, g, n)
        c_ = c_c.reshape(bsz, s, g, n)
        xbc = jnp.concatenate([xs_raw, b_raw, c_raw], axis=-1)  # cache layout
        return z, xs, b_, c_, dt_raw, xbc
    proj = linear(x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc_conv = _causal_conv(cfg, xbc, params["conv_w"], params["conv_b"])
    xs, b_, c_ = _split_xbc(cfg, xbc_conv)
    return z, xs, b_, c_, dt_raw, xbc


def mamba_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, d]
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[SSMCache]]:
    bsz, s, _ = x.shape
    z, xs, b_, c_, dt_raw, xbc = _project(params, cfg, x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])
    y, final_state = _ssd_chunked(cfg, xs, dt, a, b_, c_)
    y = y + xs * params["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = linear(y, params["out_proj"])
    cache = None
    if return_cache:
        tail = cfg.d_conv - 1
        conv_tail = jnp.pad(xbc, ((0, 0), (tail, 0), (0, 0)))[:, -tail:]
        cache = SSMCache(conv=conv_tail, state=final_state)
    return out, cache


def mamba_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, 1, d]
    cache: SSMCache,
) -> Tuple[jax.Array, SSMCache]:
    bsz = x.shape[0]
    if cfg.ssm_split_proj:
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        z = linear(x, params["in_z"])
        xbc = jnp.concatenate(
            [linear(x, params["in_x"]), linear(x, params["in_B"]),
             linear(x, params["in_C"])], axis=-1,
        )
        dt_raw = linear(x, params["in_dt"])
        window = jnp.concatenate([cache.conv, xbc], axis=1)  # [B, d_conv, C]
        outs = []
        for lo, hi, w_key, b_key in (
            (0, di, "conv_x_w", "conv_x_b"),
            (di, di + g * n, "conv_B_w", "conv_B_b"),
            (di + g * n, di + 2 * g * n, "conv_C_w", "conv_C_b"),
        ):
            seg = window[:, :, lo:hi]
            outs.append(
                jnp.einsum("bkc,kc->bc", seg, params[w_key]) + params[b_key]
            )
        conv_out = jax.nn.silu(jnp.concatenate(outs, axis=-1))[:, None]
    else:
        proj = linear(x, params["in_proj"])
        z, xbc, dt_raw = _split_proj(cfg, proj)
        # rolling conv state
        window = jnp.concatenate([cache.conv, xbc], axis=1)  # [B, d_conv, C]
        conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None]            # [B,1,C]
    xs, b_, c_ = _split_xbc(cfg, conv_out)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[:, 0] * a[None])                     # [B,H]
    rep = cfg.ssm_heads // cfg.ssm_groups
    bh = jnp.repeat(b_[:, 0], rep, axis=1)               # [B,H,N]
    chh = jnp.repeat(c_[:, 0], rep, axis=1)
    contrib = (dt[:, 0][..., None, None] * xs[:, 0][..., None]) * bh[:, :, None, :]
    new_state = cache.state * da[..., None, None] + contrib.astype(cache.state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, chh.astype(new_state.dtype))
    y = y.astype(xs.dtype) + xs[:, 0] * params["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = linear(y, params["out_proj"])
    return out, SSMCache(conv=window[:, 1:], state=new_state)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    )
