"""Mixture-of-Experts block (DeepSeek-V2 style: shared + routed top-k).

Dispatch is capacity-based scatter/gather — TPU-native dense buffers,
no ragged shapes:

  1. router softmax over E experts, top-k per token;
  2. token t's j-th choice goes to slot `cumsum(one_hot)` within its expert
     buffer; overflow beyond capacity C is dropped (weights renormalised);
  3. scatter tokens into [E, C, d], run the expert FFN as a batched einsum
     (experts shard over the `model` mesh axis => expert parallelism; the
     scatter/gather lower to all-to-all style collectives under GSPMD);
  4. gather back and combine with routing weights; shared experts run
     densely on every token.

Aux losses: switch-style load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear

__all__ = ["moe_init", "moe_apply"]


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, e, ffe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e), dtype, fan_in=d),
        "w_gate": dense_init(ks[1], (e, d, ffe), dtype, fan_in=d),
        "w_up": dense_init(ks[2], (e, d, ffe), dtype, fan_in=d),
        "w_down": dense_init(ks[3], (e, ffe, d), dtype, fan_in=ffe),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ffe
        sk = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": dense_init(sk[0], (d, sff), dtype),
            "w_up": dense_init(sk[1], (d, sff), dtype),
            "w_down": dense_init(sk[2], (sff, d), dtype),
        }
    return params


def moe_apply(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    xf = x.reshape(t, d)

    logits = linear(xf, params["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(t * k * cfg.capacity_factor / e))

    # slot of each (token, choice) within its expert buffer
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.int32)        # [T, K, E]
    flat_oh = onehot.reshape(t * k, e)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) - flat_oh      # [T*K, E]
    slot = jnp.sum(pos_in_expert * flat_oh, axis=-1)           # [T*K]
    expert_of = top_i.reshape(t * k)
    keep = slot < capacity
    dest = expert_of * capacity + jnp.minimum(slot, capacity - 1)

    tok_of = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * capacity, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[tok_of], 0.0)
    buf = buf.at[dest].add(contrib)
    buf = buf.reshape(e, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * capacity, d)

    gathered = out_buf[dest]                                  # [T*K, d]
    weight = jnp.where(keep, top_p.reshape(t * k), 0.0)
    y = jnp.zeros((t, d), xf.dtype).at[tok_of].add(
        gathered * weight[:, None].astype(xf.dtype)
    )

    if cfg.n_shared_experts:
        sp = params["shared"]
        y = y + linear(
            jax.nn.silu(linear(xf, sp["w_gate"])) * linear(xf, sp["w_up"]),
            sp["w_down"],
        )

    # ---- aux losses (computed in f32) ----
    me = probs.mean(axis=0)                                   # mean router prob
    ce = (onehot.sum(axis=1) > 0).astype(jnp.float32).mean(axis=0)  # routed frac
    lb_loss = e * jnp.sum(me * ce) * cfg.router_aux_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_coef
    return y.reshape(b, s, d), lb_loss + z_loss
