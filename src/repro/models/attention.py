"""Attention blocks: GQA (opt. qk-norm, sliding window) and MLA.

Three execution modes share one code path each:
  * full-sequence (train / prefill)  — causal (optionally windowed) mask;
  * single-token decode              — ring-buffer KV cache of capacity C
                                       (C = seq_len for full attention,
                                        C = window for sliding window).

The cache stores an explicit `positions [C]` array (−1 = empty), so ring
wraparound and window masking fall out of one predicate instead of index
gymnastics.  MLA decodes in the *absorbed* form: the cache holds only the
compressed c_kv / k_rope streams and the per-head expansions are folded
into the query/output projections (DeepSeek-V2 Sec. 2.1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, linear, rms_norm, rope_freqs

__all__ = [
    "KVCache",
    "MLACache",
    "gqa_init",
    "gqa_apply",
    "gqa_decode",
    "mla_init",
    "mla_apply",
    "mla_decode",
    "init_kv_cache",
    "init_mla_cache",
]

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # [B, C, KV, hd]
    v: jax.Array          # [B, C, KV, hd]
    positions: jax.Array  # [C] int32, -1 = empty


class MLACache(NamedTuple):
    c_kv: jax.Array       # [B, C, kv_lora]
    k_rope: jax.Array     # [B, C, rope_hd]
    positions: jax.Array  # [C] int32


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, params["wq"]).reshape(b, s, h, hd)
    k = linear(x, params["wk"]).reshape(b, s, kv, hd)
    v = linear(x, params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)  # [s, hd/2]
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    return q, k, v


def _grouped_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, KV, hd]
    v: jax.Array,  # [B, T, KV, hd]
    mask: jax.Array,  # [S, T] or [B, S, T] bool (True = attend)
    scale: float,
) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask_b = mask[None, None, None]
    else:
        mask_b = mask[:, None, None]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)


def _causal_mask(s: int, window: Optional[int]) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if window is not None:
        mask &= (i - j) < window
    return mask


def _chunked_grouped_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, KV, hd]
    v: jax.Array,
    window: Optional[int],
    scale: float,
    chunk: int,
) -> jax.Array:
    """Query-chunked causal attention: peak score buffer is [.., chunk, S]
    instead of [.., S, S] (prefill memory cap; keys stay resident)."""
    b, s, h, hd = q.shape
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    j = jnp.arange(s)

    def one(args):
        qi, ci = args
        rows = ci * chunk + jnp.arange(chunk)
        mask = j[None, :] <= rows[:, None]
        if window is not None:
            mask &= (rows[:, None] - j[None, :]) < window
        return _grouped_attention(qi, k, v, mask, scale)

    out = jax.lax.map(one, (qc, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def gqa_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,  # [S]
    return_cache: bool = False,
    cache_capacity: Optional[int] = None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions)
    if cfg.use_flash and mask_is_plain(cfg, s):
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, window=cfg.window)
    elif cfg.prefill_chunk and s > cfg.prefill_chunk and s % cfg.prefill_chunk == 0:
        out = _chunked_grouped_attention(
            q, k, v, cfg.window, cfg.head_dim ** -0.5, cfg.prefill_chunk
        )
    else:
        mask = _causal_mask(s, cfg.window)
        out = _grouped_attention(q, k, v, mask, cfg.head_dim ** -0.5)
    y = linear(out.reshape(b, s, -1), params["wo"])
    cache = None
    if return_cache:
        cap = cache_capacity or s
        take = min(s, cap)
        pos_arr = jnp.full((cap,), -1, jnp.int32)
        cache = KVCache(
            k=jnp.zeros((b, cap) + k.shape[2:], k.dtype).at[:, :take].set(k[:, -take:]),
            v=jnp.zeros((b, cap) + v.shape[2:], v.dtype).at[:, :take].set(v[:, -take:]),
            positions=pos_arr.at[:take].set(positions[-take:].astype(jnp.int32)),
        )
    return y, cache


def mask_is_plain(cfg: ModelConfig, s: int) -> bool:
    return True  # flash kernel handles causal + window masks itself


def gqa_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,      # [B, 1, d]
    pos: jax.Array,    # scalar int32 — position of the new token
    cache: KVCache,
) -> Tuple[jax.Array, KVCache]:
    b = x.shape[0]
    cap = cache.k.shape[1]
    q, k, v = _qkv(params, cfg, x, pos[None])
    slot = (pos % cap).astype(jnp.int32)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_positions = cache.positions.at[slot].set(pos.astype(jnp.int32))
    valid = (new_positions >= 0) & (new_positions <= pos)
    if cfg.window is not None:
        valid &= (pos - new_positions) < cfg.window
    out = _grouped_attention(
        q, new_k, new_v, valid[None, None, :].repeat(b, 0), cfg.head_dim ** -0.5
    )
    y = linear(out.reshape(b, 1, -1), params["wo"])
    return y, KVCache(new_k, new_v, new_positions)


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> KVCache:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, capacity, kv, hd), dtype),
        v=jnp.zeros((batch, capacity, kv, hd), dtype),
        positions=jnp.full((capacity,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_hd, v_hd = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    q_in = cfg.q_lora if cfg.q_lora else d
    p = {
        "w_uq": dense_init(ks[1], (q_in, h * (nope + rope_hd)), dtype),
        "w_dkv": dense_init(ks[2], (d, cfg.kv_lora + rope_hd), dtype),
        "kv_norm": jnp.ones((cfg.kv_lora,), dtype),
        "w_uk": dense_init(ks[3], (cfg.kv_lora, h * nope), dtype),
        "w_uv": dense_init(ks[4], (cfg.kv_lora, h * v_hd), dtype),
        "wo": dense_init(ks[5], (h * v_hd, d), dtype),
    }
    if cfg.q_lora:
        p["w_dq"] = dense_init(ks[0], (d, cfg.q_lora), dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora,), dtype)
    return p


def _mla_q(params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    h, nope, rope_hd = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    if cfg.q_lora:
        cq = rms_norm(linear(x, params["w_dq"]), params["q_norm"])
    else:
        cq = x
    q = linear(cq, params["w_uq"]).reshape(b, s, h, nope + rope_hd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_freqs(positions, rope_hd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None], sin[None])
    return q_nope, q_rope


def _mla_ckv(params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    ckv_full = linear(x, params["w_dkv"])
    c_kv = rms_norm(ckv_full[..., : cfg.kv_lora], params["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora :]
    cos, sin = rope_freqs(positions, cfg.rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos[None], sin[None])
    return c_kv, k_rope


def mla_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    return_cache: bool = False,
    cache_capacity: Optional[int] = None,
) -> Tuple[jax.Array, Optional[MLACache]]:
    """Full-sequence MLA with per-head expansion (train / prefill)."""
    b, s, _ = x.shape
    h, nope, v_hd = cfg.n_heads, cfg.head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    k_nope = linear(c_kv, params["w_uk"]).reshape(b, s, h, nope)
    v = linear(c_kv, params["w_uv"]).reshape(b, s, h, v_hd)
    scale = (nope + cfg.rope_head_dim) ** -0.5

    def _attend(qn, qr, rows):  # qn [B,C,H,nope], rows [C]
        sc = (
            jnp.einsum("bshn,bthn->bhst", qn, k_nope)
            + jnp.einsum("bshr,btr->bhst", qr, k_rope)
        ).astype(jnp.float32) * scale
        j = jnp.arange(s)
        mask = j[None, :] <= rows[:, None]
        if cfg.window is not None:
            mask &= (rows[:, None] - j[None, :]) < cfg.window
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthv->bshv", probs, v)

    chunk = cfg.prefill_chunk
    if chunk and s > chunk and s % chunk == 0:
        nc = s // chunk
        qn_c = q_nope.reshape(b, nc, chunk, h, nope).transpose(1, 0, 2, 3, 4)
        qr_c = q_rope.reshape(b, nc, chunk, h, cfg.rope_head_dim).transpose(1, 0, 2, 3, 4)

        def one(args):
            qn, qr, ci = args
            return _attend(qn, qr, ci * chunk + jnp.arange(chunk))

        out = jax.lax.map(one, (qn_c, qr_c, jnp.arange(nc)))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, -1)
    else:
        out = _attend(q_nope, q_rope, jnp.arange(s)).reshape(b, s, -1)
    y = linear(out, params["wo"])
    cache = None
    if return_cache:
        cap = cache_capacity or s
        take = min(s, cap)
        pos_arr = jnp.full((cap,), -1, jnp.int32)
        cache = MLACache(
            c_kv=jnp.zeros((b, cap, cfg.kv_lora), c_kv.dtype)
            .at[:, :take]
            .set(c_kv[:, -take:]),
            k_rope=jnp.zeros((b, cap, cfg.rope_head_dim), k_rope.dtype)
            .at[:, :take]
            .set(k_rope[:, -take:]),
            positions=pos_arr.at[:take].set(positions[-take:].astype(jnp.int32)),
        )
    return y, cache


def mla_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,    # [B, 1, d]
    pos: jax.Array,  # scalar
    cache: MLACache,
) -> Tuple[jax.Array, MLACache]:
    """Absorbed-form decode: scores against the compressed cache."""
    b = x.shape[0]
    h, nope, v_hd = cfg.n_heads, cfg.head_dim, cfg.v_head_dim
    cap = cache.c_kv.shape[1]
    q_nope, q_rope = _mla_q(params, cfg, x, pos[None])  # [B,1,H,*]
    c_new, kr_new = _mla_ckv(params, cfg, x, pos[None])
    slot = (pos % cap).astype(jnp.int32)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, slot, axis=1)
    positions = cache.positions.at[slot].set(pos.astype(jnp.int32))
    valid = (positions >= 0) & (positions <= pos)
    if cfg.window is not None:
        valid &= (pos - positions) < cfg.window
    # absorb W_uk into the query:  q_eff[b,h,c] = q_nope . W_uk[:, h, :]
    w_uk = params["w_uk"].reshape(cfg.kv_lora, h, nope)
    q_eff = jnp.einsum("bshn,chn->bshc", q_nope, w_uk)[:, 0]  # [B,H,kv_lora]
    scale = (nope + cfg.rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bhc,btc->bht", q_eff, c_kv)
        + jnp.einsum("bshr,btr->bht", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bht,btc->bhc", probs, c_kv)  # compressed context
    w_uv = params["w_uv"].reshape(cfg.kv_lora, h, v_hd)
    out = jnp.einsum("bhc,chv->bhv", ctx, w_uv).reshape(b, 1, h * v_hd)
    y = linear(out, params["wo"])
    return y, MLACache(c_kv, k_rope, positions)


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, capacity, cfg.kv_lora), dtype),
        k_rope=jnp.zeros((batch, capacity, cfg.rope_head_dim), dtype),
        positions=jnp.full((capacity,), -1, jnp.int32),
    )
