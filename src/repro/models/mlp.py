"""SwiGLU feed-forward block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, linear

__all__ = ["mlp_init", "mlp_apply"]


def mlp_init(key: jax.Array, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, d_ff), dtype),
        "w_up": dense_init(ks[1], (d, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d), dtype),
    }


def mlp_apply(params: dict, x: jax.Array) -> jax.Array:
    return linear(jax.nn.silu(linear(x, params["w_gate"])) * linear(x, params["w_up"]), params["w_down"])
