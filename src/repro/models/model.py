"""Decoder assembly: groups of scanned blocks, three execution paths.

A model is a sequence of *groups*; each group scans `repeat` copies of a
block `pattern` (list of block kinds).  Params and caches are stacked along
the scan axis, so HLO size is independent of depth:

  dense/vlm/audio : [("attn", "mlp")] * L              (one group)
  moe             : dense first layers, then (mla|attn, moe)
  ssm             : [("mamba",)] * L
  hybrid (zamba2) : super-blocks [shared_block, mamba*attn_every] — the
                    transformer block's *weights* are shared across all
                    applications (Zamba2), its KV cache is per-site.

Paths:
  train_loss  — full sequence, next-token CE (+ MoE aux), optional remat;
  prefill     — full sequence, returns logits of last position + caches;
  decode_step — one token against ring-buffer caches (serve_step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, linear, rms_norm
from repro.models.mlp import mlp_apply, mlp_init

__all__ = [
    "LayerGroup",
    "layer_groups",
    "init_params",
    "train_loss",
    "prefill",
    "decode_step",
    "init_cache",
]


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    repeat: int
    pattern: Tuple[str, ...]  # block kinds, e.g. ("attn", "mlp")


def layer_groups(cfg: ModelConfig) -> List[LayerGroup]:
    at = cfg.arch_type
    if at in ("dense", "vlm", "audio"):
        kind = "mla" if cfg.use_mla else "attn"
        return [LayerGroup(cfg.n_layers, (kind, "mlp"))]
    if at == "moe":
        kind = "mla" if cfg.use_mla else "attn"
        groups = []
        if cfg.first_dense_layers:
            groups.append(LayerGroup(cfg.first_dense_layers, (kind, "mlp")))
        groups.append(
            LayerGroup(cfg.n_layers - cfg.first_dense_layers, (kind, "moe"))
        )
        return [g for g in groups if g.repeat > 0]
    if at == "ssm":
        return [LayerGroup(cfg.n_layers, ("mamba",))]
    if at == "hybrid":
        every = cfg.attn_every
        n_full = cfg.n_layers // every
        rem = cfg.n_layers - n_full * every
        groups = []
        if n_full:
            groups.append(LayerGroup(n_full, ("shared_block",) + ("mamba",) * every))
        if rem:
            groups.append(LayerGroup(1, ("shared_block",) + ("mamba",) * rem))
        return groups
    raise ValueError(f"unknown arch_type {at!r}")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key: jax.Array, kind: str, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    if kind == "attn":
        return {"ln": jnp.ones((d,), dtype), "attn": attn.gqa_init(key, cfg, dtype)}
    if kind == "mla":
        return {"ln": jnp.ones((d,), dtype), "attn": attn.mla_init(key, cfg, dtype)}
    if kind == "mlp":
        return {"ln": jnp.ones((d,), dtype), "mlp": mlp_init(key, d, cfg.d_ff, dtype)}
    if kind == "moe":
        return {"ln": jnp.ones((d,), dtype), "moe": moe_mod.moe_init(key, cfg, dtype)}
    if kind == "mamba":
        return {"ln": jnp.ones((d,), dtype), "mamba": ssm_mod.mamba_init(key, cfg, dtype)}
    raise ValueError(kind)


def _shared_block_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    groups = layer_groups(cfg)
    keys = jax.random.split(key, len(groups) + 4)
    params: dict = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), dtype)
    if cfg.arch_type == "vlm":
        params["vision_proj"] = dense_init(
            keys[2], (cfg.vision_dim, cfg.d_model), dtype
        )
    if cfg.arch_type == "hybrid":
        params["shared_block"] = _shared_block_init(keys[3], cfg, dtype)

    gparams = []
    for gi, grp in enumerate(groups):
        gkey = keys[4 + gi]

        def one_layer(k, _grp=grp):
            bkeys = jax.random.split(k, len(_grp.pattern))
            return {
                f"{i}_{kind}": _block_init(bk, kind, cfg, dtype)
                for i, (kind, bk) in enumerate(zip(_grp.pattern, bkeys))
                if kind != "shared_block"
            }

        lkeys = jax.random.split(gkey, grp.repeat)
        gparams.append(jax.vmap(one_layer)(lkeys))
    params["groups"] = gparams
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _block_cache(kind: str, cfg: ModelConfig, batch: int, capacity: int, dtype):
    if kind in ("attn", "shared_block"):
        return attn.init_kv_cache(cfg, batch, capacity, dtype)
    if kind == "mla":
        return attn.init_mla_cache(cfg, batch, capacity, dtype)
    if kind == "mamba":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    return None  # mlp / moe carry no cache


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> list:
    """Abstract-friendly cache pytree mirroring the group structure."""
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for grp in layer_groups(cfg):
        entry = {}
        for i, kind in enumerate(grp.pattern):
            c = _block_cache(kind, cfg, batch, capacity, dtype)
            if c is not None:
                entry[f"{i}_{kind}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (grp.repeat,) + x.shape
                    ).copy(),
                    c,
                )
        caches.append(entry)
    return caches


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _apply_block_full(
    kind: str,
    bparams: dict,
    shared: Optional[dict],
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    want_cache: bool,
    capacity: int,
):
    """Full-sequence (train/prefill). Returns (x, cache_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h, cache = attn.gqa_apply(
            bparams["attn"], cfg, rms_norm(x, bparams["ln"]), positions,
            return_cache=want_cache, cache_capacity=capacity,
        )
        return x + h, cache, aux
    if kind == "mla":
        h, cache = attn.mla_apply(
            bparams["attn"], cfg, rms_norm(x, bparams["ln"]), positions,
            return_cache=want_cache, cache_capacity=capacity,
        )
        return x + h, cache, aux
    if kind == "mlp":
        return x + mlp_apply(bparams["mlp"], rms_norm(x, bparams["ln"])), None, aux
    if kind == "moe":
        h, aux = moe_mod.moe_apply(bparams["moe"], cfg, rms_norm(x, bparams["ln"]))
        return x + h, None, aux
    if kind == "mamba":
        h, cache = ssm_mod.mamba_apply(
            bparams["mamba"], cfg, rms_norm(x, bparams["ln"]), return_cache=want_cache
        )
        return x + h, cache, aux
    if kind == "shared_block":
        sb = shared
        h, cache = attn.gqa_apply(
            sb["attn"], cfg, rms_norm(x, sb["ln1"]), positions,
            return_cache=want_cache, cache_capacity=capacity,
        )
        x = x + h
        x = x + mlp_apply(sb["mlp"], rms_norm(x, sb["ln2"]))
        return x, cache, aux
    raise ValueError(kind)


def _apply_block_decode(
    kind: str,
    bparams: dict,
    shared: Optional[dict],
    cfg: ModelConfig,
    x: jax.Array,
    pos: jax.Array,
    cache,
):
    if kind == "attn":
        h, c = attn.gqa_decode(bparams["attn"], cfg, rms_norm(x, bparams["ln"]), pos, cache)
        return x + h, c
    if kind == "mla":
        h, c = attn.mla_decode(bparams["attn"], cfg, rms_norm(x, bparams["ln"]), pos, cache)
        return x + h, c
    if kind == "mlp":
        return x + mlp_apply(bparams["mlp"], rms_norm(x, bparams["ln"])), None
    if kind == "moe":
        h, _ = moe_mod.moe_apply(bparams["moe"], cfg, rms_norm(x, bparams["ln"]))
        return x + h, None
    if kind == "mamba":
        h, c = ssm_mod.mamba_decode(bparams["mamba"], cfg, rms_norm(x, bparams["ln"]), cache)
        return x + h, c
    if kind == "shared_block":
        sb = shared
        h, c = attn.gqa_decode(sb["attn"], cfg, rms_norm(x, sb["ln1"]), pos, cache)
        x = x + h
        x = x + mlp_apply(sb["mlp"], rms_norm(x, sb["ln2"]))
        return x, c
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# trunk runners
# ---------------------------------------------------------------------------
def _run_trunk_full(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    want_cache: bool,
    capacity: int,
):
    shared = params.get("shared_block")
    groups = layer_groups(cfg)
    caches_out = []
    aux_total = jnp.zeros((), jnp.float32)
    for grp, gparams in zip(groups, params["groups"]):

        def body(carry, layer_params):
            h, aux_acc = carry
            cache_entries = {}
            for i, kind in enumerate(grp.pattern):
                bp = layer_params.get(f"{i}_{kind}")
                h, cache, aux = _apply_block_full(
                    kind, bp, shared, cfg, h, positions, want_cache, capacity
                )
                if cache is not None:
                    cache_entries[f"{i}_{kind}"] = cache
            return (h, aux_acc + aux), cache_entries

        if cfg.remat:
            if cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                )
            else:
                body = jax.checkpoint(body)
        if cfg.unroll:
            ys = []
            carry = (x, aux_total)
            for li in range(grp.repeat):
                lp = jax.tree_util.tree_map(lambda t, _li=li: t[_li], gparams)
                carry, y = body(carry, lp)
                ys.append(y)
            (x, aux_total) = carry
            gcache = (
                jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
                if ys and ys[0]
                else {}
            )
        else:
            (x, aux_total), gcache = jax.lax.scan(body, (x, aux_total), gparams)
        caches_out.append(gcache)
    return x, caches_out, aux_total


def _run_trunk_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    pos: jax.Array,
    caches: list,
):
    shared = params.get("shared_block")
    groups = layer_groups(cfg)
    new_caches = []
    for grp, gparams, gcache in zip(groups, params["groups"], caches):

        def body(h, xs):
            layer_params, layer_cache = xs
            out_entries = {}
            for i, kind in enumerate(grp.pattern):
                bp = layer_params.get(f"{i}_{kind}")
                ck = f"{i}_{kind}"
                h, c = _apply_block_decode(
                    kind, bp, shared, cfg, h, pos, layer_cache.get(ck)
                )
                if c is not None:
                    out_entries[ck] = c
            return h, out_entries

        if cfg.unroll:
            ys = []
            for li in range(grp.repeat):
                sl = lambda t, _li=li: t[_li]
                x, y = body(
                    x,
                    (
                        jax.tree_util.tree_map(sl, gparams),
                        jax.tree_util.tree_map(sl, gcache),
                    ),
                )
                ys.append(y)
            gcache_new = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
        else:
            x, gcache_new = jax.lax.scan(body, x, (gparams, gcache))
        new_caches.append(gcache_new)
    return x, new_caches


# ---------------------------------------------------------------------------
# embeddings & heads
# ---------------------------------------------------------------------------
def _embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    tok = batch["tokens"]
    x = params["embed"][tok]
    if cfg.arch_type == "vlm":
        patches = batch["patch_embeds"]  # [B, n_patches, vision_dim]
        vis = linear(patches.astype(x.dtype), params["vision_proj"])
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _logits(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("...d,dv->...v", x, head).astype(jnp.float32)


# ---------------------------------------------------------------------------
# public paths
# ---------------------------------------------------------------------------
def train_loss(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux).  batch: tokens [B,S]
    (+ patch_embeds for vlm); loss over text positions only."""
    x = _embed_inputs(params, cfg, batch)
    s_total = x.shape[1]
    positions = jnp.arange(s_total)
    x, _, aux = _run_trunk_full(params, cfg, x, positions, False, s_total)
    logits = _logits(params, cfg, x)
    tok = batch["tokens"]
    if cfg.arch_type == "vlm":
        logits = logits[:, cfg.n_patches :]
    pred = logits[:, :-1]
    tgt = tok[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux


def prefill(params: dict, cfg: ModelConfig, batch: dict, capacity: int):
    """Returns (last-position logits [B, vocab], caches)."""
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    x, caches, _ = _run_trunk_full(params, cfg, x, positions, True, capacity)
    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    return logits, caches


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array, pos: jax.Array, caches: list):
    """token [B] int32, pos scalar int32 -> (logits [B, vocab], caches)."""
    x = params["embed"][token][:, None]  # [B,1,d]
    x, new_caches = _run_trunk_decode(params, cfg, x, pos, caches)
    logits = _logits(params, cfg, x)[:, 0]
    return logits, new_caches
