"""Architecture registry: the 10 assigned archs (+ the paper's own models).

Every module exposes FULL (exact assigned config) and SMOKE (reduced:
<=2 layers, d_model <= 512, <=4 experts) ModelConfigs.  `get_config(name,
variant)` is the single lookup used by the launcher, dry-run and tests.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "mamba2_1p3b",
    "minitron_4b",
    "yi_34b",
    "deepseek_v2_236b",
    "zamba2_1p2b",
    "stablelm_1p6b",
    "internvl2_2b",
    "musicgen_large",
    "deepseek_v2_lite_16b",
    "qwen3_14b",
]

# CLI aliases (the assignment's spelling) -> module names
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "minitron-4b": "minitron_4b",
    "yi-34b": "yi_34b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-1.2b": "zamba2_1p2b",
    "stablelm-1.6b": "stablelm_1p6b",
    "internvl2-2b": "internvl2_2b",
    "musicgen-large": "musicgen_large",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-14b": "qwen3_14b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str, variant: str = "full") -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg = {"full": mod.FULL, "smoke": mod.SMOKE}[variant]
    return cfg


def all_arch_names() -> List[str]:
    return list(ALIASES.keys())
