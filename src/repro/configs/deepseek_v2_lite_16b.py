"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed
top-6 [arXiv:2405.04434].

27L d_model=2048 16H, per-expert d_ff=1408, vocab=102400, first layer
dense (d_ff=10944); lite variant has no q LoRA.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    vocab=102400,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    use_mla=True,
    kv_lora=512,
    q_lora=0,
    rope_head_dim=64,
    v_head_dim=128,
    d_ff=10944,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_ff_expert=1408,
    first_dense_layers=1,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="deepseek-lite-smoke",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    kv_lora=64,
    q_lora=0,
    rope_head_dim=16,
    v_head_dim=32,
    d_ff=256,
    n_experts=4,
    n_shared_experts=1,
    moe_top_k=2,
    d_ff_expert=64,
    capacity_factor=4.0,
    dtype="float32",
)
