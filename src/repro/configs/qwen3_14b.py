"""qwen3-14b [dense] — qk-norm, GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=17408 vocab=151936.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    vocab=151936,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    qk_norm=True,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="qwen3-smoke",
    n_layers=2,
    d_model=320,
    vocab=512,
    n_heads=5,
    n_kv_heads=1,
    head_dim=64,
    d_ff=640,
    qk_norm=True,
    dtype="float32",
)
