"""minitron-4b [dense] — pruned Nemotron [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8, head_dim=128) d_ff=9216 vocab=256000.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    vocab=256000,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="minitron-smoke",
    n_layers=2,
    d_model=192,
    vocab=512,
    n_heads=6,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    dtype="float32",
)
