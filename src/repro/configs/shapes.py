"""The four assigned input shapes and the (arch x shape) policy.

  train_4k     seq=4096    global_batch=256   -> train_step (one PaME iter)
  prefill_32k  seq=32768   global_batch=32    -> prefill
  decode_32k   seq=32768   global_batch=128   -> serve_step (1 new token,
                                                 KV/state cache of seq_len)
  long_500k    seq=524288  global_batch=1     -> serve_step

long_500k policy: SSM/hybrid run natively (O(1) state).  Every
attention-bearing arch gets a sliding-window variant (window=4096,
ring-buffer cache) selected automatically at this shape — full quadratic
attention at 512k is infeasible on the target mesh, and the windowed
substitution is what makes the shape runnable for dense/MoE/VLM/audio
archs (noted in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache

__all__ = ["InputShape", "INPUT_SHAPES", "config_for_shape", "input_specs", "cache_capacity"]

LONG_CTX_WINDOW = 4096


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the per-shape policy (sliding window at 512k for attn archs)."""
    if shape.name == "long_500k" and cfg.arch_type != "ssm" and cfg.window is None:
        return cfg.replace(window=LONG_CTX_WINDOW)
    return cfg


def cache_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    """Ring-buffer capacity for decode caches."""
    if cfg.window is not None:
        return min(shape.seq_len, cfg.window)
    return shape.seq_len


def input_specs(
    cfg: ModelConfig, shape: InputShape, m_nodes: int = 1
) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   tokens [m, B/m, S]   (+ per-node patch embeds for vlm)
    prefill: tokens [B, S]        (+ patch embeds)
    decode:  token [B], pos [], cache pytree (abstract via eval_shape)
    """
    cfg = config_for_shape(cfg, shape)
    i32 = jnp.int32
    if shape.kind == "train":
        if shape.global_batch % m_nodes:
            raise ValueError(f"global_batch {shape.global_batch} % m={m_nodes}")
        b = shape.global_batch // m_nodes
        text = shape.seq_len - (cfg.n_patches if cfg.arch_type == "vlm" else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((m_nodes, b, text), i32)}
        if cfg.arch_type == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (m_nodes, b, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
            )
        return specs
    if shape.kind == "prefill":
        b = shape.global_batch
        text = shape.seq_len - (cfg.n_patches if cfg.arch_type == "vlm" else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((b, text), i32)}
        if cfg.arch_type == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
            )
        return specs
    if shape.kind == "decode":
        b = shape.global_batch
        cap = cache_capacity(cfg, shape)
        cache = jax.eval_shape(lambda: init_cache(cfg, b, cap))
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "cache": cache,
        }
    raise ValueError(shape.kind)
