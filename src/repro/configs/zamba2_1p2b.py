"""zamba2-1.2b [hybrid] — Mamba2 backbone + one *shared* attention block
applied every 6 layers (weights shared, per-site KV cache) [arXiv:2411.15242].

38L d_model=2048, ssm_state=64; shared block: 32H (kv=32, head_dim=64),
d_ff=8192, vocab=32000.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
    attn_every=6,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="zamba2-smoke",
    n_layers=2,
    d_model=256,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=16,
    attn_every=2,
    dtype="float32",
)
