"""deepseek-v2-236b [moe] — MLA + 2 shared / 160 routed top-6 [arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512 (+64 rope), q_lora=1536,
per-expert d_ff=1536, vocab=102400, first layer dense (d_ff=12288).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    vocab=102400,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head latents, no GQA grouping
    head_dim=128,    # q/k nope dim
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    v_head_dim=128,
    d_ff=12288,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    d_ff_expert=1536,
    first_dense_layers=1,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="deepseek-236b-smoke",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    kv_lora=64,
    q_lora=48,
    rope_head_dim=16,
    v_head_dim=32,
    d_ff=256,
    n_experts=4,
    n_shared_experts=1,
    moe_top_k=2,
    d_ff_expert=64,
    capacity_factor=4.0,
    dtype="float32",
)
