"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone: 24L d_model=2048 16H (GQA kv=8, head_dim=128) d_ff=8192
vocab=92553.  The InternViT vision encoder + MLP projector are STUBBED per
spec: `input_specs()` provides precomputed patch embeddings
[B, 256, 1024]; the model owns only the projection into d_model.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    vocab=92553,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    n_patches=256,
    vision_dim=1024,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="internvl2-smoke",
    n_layers=2,
    d_model=256,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    n_patches=16,
    vision_dim=64,
    dtype="float32",
)
