"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=20480 vocab=64000.
Big-model node layout: fsdp > 1 (see repro.sharding).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    vocab=64000,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="yi-smoke",
    n_layers=2,
    d_model=224,
    vocab=512,
    n_heads=7,
    n_kv_heads=1,
    head_dim=32,
    d_ff=448,
    dtype="float32",
)
