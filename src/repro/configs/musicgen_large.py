"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32, head_dim=64) d_ff=8192 vocab=2048.
The EnCodec conv codec frontend is STUBBED per spec: inputs are already
token ids in the 2048-entry codec vocabulary (codebook-interleaved stream).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    vocab=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="musicgen-smoke",
    n_layers=2,
    d_model=256,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    dtype="float32",
)
