"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (kv=32, head_dim=64) d_ff=5632 vocab=100352.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    vocab=100352,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="stablelm-smoke",
    n_layers=2,
    d_model=256,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    dtype="float32",
)
