"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attn-free, vocab=50280, ssm_state=128;
expand=2 -> d_inner=4096, head_dim=64 -> 64 SSD heads, 1 B/C group.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
    d_conv=4,
    dtype="bfloat16",
)

SMOKE = FULL.replace(
    name="mamba2-smoke",
    n_layers=2,
    d_model=256,
    vocab=512,
    ssm_state=32,
    ssm_head_dim=32,
    ssm_chunk=16,
    dtype="float32",
)
