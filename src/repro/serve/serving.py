"""Batched greedy decode against each node's current local model.

:class:`ServeLoop` is the inference half of serve-while-train: between
training dispatches it runs prefill + batched greedy decode
(``models.prefill`` / ``models.decode_step`` — the same kernels as
``examples/serve_decode.py``) against individual nodes' *current local*
parameters and records per-node service cost (prefill ms, decode ms,
tokens/s).  Queueing latency and staleness-of-served-model come from the
event clock (``repro.serve.events``): this module prices what one
request costs to serve, the event layer counts how long requests wait.

The decode loop accumulates tokens **on device** and transfers once
after the final step — a per-step ``np.asarray`` forces a device→host
sync per token, serializing dispatch and inflating ms/tok (the bug the
original example shipped with).

The jitted prefill/decode closures are built once per config: per-node
parameter slices all share one shape, so serving m nodes — or a grown
node set after a membership join — reuses the same two executables.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill

__all__ = ["decode_greedy", "component_mean_params", "ServeLoop"]


def component_mean_params(params_stacked: object, comp=None) -> object:
    """Per-node *component-mean* parameter stack ([m, ...] leaves).

    Row i of the result is the mean over the nodes sharing i's connected
    component (``comp`` — the [m] component-id vector from
    ``repro.core.scenarios.active_components``; None = one component =
    the global PME average).  This is the consensus-serving failover:
    during a network split each side serves its own component's
    averaged model, and a departed/cut-off node's traffic is answered
    by the component model instead of a stale local copy.
    """
    stacked = [
        leaf for leaf in jax.tree_util.tree_leaves(params_stacked)
        if getattr(leaf, "ndim", 0) >= 1
    ]
    m = stacked[0].shape[0]
    if comp is None:
        comp = jnp.zeros((m,), jnp.int32)
    else:
        comp = jnp.asarray(np.asarray(comp), jnp.int32)
    n_comp = int(np.asarray(comp).max()) + 1
    onehot = (comp[:, None] == jnp.arange(n_comp)[None, :]).astype(jnp.float32)
    counts = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)           # [C]

    def one(leaf):
        if getattr(leaf, "ndim", 0) < 1 or leaf.shape[0] != m:
            return leaf  # scalars / unstacked leaves pass through
        flat = jnp.reshape(leaf, (m, -1)).astype(jnp.float32)
        means = (onehot.T @ flat) / counts[:, None]              # [C, n]
        return jnp.reshape(means[comp], leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, params_stacked)


def decode_greedy(
    dc: Callable,
    params: object,
    first_tok: jax.Array,
    caches: object,
    prompt_len: int,
    gen: int,
    offset: int = 0,
) -> jax.Array:
    """Greedy-decode ``gen - 1`` steps after the prefill token.

    ``dc(params, tok, pos, caches) -> (logits, caches)`` is the (jitted)
    decode step; ``first_tok`` is the argmax of the prefill logits.
    Returns the [B, gen] token matrix as a device array — tokens are
    stacked on device, so the only host transfer is the caller's final
    ``np.asarray`` (after ``block_until_ready`` for honest timing).
    """
    tok = first_tok
    toks: List[jax.Array] = [tok]
    for i in range(gen - 1):
        pos = jnp.int32(prompt_len + offset + i)
        logits, caches = dc(params, tok, pos, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    return jnp.stack(toks, axis=1)


class ServeLoop:
    """Per-node batched greedy decode with service-cost accounting.

    One instance per model config: builds the jitted prefill/decode
    closures once and serves any node's parameter slice through them.
    Prompts are drawn from a private ``default_rng(seed)`` stream —
    independent of every training PRNG.
    """

    def __init__(
        self,
        cfg,
        prompt_len: int = 16,
        gen: int = 8,
        batch: int = 2,
        seed: int = 0,
    ):
        if gen < 2:
            raise ValueError("gen must be >= 2 (prefill token + decode)")
        self.cfg = cfg
        self.prompt_len = int(prompt_len)
        self.gen = int(gen)
        self.batch = int(batch)
        self.offset = cfg.n_patches if cfg.arch_type == "vlm" else 0
        self.capacity = self.prompt_len + self.gen + self.offset
        self._pf = jax.jit(lambda p, b: prefill(p, cfg, b, self.capacity))
        self._dc = jax.jit(
            lambda p, t, pos, c: decode_step(p, cfg, t, pos, c)
        )
        self._rng = np.random.default_rng(seed)

    def make_batch(self) -> dict:
        prompts = jnp.asarray(
            self._rng.integers(
                0, self.cfg.vocab, (self.batch, self.prompt_len)
            ),
            jnp.int32,
        )
        batch = {"tokens": prompts}
        if self.cfg.arch_type == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (self.batch, self.cfg.n_patches, self.cfg.vision_dim),
                jnp.dtype(self.cfg.dtype),
            )
        return batch

    def serve_node(self, params_node: object) -> Dict[str, float]:
        """One decode batch against a single node's parameters.

        Returns service-cost stats: prefill/decode wall-clock and the
        decode throughput in tokens/s (batch × decode steps / wall).
        """
        batch = self.make_batch()
        t0 = time.perf_counter()
        logits, caches = self._pf(params_node, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = decode_greedy(
            self._dc, params_node, tok, caches,
            self.prompt_len, self.gen, self.offset,
        )
        out = np.asarray(jax.block_until_ready(out))
        t_decode = time.perf_counter() - t0
        n_decoded = self.batch * (self.gen - 1)
        return {
            "prefill_ms": t_prefill * 1e3,
            "decode_ms": t_decode * 1e3,
            "tokens_per_s": n_decoded / max(t_decode, 1e-9),
            "tokens": out,
        }

    def serve_round(
        self,
        params_stacked: object,
        node_ids: Optional[Sequence[int]] = None,
        policy: str = "local",
        comp=None,
    ) -> Dict[int, Dict[str, float]]:
        """Serve one decode batch on each requested node's model.

        ``params_stacked`` is the node-stacked parameter pytree ([m, ...]
        leaves); per-node slices share one shape, so every node reuses
        the same compiled executables.

        ``policy`` picks what each node serves FROM:

          * ``"local"``     — node i's own current parameters (the
                              accuracy-vs-staleness default: freshest for
                              i's data, but stale for traffic failing
                              over from a departed or cut-off node).
          * ``"consensus"`` — the PME-averaged model of i's connected
                              component (``comp`` from the partition
                              schedule; None = the global average), so a
                              split component still serves one coherent
                              model and failover traffic never reads a
                              desynced local copy.
        """
        if policy not in ("local", "consensus"):
            raise ValueError(
                f"unknown serving policy {policy!r} (local | consensus)"
            )
        if policy == "consensus":
            params_stacked = component_mean_params(params_stacked, comp)
        if node_ids is None:
            leaves = jax.tree_util.tree_leaves(params_stacked)
            node_ids = range(leaves[0].shape[0])
        stats = {}
        for i in node_ids:
            p_i = jax.tree_util.tree_map(lambda x: x[i], params_stacked)
            stats[int(i)] = self.serve_node(p_i)
        return stats
