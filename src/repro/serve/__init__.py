"""Event-driven serve-while-train layer.

Each simulated node interleaves inference traffic with its PaME training
rounds:

  * :mod:`repro.serve.events` — per-node request arrival processes
    (Poisson + Markov-modulated bursts) and the :class:`ServePacing`
    round pacer that lowers to the scan engine's auxiliary carry slot.
  * :mod:`repro.serve.serving` — :class:`ServeLoop`, batched greedy
    decode against each node's *current local* model with per-node
    latency / throughput accounting.
  * :mod:`repro.serve.membership` — elastic membership: genuinely new
    nodes join mid-run with checkpoint catch-up and re-derived
    Metropolis–Hastings weights over the grown node set.

Only the lightweight event layer is imported eagerly; ``serving`` (which
pulls in the model stack) and ``membership`` are imported on demand.
"""
from repro.serve.events import (  # noqa: F401
    ARRIVAL_PRESETS,
    ArrivalProcess,
    EventState,
    PacedCarry,
    ServePacing,
    expand_events,
    get_arrival,
    list_arrivals,
)

__all__ = [
    "ARRIVAL_PRESETS",
    "ArrivalProcess",
    "EventState",
    "PacedCarry",
    "ServePacing",
    "expand_events",
    "get_arrival",
    "list_arrivals",
]
