"""Elastic membership: genuinely new nodes joining a running DFL system.

The fault layer's crash/rejoin chain (``repro.core.faults``) is a
*fixed-m* recovery path: a crashed node's state freezes bitwise and the
node count never changes.  This module implements true joins — the node
set grows mid-run:

  * :func:`grown_topology` attaches each new node to ``degree`` uniform
    existing nodes and re-derives the Metropolis–Hastings weights over
    the grown graph, so the realized mixing matrix stays symmetric ⇒
    doubly stochastic (mean-preserving) by construction.
  * :func:`expand_state` grows every node-stacked state leaf with
    *donor* rows — the new node catches up by cloning a trained
    neighbor, either from the live state or from a restored checkpoint
    (``repro.checkpoint.store``).  For a node whose state has not moved
    since the checkpoint the two paths are bitwise identical (pinned by
    the conformance suite).
  * :func:`check_join_faults` is the loud guard against mixing the two
    recovery paths: crash faults (``FaultModel.crash > 0``) assume
    fixed-m ``rejoin`` semantics and may not be combined with elastic
    membership.

PaME's per-node draws stay stable across growth: ``make_topology_arrays``
draws kappa_i sequentially from ``default_rng(seed)``, so the first
m_old entries are unchanged when m grows — existing nodes keep their
communication periods; attach targets' t_i = max(1, floor(nu·|N_i|))
grow with their realized degree, which is the intended semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt_mod
from repro.core.topology import (
    Topology,
    metropolis_matrix,
    spectral_gap_zeta,
)

__all__ = [
    "JoinEvent",
    "parse_join_spec",
    "topology_from_adjacency",
    "grown_topology",
    "default_donors",
    "expand_state",
    "check_join_faults",
]


@dataclasses.dataclass(frozen=True)
class JoinEvent:
    """``n_new`` nodes join at global step ``step``, each attaching to
    ``degree`` uniform existing nodes (drawn from ``seed`` + the current
    node count, so repeated events draw fresh attachments)."""

    step: int
    n_new: int
    degree: int = 2

    def __post_init__(self):
        if self.step < 0 or self.n_new < 0:
            raise ValueError("join step and n_new must be non-negative")
        if self.degree < 1:
            raise ValueError("join degree must be >= 1")


def parse_join_spec(spec: Optional[str], degree: int = 2
                    ) -> Tuple[JoinEvent, ...]:
    """Parse ``"STEP:N[:DEGREE]"`` comma-lists (e.g. ``"40:2,80:2"``)."""
    if not spec:
        return ()
    events = []
    for part in spec.split(","):
        fields = part.strip().split(":")
        if len(fields) not in (2, 3):
            raise ValueError(
                f"join spec {part!r} is not STEP:N or STEP:N:DEGREE"
            )
        events.append(JoinEvent(
            step=int(fields[0]), n_new=int(fields[1]),
            degree=int(fields[2]) if len(fields) == 3 else degree,
        ))
    return tuple(sorted(events, key=lambda e: e.step))


def topology_from_adjacency(a: np.ndarray) -> Topology:
    """Build a Topology (neighbor sets + Metropolis–Hastings mixing +
    spectral gap) from an explicit symmetric 0/1 adjacency."""
    a = np.asarray(a)
    m = a.shape[0]
    if a.shape != (m, m) or not np.array_equal(a, a.T):
        raise ValueError("adjacency must be square and symmetric")
    if np.any(np.diag(a) != 0):
        raise ValueError("adjacency must have a zero diagonal")
    nsets = tuple(
        tuple(int(j) for j in np.nonzero(a[i])[0]) for i in range(m)
    )
    b = metropolis_matrix(a)
    return Topology(
        m=m, adjacency=a, neighbor_sets=nsets, mixing=b,
        zeta=spectral_gap_zeta(b),
    )


def grown_topology(topo: Topology, n_new: int, degree: int = 2,
                   seed: int = 0) -> Topology:
    """Grow the graph by n_new nodes, each attached to ``degree`` uniform
    *existing* nodes (so every new node has a trained donor and the grown
    graph stays connected whenever the base graph is).

    The attachment draw is seeded on ``(seed, topo.m)`` — successive join
    events on a growing run draw fresh, reproducible attachments.
    """
    if n_new == 0:
        return topo
    m_old, m_new = topo.m, topo.m + n_new
    rng = np.random.default_rng((int(seed), int(topo.m)))
    a = np.zeros((m_new, m_new), dtype=topo.adjacency.dtype)
    a[:m_old, :m_old] = topo.adjacency
    for idx in range(n_new):
        i = m_old + idx
        deg = min(degree, m_old)
        targets = rng.choice(m_old, size=deg, replace=False)
        a[i, targets] = 1
        a[targets, i] = 1
    return topology_from_adjacency(a)


def default_donors(topo_new: Topology, m_old: int) -> np.ndarray:
    """Donor for each new node: its lowest-id neighbor among the old
    nodes — the node it attached to, whose trained state it clones."""
    donors = []
    for i in range(m_old, topo_new.m):
        olds = [j for j in topo_new.neighbor_sets[i] if j < m_old]
        if not olds:
            raise ValueError(f"new node {i} has no old-node neighbor")
        donors.append(min(olds))
    return np.asarray(donors, np.int64)


def expand_state(state: object, m_old: int, donors: Sequence[int],
                 source_state: Optional[object] = None) -> object:
    """Grow every node-stacked leaf of ``state`` by len(donors) rows.

    A leaf is node-stacked iff its leading axis is exactly ``m_old``;
    scalars (step counters) and unstacked leaves (shared PRNG keys) pass
    through.  New rows are the donor nodes' rows read from
    ``source_state`` (default: the live state) — pass a checkpoint-
    restored state for checkpoint catch-up.  Cloning the donor includes
    its per-node PRNG/penalty entries: the new node continues the
    donor's schedule, which is exactly the catch-up semantics.

    Zero joins (empty ``donors``) return ``state`` unchanged — bitwise.
    """
    donors = np.asarray(donors, np.int64)
    if donors.size == 0:
        return state
    if np.any(donors < 0) or np.any(donors >= m_old):
        raise ValueError(f"donors must index old nodes [0, {m_old})")
    src = state if source_state is None else source_state
    didx = jnp.asarray(donors)

    def grow(leaf, s_leaf):
        if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) < 1:
            return leaf
        if leaf.shape[0] != m_old:
            return leaf
        rows = jnp.asarray(s_leaf)[didx]
        return jnp.concatenate([jnp.asarray(leaf), rows], axis=0)

    return jax.tree_util.tree_map(grow, state, src)


def check_join_faults(faults: Optional[flt_mod.FaultModel]) -> None:
    """Refuse to mix the two recovery paths.

    ``FaultModel.crash``/``rejoin`` is documented for *fixed-m* transient
    crashes: the crashed node's frozen state IS the local checkpoint it
    rejoins from, and every fault chain is shaped [m, ...].  Elastic
    membership changes m mid-run — silently combining the two would
    rejoin crashed nodes into a graph they were never weighted for.
    Loss/burst/delay chains are per-link transients and re-initialize
    cleanly over the grown node set, so they remain allowed.
    """
    if faults is not None and faults.crash > 0.0:
        raise ValueError(
            "elastic membership (node joins) cannot be combined with crash "
            f"faults: FaultModel(crash={faults.crash}, rejoin="
            f"{faults.rejoin}) uses the fixed-m rejoin path (state frozen "
            "and restored in place), while joins grow m and re-derive the "
            "mixing weights.  Run crashes via --crash without --join, or "
            "model churn with Scenario(churn=...) which composes with "
            "joins."
        )
