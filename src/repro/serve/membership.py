"""Elastic membership: nodes joining AND leaving a running DFL system.

The fault layer's crash/rejoin chain (``repro.core.faults``) is a
*fixed-m* recovery path: a crashed node's state freezes bitwise and the
node count never changes.  This module implements true membership
changes — the node set grows and shrinks mid-run:

  * :func:`grown_topology` attaches each new node to ``degree`` uniform
    existing nodes and re-derives the Metropolis–Hastings weights over
    the grown graph, so the realized mixing matrix stays symmetric ⇒
    doubly stochastic (mean-preserving) by construction.
  * :func:`expand_state` grows every node-stacked state leaf with
    *donor* rows — the new node catches up by cloning a trained
    neighbor, either from the live state or from a restored checkpoint
    (``repro.checkpoint.store``).  For a node whose state has not moved
    since the checkpoint the two paths are bitwise identical (pinned by
    the conformance suite).
  * :func:`shrunk_topology` + :func:`retire_state` are the graceful
    *departure* half: a leaving node hands its parameter mass to its
    neighbors — each survivor j absorbs β_j·(x_ℓ − x̄) where β_j is the
    leaver's MH weight toward j renormalized over its neighbors
    (Σβ_j = 1) and x̄ is the pre-departure global mean, so the survivor
    mean equals the pre-departure mean *exactly* (mean-preserving by
    construction; near consensus the handoff vanishes) — then the MH
    weights are re-derived over the survivor set and the engine
    continues at reduced m.  Contrast with the crash chain's fixed-m
    bitwise freeze.
  * :func:`parse_chaos_spec` is the declarative chaos timeline —
    ``"leave@200:2,partition@400:bridge,heal@800,join@900:1"`` — that
    composes leaves/joins with scheduled network partitions
    (``repro.core.scenarios.PartitionWindow``).
  * :func:`check_membership_faults` is the loud guard against mixing
    the recovery paths: crash faults (``FaultModel.crash > 0``) assume
    fixed-m ``rejoin`` semantics and may not be combined with elastic
    membership, and chaos timelines that would silently produce a
    non-stochastic realization (leave+join at one step, membership
    changes inside an open partition window, emptying the graph) are
    rejected up front.  :func:`check_join_faults` remains the join-only
    entry point.

PaME's per-node draws stay stable across growth: ``make_topology_arrays``
draws kappa_i sequentially from ``default_rng(seed)``, so the first
m_old entries are unchanged when m grows — existing nodes keep their
communication periods; attach targets' t_i = max(1, floor(nu·|N_i|))
grow with their realized degree, which is the intended semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt_mod
from repro.core.scenarios import PartitionWindow
from repro.core.topology import (
    Topology,
    metropolis_matrix,
    spectral_gap_zeta,
)

__all__ = [
    "JoinEvent",
    "ChaosEvent",
    "parse_join_spec",
    "parse_chaos_spec",
    "chaos_partitions",
    "topology_from_adjacency",
    "grown_topology",
    "shrunk_topology",
    "default_donors",
    "expand_state",
    "retire_state",
    "check_join_faults",
    "check_membership_faults",
]


@dataclasses.dataclass(frozen=True)
class JoinEvent:
    """``n_new`` nodes join at global step ``step``, each attaching to
    ``degree`` uniform existing nodes (drawn from ``seed`` + the current
    node count, so repeated events draw fresh attachments)."""

    step: int
    n_new: int
    degree: int = 2

    def __post_init__(self):
        if self.step < 0 or self.n_new < 0:
            raise ValueError("join step and n_new must be non-negative")
        if self.degree < 1:
            raise ValueError("join degree must be >= 1")


def parse_join_spec(spec: Optional[str], degree: int = 2
                    ) -> Tuple[JoinEvent, ...]:
    """Parse ``"STEP:N[:DEGREE]"`` comma-lists (e.g. ``"40:2,80:2"``)."""
    if not spec:
        return ()
    events = []
    for part in spec.split(","):
        fields = part.strip().split(":")
        if len(fields) not in (2, 3):
            raise ValueError(
                f"join spec {part!r} is not STEP:N or STEP:N:DEGREE"
            )
        events.append(JoinEvent(
            step=int(fields[0]), n_new=int(fields[1]),
            degree=int(fields[2]) if len(fields) == 3 else degree,
        ))
    return tuple(sorted(events, key=lambda e: e.step))


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One entry of a chaos timeline.

    ``kind`` is one of:

      * ``"leave"``     — ``n`` nodes depart gracefully at ``step`` (the
                          highest-id nodes: LIFO departure, so state
                          rows stay contiguous and the most recently
                          joined leave first).
      * ``"join"``      — ``n`` new nodes join, each attached to
                          ``degree`` uniform existing nodes.
      * ``"partition"`` — the graph splits into ``n`` connected
                          components at ``step`` (seeded multi-source
                          BFS cut; ``n=2`` is the classic bridge cut).
      * ``"heal"``      — the open partition re-merges at ``step``.
    """

    step: int
    kind: str
    n: int = 0
    degree: int = 2

    def __post_init__(self):
        if self.kind not in ("leave", "join", "partition", "heal"):
            raise ValueError(f"unknown chaos event kind {self.kind!r}")
        if self.step < 0:
            raise ValueError("chaos event step must be non-negative")
        if self.kind in ("leave", "join") and self.n < 0:
            raise ValueError(f"{self.kind} count must be non-negative")
        if self.kind == "partition" and self.n < 2:
            raise ValueError("a partition needs at least 2 components")
        if self.kind == "join" and self.degree < 1:
            raise ValueError("join degree must be >= 1")


def parse_chaos_spec(spec: Optional[str], degree: int = 2
                     ) -> Tuple[ChaosEvent, ...]:
    """Parse the declarative chaos timeline grammar.

    Comma-separated ``KIND@STEP[:ARG[:ARG]]`` entries, e.g.::

        leave@200:2,partition@400:bridge,heal@800,join@900:1

      * ``leave@STEP:N``          — N highest-id nodes depart
      * ``partition@STEP:bridge`` — split into 2 components
      * ``partition@STEP:P``      — split into P components
      * ``heal@STEP``             — re-merge the open partition
      * ``join@STEP:N[:DEG]``     — N joiners at attach degree DEG

    Events are returned sorted by step.  An empty/None spec is the
    empty timeline — callers keep the plain serve_train path bitwise.
    """
    if not spec:
        return ()
    events = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "@" not in part:
            raise ValueError(
                f"chaos event {part!r} is not KIND@STEP[:ARG[:ARG]]"
            )
        kind, _, rest = part.partition("@")
        fields = rest.split(":")
        kind = kind.strip()
        if kind == "heal":
            if len(fields) != 1:
                raise ValueError(f"heal takes no argument: {part!r}")
            events.append(ChaosEvent(step=int(fields[0]), kind="heal"))
        elif kind == "partition":
            if len(fields) != 2:
                raise ValueError(
                    f"partition needs one argument (bridge or a part "
                    f"count): {part!r}"
                )
            n = 2 if fields[1].strip() == "bridge" else int(fields[1])
            events.append(ChaosEvent(step=int(fields[0]), kind="partition",
                                     n=n))
        elif kind == "leave":
            if len(fields) != 2:
                raise ValueError(f"leave needs a node count: {part!r}")
            events.append(ChaosEvent(step=int(fields[0]), kind="leave",
                                     n=int(fields[1])))
        elif kind == "join":
            if len(fields) not in (2, 3):
                raise ValueError(
                    f"join is join@STEP:N[:DEGREE]: {part!r}"
                )
            events.append(ChaosEvent(
                step=int(fields[0]), kind="join", n=int(fields[1]),
                degree=int(fields[2]) if len(fields) == 3 else degree,
            ))
        else:
            raise ValueError(
                f"unknown chaos event kind {kind!r} in {part!r} "
                "(leave/partition/heal/join)"
            )
    return tuple(sorted(events, key=lambda e: e.step))


def chaos_partitions(events: Sequence[ChaosEvent], num_steps: int,
                     seed: int = 0) -> Tuple[PartitionWindow, ...]:
    """Fold a timeline's partition/heal pairs into `PartitionWindow`s.

    Each ``partition`` opens a window that the next ``heal`` closes; an
    unhealed partition runs to ``num_steps``.  A heal without an open
    partition, or a partition while one is open, raises — the grammar
    would otherwise silently realize a non-schedulable cut.
    """
    windows = []
    open_ev: Optional[ChaosEvent] = None
    for ev in sorted(events, key=lambda e: e.step):
        if ev.kind == "partition":
            if open_ev is not None:
                raise ValueError(
                    f"partition@{ev.step} while the partition@"
                    f"{open_ev.step} window is still open (heal it first)"
                )
            open_ev = ev
        elif ev.kind == "heal":
            if open_ev is None:
                raise ValueError(
                    f"heal@{ev.step} without an open partition"
                )
            windows.append(PartitionWindow(
                start=open_ev.step, heal=ev.step, n_parts=open_ev.n,
                seed=seed,
            ))
            open_ev = None
    if open_ev is not None:
        windows.append(PartitionWindow(
            start=open_ev.step, heal=max(num_steps, open_ev.step + 1),
            n_parts=open_ev.n, seed=seed,
        ))
    return tuple(windows)


def topology_from_adjacency(a: np.ndarray) -> Topology:
    """Build a Topology (neighbor sets + Metropolis–Hastings mixing +
    spectral gap) from an explicit symmetric 0/1 adjacency."""
    a = np.asarray(a)
    m = a.shape[0]
    if a.shape != (m, m) or not np.array_equal(a, a.T):
        raise ValueError("adjacency must be square and symmetric")
    if np.any(np.diag(a) != 0):
        raise ValueError("adjacency must have a zero diagonal")
    nsets = tuple(
        tuple(int(j) for j in np.nonzero(a[i])[0]) for i in range(m)
    )
    b = metropolis_matrix(a)
    return Topology(
        m=m, adjacency=a, neighbor_sets=nsets, mixing=b,
        zeta=spectral_gap_zeta(b),
    )


def grown_topology(topo: Topology, n_new: int, degree: int = 2,
                   seed: int = 0) -> Topology:
    """Grow the graph by n_new nodes, each attached to ``degree`` uniform
    *existing* nodes (so every new node has a trained donor and the grown
    graph stays connected whenever the base graph is).

    The attachment draw is seeded on ``(seed, topo.m)`` — successive join
    events on a growing run draw fresh, reproducible attachments.
    """
    if n_new == 0:
        return topo
    m_old, m_new = topo.m, topo.m + n_new
    rng = np.random.default_rng((int(seed), int(topo.m)))
    a = np.zeros((m_new, m_new), dtype=topo.adjacency.dtype)
    a[:m_old, :m_old] = topo.adjacency
    for idx in range(n_new):
        i = m_old + idx
        deg = min(degree, m_old)
        targets = rng.choice(m_old, size=deg, replace=False)
        a[i, targets] = 1
        a[targets, i] = 1
    return topology_from_adjacency(a)


def shrunk_topology(topo: Topology, leavers: Sequence[int]) -> Topology:
    """Remove ``leavers`` from the graph and re-derive the
    Metropolis–Hastings weights over the survivor set — symmetric ⇒
    doubly stochastic by construction, same conformance as
    :func:`grown_topology`.  Surviving node ids compact downward in
    order (survivor i keeps its relative position).

    Zero leavers return ``topo`` unchanged — the same object.
    """
    leavers = sorted({int(i) for i in leavers})
    if not leavers:
        return topo
    if leavers[0] < 0 or leavers[-1] >= topo.m:
        raise ValueError(f"leavers must index nodes [0, {topo.m})")
    if len(leavers) >= topo.m:
        raise ValueError(
            f"cannot retire all {topo.m} nodes — at least one must remain"
        )
    keep = np.asarray([i for i in range(topo.m) if i not in set(leavers)])
    return topology_from_adjacency(topo.adjacency[np.ix_(keep, keep)])


def default_donors(topo_new: Topology, m_old: int) -> np.ndarray:
    """Donor for each new node: its lowest-id neighbor among the old
    nodes — the node it attached to, whose trained state it clones."""
    donors = []
    for i in range(m_old, topo_new.m):
        olds = [j for j in topo_new.neighbor_sets[i] if j < m_old]
        if not olds:
            raise ValueError(f"new node {i} has no old-node neighbor")
        donors.append(min(olds))
    return np.asarray(donors, np.int64)


def expand_state(state: object, m_old: int, donors: Sequence[int],
                 source_state: Optional[object] = None) -> object:
    """Grow every node-stacked leaf of ``state`` by len(donors) rows.

    A leaf is node-stacked iff its leading axis is exactly ``m_old``;
    scalars (step counters) and unstacked leaves (shared PRNG keys) pass
    through.  New rows are the donor nodes' rows read from
    ``source_state`` (default: the live state) — pass a checkpoint-
    restored state for checkpoint catch-up.  Cloning the donor includes
    its per-node PRNG/penalty entries: the new node continues the
    donor's schedule, which is exactly the catch-up semantics.

    Zero joins (empty ``donors``) return ``state`` unchanged — bitwise.
    """
    donors = np.asarray(donors, np.int64)
    if donors.size == 0:
        return state
    if np.any(donors < 0) or np.any(donors >= m_old):
        raise ValueError(f"donors must index old nodes [0, {m_old})")
    src = state if source_state is None else source_state
    didx = jnp.asarray(donors)

    def grow(leaf, s_leaf):
        if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) < 1:
            return leaf
        if leaf.shape[0] != m_old:
            return leaf
        rows = jnp.asarray(s_leaf)[didx]
        return jnp.concatenate([jnp.asarray(leaf), rows], axis=0)

    return jax.tree_util.tree_map(grow, state, src)


def retire_state(state: object, topo: Topology,
                 leavers: Sequence[int]) -> object:
    """Shrink every node-stacked state leaf, handing each leaver's
    parameter mass to its neighbors — mean-preserving by construction.

    For each leaver ℓ (processed highest-id first, each against the
    current shrinking topology's mixing matrix), every surviving node j
    absorbs ``β_j · (x_ℓ − x̄)`` where ``β_j = B_ℓj / (1 − B_ℓℓ)`` is
    the leaver's MH weight toward j renormalized over its neighbors
    (``Σ_j β_j = 1``; an isolated leaver hands off uniformly) and
    ``x̄`` is the mean over ALL current nodes including ℓ.  The survivor
    mean then equals the pre-departure global mean *exactly*:

        (Σ_{j≠ℓ} x_j + (x_ℓ − x̄)) / (m−1) = ((m−1)·x̄) / (m−1) = x̄

    Near consensus (x_ℓ ≈ x̄) the handoff vanishes — a graceful leave
    costs nothing, unlike the crash chain's frozen row.  The handoff
    applies to every floating node-stacked leaf (leaves identical
    across nodes — momentum at init, penalty schedules — hand off a
    zero deviation, so it is exact for them too); integer leaves
    (per-node PRNG keys) simply drop the leaver's row.

    Zero leavers return ``state`` unchanged — bitwise.
    """
    leavers = sorted({int(i) for i in leavers}, reverse=True)
    if not leavers:
        return state
    if leavers[-1] < 0 or leavers[0] >= topo.m:
        raise ValueError(f"leavers must index nodes [0, {topo.m})")
    if len(leavers) >= topo.m:
        raise ValueError(
            f"cannot retire all {topo.m} nodes — at least one must remain"
        )
    cur_topo = topo
    for ell in leavers:
        m = cur_topo.m
        b_row = np.asarray(cur_topo.mixing[ell], np.float64)
        b_ll = float(b_row[ell])
        if b_ll >= 1.0 - 1e-12:  # isolated leaver: uniform handoff
            beta = np.full(m, 1.0 / (m - 1))
        else:
            beta = b_row / (1.0 - b_ll)
        beta[ell] = 0.0
        keep = np.asarray([i for i in range(m) if i != ell])
        didx = jnp.asarray(keep)
        beta_keep = jnp.asarray(beta[keep], jnp.float32)

        def shrink(leaf, _ell=ell, _m=m, _didx=didx, _beta=beta_keep):
            if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) < 1:
                return leaf
            if leaf.shape[0] != _m:
                return leaf
            leaf = jnp.asarray(leaf)
            kept = leaf[_didx]
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                return kept
            dev = (leaf[_ell] - jnp.mean(leaf, axis=0)).astype(leaf.dtype)
            b = _beta.reshape((_m - 1,) + (1,) * (leaf.ndim - 1))
            return kept + (b * dev).astype(leaf.dtype)

        state = jax.tree_util.tree_map(shrink, state)
        cur_topo = shrunk_topology(cur_topo, (ell,))
    return state


def check_join_faults(faults: Optional[flt_mod.FaultModel]) -> None:
    """Refuse to mix the two recovery paths.

    ``FaultModel.crash``/``rejoin`` is documented for *fixed-m* transient
    crashes: the crashed node's frozen state IS the local checkpoint it
    rejoins from, and every fault chain is shaped [m, ...].  Elastic
    membership changes m mid-run — silently combining the two would
    rejoin crashed nodes into a graph they were never weighted for.
    Loss/burst/delay chains are per-link transients and re-initialize
    cleanly over the grown node set, so they remain allowed.
    """
    if faults is not None and faults.crash > 0.0:
        raise ValueError(
            "elastic membership (node joins) cannot be combined with crash "
            f"faults: FaultModel(crash={faults.crash}, rejoin="
            f"{faults.rejoin}) uses the fixed-m rejoin path (state frozen "
            "and restored in place), while joins grow m and re-derive the "
            "mixing weights.  Run crashes via --crash without --join, or "
            "model churn with Scenario(churn=...) which composes with "
            "joins."
        )


def check_membership_faults(
    faults: Optional[flt_mod.FaultModel],
    events: Sequence[ChaosEvent] = (),
    m0: Optional[int] = None,
) -> None:
    """Validate a chaos timeline loudly instead of letting it silently
    produce a non-stochastic realization.

    Rejects, in order of how subtly each would corrupt the run:

      * crash faults combined with any membership change (leave/join) —
        the crash chain's fixed-m ``rejoin`` path would restore a frozen
        row into a graph it was never weighted for, and a scheduled
        leave could retire a node whose state is mid-crash (the
        "leaving a crashed node" hazard): the frozen mass would be
        handed off from a stale snapshot.
      * a leave and a join at the same step — the leaver ids and joiner
        ids would alias the same rows (leave+join at one step targeting
        one id is order-dependent), so the two must be scheduled at
        distinct steps.
      * a membership change inside an open partition window — the
        partition's component map was drawn over a node set that no
        longer exists (partitioning an already-departed node), so the
        realized matrix would cut edges of phantom nodes.  Heal first,
        then change membership.
      * a timeline that empties the graph (``m0`` given): cumulative
        leaves/joins must keep at least one node at every event.

    :func:`check_join_faults` (crash × join) remains the join-only
    entry point and is applied here as the first check.
    """
    events = tuple(sorted(events, key=lambda e: e.step))
    membership = [e for e in events if e.kind in ("leave", "join") and e.n > 0]
    if membership and faults is not None and faults.crash > 0.0:
        kinds = sorted({e.kind for e in membership})
        raise ValueError(
            f"chaos timeline schedules membership changes ({'/'.join(kinds)}) "
            f"but crash faults are bound (FaultModel(crash={faults.crash})): "
            "the fixed-m rejoin path freezes state rows in place, so a "
            "departure could retire a crashed node's stale snapshot and a "
            "join would rejoin crashes into a re-weighted graph.  Run "
            "crashes without membership changes, or drop --crash."
        )
    by_step: dict = {}
    for e in membership:
        by_step.setdefault(e.step, set()).add(e.kind)
    for step, kinds in sorted(by_step.items()):
        if len(kinds) > 1:
            raise ValueError(
                f"leave and join scheduled at the same step {step}: the "
                "retired and joining rows would alias — schedule them at "
                "distinct steps"
            )
    open_since: Optional[int] = None
    m = m0
    for e in events:
        if e.kind == "partition":
            if m is not None and e.n > m:
                raise ValueError(
                    f"partition@{e.step} into {e.n} components, but only "
                    f"{m} nodes remain at that step"
                )
            open_since = e.step
        elif e.kind == "heal":
            open_since = None
        elif open_since is not None:
            raise ValueError(
                f"{e.kind}@{e.step} inside the partition window open since "
                f"step {open_since}: the component map was drawn over the "
                "pre-change node set (it would partition already-departed "
                "or not-yet-joined nodes).  Heal the split before changing "
                "membership."
            )
        if m is not None:
            if e.kind == "leave":
                if e.n >= m:
                    raise ValueError(
                        f"leave@{e.step}:{e.n} would retire "
                        f"{'all' if e.n == m else 'more than all'} "
                        f"{m} remaining nodes"
                    )
                m -= e.n
            elif e.kind == "join":
                m += e.n
