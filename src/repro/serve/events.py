"""Request arrival processes and arrival-driven round pacing.

The serving half of the online-DFL scenario: every node fields a stream
of inference requests while it trains.  Arrivals are sampled per node
per *training round* from either a plain Poisson process or a
Markov-modulated Poisson process (MMPP — a hidden per-node burst chain
switches the rate between ``rate`` and ``burst_rate``), each node serves
up to ``capacity`` queued requests per round, and a node whose backlog
exceeds ``defer_threshold`` *defers its gossip exchange* for the round:
it keeps taking local gradient steps (the paper's straggler semantics —
self-loop in the realized B^k, mean-preserving by construction) but
stops answering pull requests until the queue drains.

Everything is traceable: :meth:`ServePacing.advance` is called inside
the scan-fused engine step, with the :class:`EventState` threaded
through the engine's auxiliary carry slot (wrapped in
:class:`PacedCarry` next to the fault carry when both are bound).  The
per-round draws are counter-mode — ``fold_in(state.key, k)`` — so the
event clock is deterministic in (seed, step) and independent of the
training PRNG streams.

Latency accounting is Little's law: ``wait`` accumulates the post-serve
backlog integral, so ``wait_i / served_i`` is node i's mean request
sojourn time in rounds — equivalently the mean *staleness of the served
model*: a request answered w rounds after it arrived is served by a
model w rounds newer than the one it would have seen at arrival.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ArrivalProcess",
    "ARRIVAL_PRESETS",
    "get_arrival",
    "list_arrivals",
    "EventState",
    "PacedCarry",
    "ServePacing",
    "expand_events",
    "shrink_events",
]


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Per-node request arrival model, sampled once per training round.

    ``burst_rate == 0`` is a plain Poisson(rate) process; ``burst_rate >
    0`` turns it into an MMPP: a hidden two-state Markov chain per node
    (quiet -> burst with ``p_up``, burst -> quiet with ``p_down``) and
    the round's arrivals drawn Poisson at the state's rate.  All rates
    are requests / node / round.
    """

    name: str = "off"
    rate: float = 0.0        # quiet-state mean arrivals per round
    burst_rate: float = 0.0  # burst-state rate (0 = plain Poisson)
    p_up: float = 0.05       # P[quiet -> burst] per round
    p_down: float = 0.25     # P[burst -> quiet] per round
    seed: int = 0

    def __post_init__(self):
        if self.rate < 0.0 or self.burst_rate < 0.0:
            raise ValueError("arrival rates must be non-negative")
        for field in ("p_up", "p_down"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} must be a probability in [0, 1]")

    @property
    def modulated(self) -> bool:
        return self.burst_rate > 0.0

    @property
    def is_static(self) -> bool:
        """True iff no requests ever arrive (pacing is a no-op)."""
        return self.rate == 0.0 and self.burst_rate == 0.0


ARRIVAL_PRESETS = {
    "off": ArrivalProcess(name="off"),
    "quiet": ArrivalProcess(name="quiet", rate=0.5),
    "steady": ArrivalProcess(name="steady", rate=2.0),
    "bursty": ArrivalProcess(
        name="bursty", rate=0.5, burst_rate=8.0, p_up=0.05, p_down=0.25
    ),
    "rush": ArrivalProcess(
        name="rush", rate=4.0, burst_rate=16.0, p_up=0.1, p_down=0.1
    ),
}


def get_arrival(name: str) -> ArrivalProcess:
    if name not in ARRIVAL_PRESETS:
        raise ValueError(
            f"unknown arrival preset {name!r}; pick from {sorted(ARRIVAL_PRESETS)}"
        )
    return ARRIVAL_PRESETS[name]


def list_arrivals() -> Tuple[str, ...]:
    return tuple(ARRIVAL_PRESETS)


class EventState(NamedTuple):
    """Device-side event clock (all leaves scan-carried).

    Cumulative counters (``arrived`` / ``served`` / ``wait``) survive the
    whole run — and, via :func:`expand_events`, membership growth — so
    run-level QPS and Little's-law latency read straight off the final
    state.
    """

    hi: jax.Array       # [m] bool — MMPP burst-chain state
    queue: jax.Array    # [m] i32 — backlog after this round's serving
    arrived: jax.Array  # [m] i32 — cumulative arrivals
    served: jax.Array   # [m] i32 — cumulative served requests
    wait: jax.Array     # [m] f32 — backlog integral (Little's law)
    key: jax.Array      # base PRNG key, folded with the step index


class PacedCarry(NamedTuple):
    """Auxiliary carry of a paced bind: the event clock plus whatever
    inner carry (the FaultCarry of a fault-injected bind) the step also
    threads.  ``inner`` is None for pacing-only binds — a pytree leafless
    node, so the scan carry stays well-formed."""

    events: EventState
    inner: Optional[object]


@dataclasses.dataclass(frozen=True)
class ServePacing:
    """Arrival-driven gossip pacing for one bound algorithm.

    Per round and node: arrivals ~ process, up to ``capacity`` requests
    served, and a post-serve backlog above ``defer_threshold`` marks the
    node *busy* — it defers the round's exchange exactly like a scenario
    straggler (local update still applied, self-loop in B^k).
    """

    process: ArrivalProcess = ArrivalProcess()
    capacity: int = 4         # requests a node can serve per round
    defer_threshold: int = 8  # backlog beyond which gossip defers

    def __post_init__(self):
        if self.capacity < 0:
            raise ValueError("capacity must be >= 0")
        if self.defer_threshold < 0:
            raise ValueError("defer_threshold must be >= 0")

    @property
    def is_static(self) -> bool:
        """True iff the process never generates load — a static pacing
        binds the plain unpaced program, bit-identical to ``pacing=None``
        (same convention as zero-rate scenarios / fault models)."""
        return self.process.is_static

    def init(self, m: int, key: Optional[jax.Array] = None) -> EventState:
        """Fresh event clock for m nodes (all queues empty, chains quiet)."""
        if key is None:
            key = jax.random.PRNGKey(self.process.seed)
        return EventState(
            hi=jnp.zeros((m,), bool),
            queue=jnp.zeros((m,), jnp.int32),
            arrived=jnp.zeros((m,), jnp.int32),
            served=jnp.zeros((m,), jnp.int32),
            wait=jnp.zeros((m,), jnp.float32),
            key=key,
        )

    def advance(
        self, es: EventState, k: jax.Array
    ) -> Tuple[EventState, jax.Array, dict]:
        """One round of the event clock (fully traceable).

        Returns ``(new_state, busy, metrics)`` where ``busy`` is the [m]
        bool defer mask the training step ORs into its straggler mask,
        and ``metrics`` are per-round scalars (queue depth, served
        requests, deferred node count) merged into the step metrics.
        """
        proc = self.process
        m = es.queue.shape[0]
        kk = jax.random.fold_in(es.key, jnp.asarray(k, jnp.int32))
        k_mod, k_arr = jax.random.split(kk)
        hi = es.hi
        if proc.modulated:
            u = jax.random.uniform(k_mod, (m,))
            hi = jnp.where(es.hi, u >= proc.p_down, u < proc.p_up)
            lam = jnp.where(hi, proc.burst_rate, proc.rate).astype(jnp.float32)
        else:
            lam = jnp.full((m,), proc.rate, jnp.float32)
        arrivals = jax.random.poisson(k_arr, lam, (m,)).astype(jnp.int32)
        backlog = es.queue + arrivals
        served_now = jnp.minimum(backlog, jnp.int32(self.capacity))
        queue = backlog - served_now
        busy = queue > jnp.int32(self.defer_threshold)
        new_es = EventState(
            hi=hi,
            queue=queue,
            arrived=es.arrived + arrivals,
            served=es.served + served_now,
            wait=es.wait + queue.astype(jnp.float32),
            key=es.key,
        )
        metrics = {
            "queue_depth": jnp.mean(queue.astype(jnp.float32)),
            "served_reqs": jnp.sum(served_now).astype(jnp.float32),
            "deferred_nodes": jnp.sum(busy.astype(jnp.int32)),
        }
        return new_es, busy, metrics


def expand_events(es: EventState, n_new: int) -> EventState:
    """Grow the event clock for n_new joining nodes (elastic membership).

    New nodes start quiet with empty queues and zeroed counters; the
    existing nodes' cumulative accounting carries through the join, so
    run-level QPS / latency stay correct across membership changes.
    """
    if n_new <= 0:
        return es

    def grow_i32(x):
        return jnp.concatenate([x, jnp.zeros((n_new,), x.dtype)])

    return EventState(
        hi=jnp.concatenate([es.hi, jnp.zeros((n_new,), bool)]),
        queue=grow_i32(es.queue),
        arrived=grow_i32(es.arrived),
        served=grow_i32(es.served),
        wait=grow_i32(es.wait),
        key=es.key,
    )


def shrink_events(es: EventState, keep) -> EventState:
    """Shrink the event clock to the surviving nodes (graceful leave).

    ``keep`` indexes the survivors in the pre-departure numbering; their
    cumulative QPS/latency accounting carries through the departure.  A
    departed node's still-queued requests leave with it — its traffic is
    the consensus-serving failover's problem, not the event clock's.
    """
    keep = jnp.asarray(np.asarray(keep, np.int64))
    if keep.shape[0] == es.queue.shape[0]:
        return es
    return EventState(
        hi=es.hi[keep],
        queue=es.queue[keep],
        arrived=es.arrived[keep],
        served=es.served[keep],
        wait=es.wait[keep],
        key=es.key,
    )
