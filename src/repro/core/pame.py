"""PaME — Algorithm 1 of the paper, as a functional JAX step.

All m nodes are simulated inside one SPMD program: every state leaf carries
a leading node axis [m, ...].  Per-node randomness (neighbor selection,
coordinate masks, sub-batches) is counter-based via fold_in(step), so nodes
behave independently without a coordinator — the paper's "partially
synchronized" regime.

Update rule (lines 4–14):
    k in K_i:  v_i = PME(w_i, {w_j : j in N_i^k}),  N_i^k ~ U(N_i, t_i)
    else:      v_i = w_i
    w_i^{k+1}  = v_i - grad f_i(v_i; B_i^k) / (sigma_i^k * t_i)
    sigma_i^{k+1} = gamma_i * sigma_i^k

The non-communicating branch is realised by zeroing the receiver's column
of the selection matrix A, which drives every coordinate count to zero and
makes PME return w_i exactly — one fused code path, no per-node cond.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, pme
from repro.core.topology import Topology

__all__ = [
    "PaMEConfig", "PaMEState", "TopologyArrays",
    "pame_init", "pame_step", "make_pame_runner", "run_pame",
]

# grad_fn(params_i, batch_i, key) -> (loss_i, grads_i)
GradFn = Callable[[object, object, jax.Array], Tuple[jax.Array, object]]


@dataclasses.dataclass(frozen=True)
class PaMEConfig:
    """Hyper-parameters of Algorithm 1 (paper Table II defaults)."""

    nu: float = 0.2          # participation rate nu_i
    p: float = 0.2           # transmission rate s/n
    gamma: float = 1.005     # penalty growth gamma_i > 1
    sigma0: float = 1.0      # initial penalty sigma_i^0
    kappa_lo: int = 3        # communication period interval [lo, hi]
    kappa_hi: int = 7
    mask_mode: str = "exact"  # "exact" (paper) | "bernoulli" (huge leaves)
    homogeneous_kappa: Optional[int] = None  # set to force kappa_i = k0
    exchange: str = "dense"  # "dense" (paper-faithful simulation) |
                             # "compressed" (block-systematic payloads, the
                             # beyond-paper wire format — core.gossip) |
                             # "compressed_q8" (int8 payloads on the wire)
    mixing: str = "dense"    # node-axis contraction of the dense exchange:
                             # "dense" ([m, m] selection-matrix einsum) |
                             # "sparse" (padded neighbor gather, O(m·deg·n))
    partition: str = "flat"  # message format over a multi-leaf model:
                             # "flat" prices one concatenated vector (the
                             # paper's single-vector Eq. (8)); "tree" makes
                             # each pytree leaf its own message segment —
                             # per-leaf rates (p_leaf) and per-leaf Eq.-(8)
                             # accounting (sum over leaf occupancy patterns)
    p_leaf: Optional[Tuple[float, ...]] = None  # per-leaf transmission
                             # rates in tree_flatten order (tree partition
                             # only); None broadcasts the global p

    def __post_init__(self):
        if self.partition not in ("flat", "tree"):
            raise ValueError(
                f"unknown partition {self.partition!r}; pick 'flat' or 'tree'"
            )
        if self.p_leaf is not None:
            if self.partition != "tree":
                raise ValueError("p_leaf requires partition='tree'")
            # normalize to a hashable tuple: p_leaf sits in the registry's
            # static_hp_fields, which compares configs for equality
            object.__setattr__(
                self, "p_leaf", tuple(float(r) for r in self.p_leaf)
            )
        if self.partition == "tree" and self.exchange != "dense":
            raise NotImplementedError(
                "partition='tree' needs exchange='dense'; the compressed "
                "wire formats still assume a single flat payload"
            )


class TopologyArrays(NamedTuple):
    """Device-side view of a Topology for use inside jit."""

    nbrs: jax.Array   # [m, d] padded neighbor ids
    valid: jax.Array  # [m, d] bool
    t: jax.Array      # [m] t_i = max(1, floor(nu_i |N_i|))
    kappa: jax.Array  # [m] per-node communication periods


class PaMEState(NamedTuple):
    params: object     # pytree, leaves [m, ...]
    sigma: jax.Array   # [m]
    step: jax.Array    # int32 scalar
    key: jax.Array     # PRNG key


def make_topology_arrays(
    topo: Topology, cfg: PaMEConfig, seed: int = 0
) -> TopologyArrays:
    nbrs, valid = topo.neighbor_matrix_padded()
    deg = topo.degrees
    t = np.maximum(1, np.floor(cfg.nu * deg)).astype(np.int32)
    rng = np.random.default_rng(seed)
    if cfg.homogeneous_kappa is not None:
        kappa = np.full(topo.m, cfg.homogeneous_kappa, dtype=np.int32)
    else:
        kappa = rng.integers(cfg.kappa_lo, cfg.kappa_hi + 1, topo.m).astype(np.int32)
    return TopologyArrays(
        nbrs=jnp.asarray(nbrs),
        valid=jnp.asarray(valid),
        t=jnp.asarray(t),
        kappa=jnp.asarray(kappa),
    )


def pame_init(key: jax.Array, params_stacked: object, m: int, cfg: PaMEConfig) -> PaMEState:
    """W^0 = 0 per Setup 1 is the caller's choice; any stacked init works
    as long as it lies in N(delta) (Lemma 3)."""
    del m
    leaves = jax.tree_util.tree_leaves(params_stacked)
    m_ = leaves[0].shape[0]
    return PaMEState(
        params=params_stacked,
        sigma=jnp.full((m_,), cfg.sigma0, dtype=jnp.float32),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


def _tree_scale_sub(base, grads, scale):
    """base - grads * scale[node] broadcast over trailing dims."""

    def one(b, g):
        s = scale.reshape((-1,) + (1,) * (b.ndim - 1))
        return b - g * s.astype(b.dtype)

    return jax.tree_util.tree_map(one, base, grads)


def pame_step(
    state: PaMEState,
    batch: object,  # pytree, leaves [m, ...] (per-node sub-batches B_i^k)
    grad_fn: GradFn,
    topo: TopologyArrays,
    cfg: PaMEConfig,
    param_shardings: Optional[object] = None,  # pin v_bar's layout so the
    # gossip einsum cannot re-shard the whole model compute downstream
    realization: Optional[object] = None,  # scenarios.Realization — dynamic
    # network state for this step; restricts PME to surviving neighbors and
    # adds realized wire-bit metrics.  None keeps the static program as-is.
    self_params: Optional[object] = None,  # fresh self-view for the lambda=0
    # fill under bounded staleness: state.params then carries the delayed
    # sender stack (what the wire transports) while each node's own fill
    # reads its true current parameters.  None = classic single stack.
    delivered: Optional[jax.Array] = None,  # [m, d] bool — message-level
    # delivery mask (repro.core.faults).  A selected message is *sent* (and
    # charged) regardless; only delivered ones enter the average.  PME's
    # count normalization keeps the realized averaging row-stochastic under
    # arbitrary asymmetric loss, with the lambda=0 fill as the limit case.
) -> Tuple[PaMEState, dict]:
    m = topo.nbrs.shape[0]
    k_sel, k_mask, k_data = (
        jax.random.fold_in(state.key, state.step * 3 + i) for i in range(3)
    )

    if cfg.partition == "tree":
        # tree-partitioned exchange: each leaf is its own message segment
        # with its own rate; a float keeps the flat code path bit-identical
        num_leaves = len(jax.tree_util.tree_leaves(state.params))
        rate = pme.leaf_rates(num_leaves, cfg.p, cfg.p_leaf)
    else:
        rate = cfg.p

    comm_mask = (state.step % topo.kappa) == 0  # k in K_i
    survivors = None
    if realization is not None:
        # offline / straggling receivers skip the exchange entirely; the
        # sender side is filtered through the realized edge set below.
        comm_mask = comm_mask & realization.participating
        survivors = realization.edge_alive
    if cfg.exchange == "dense" and cfg.mixing == "sparse":
        # padded neighbor-exchange: never materialise the [m, m] selection
        # matrix; gather over max_degree slots instead (same PRNG draws).
        sel = pme.sample_neighbor_selection_padded(
            k_sel, topo.nbrs, topo.valid, topo.t, comm_mask, survivors=survivors
        )
        n_messages = jnp.sum(sel.astype(jnp.int32))
        sel_recv = sel if delivered is None else sel & delivered
        v_bar = pme.pme_average_pytree_padded(
            k_mask, state.params, topo.nbrs, sel_recv, rate,
            mode=cfg.mask_mode, pad=~topo.valid, self_params=self_params,
        )
    else:
        if delivered is not None:
            raise NotImplementedError(
                "message-level delivery masks need mixing='sparse' "
                "(padded selection); the dense selection matrix has no "
                "per-slot delivery channel"
            )
        a = pme.sample_neighbor_selection(
            k_sel, topo.nbrs, topo.valid, topo.t, comm_mask, survivors=survivors
        )
        n_messages = jnp.sum(a).astype(jnp.int32)
        if cfg.exchange in ("compressed", "compressed_q8"):
            from repro.core import gossip

            if self_params is not None:
                raise NotImplementedError(
                    "self_params (message-only delay) is not supported on "
                    "the compressed exchange path"
                )
            v_bar = gossip.compressed_pme_average_pytree(
                k_mask, state.params, a, cfg.p, shardings=param_shardings,
                quantize_bits=8 if cfg.exchange == "compressed_q8" else 0,
            )
        else:
            v_bar = pme.pme_average_pytree(
                k_mask, state.params, a, rate, mode=cfg.mask_mode,
                self_params=self_params,
            )
    if param_shardings is not None:
        v_bar = jax.lax.with_sharding_constraint(v_bar, param_shardings)

    node_keys = jax.random.split(k_data, m)
    losses, grads = jax.vmap(grad_fn)(v_bar, batch, node_keys)

    stepsize = 1.0 / (state.sigma * topo.t.astype(jnp.float32))
    new_params = _tree_scale_sub(v_bar, grads, stepsize)

    # consensus error ||W - Pi||_F^2 (metric of Lemma 6)
    def _cons(leaf):
        mean = leaf.mean(axis=0, keepdims=True)
        return jnp.sum((leaf - mean) ** 2)

    consensus = sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(_cons, new_params)
    ))

    new_state = PaMEState(
        params=new_params,
        sigma=state.sigma * cfg.gamma,
        step=state.step + 1,
        key=state.key,
    )
    metrics = {
        "loss_mean": jnp.mean(losses),
        "consensus": consensus,
        "comm_nodes": jnp.sum(comm_mask.astype(jnp.int32)),
        "sigma_mean": jnp.mean(new_state.sigma),
    }
    if realization is not None:
        # realized Eq.-(8) accounting: each selected surviving neighbor
        # transmits one sparse message, in the int8 wire format when
        # exchange="compressed_q8".  Flat partition prices one concatenated
        # vector of s = round(p·n_total) coordinates; tree partition sums
        # the per-leaf segments (their own s_leaf + occupancy pattern each).
        sizes = [
            int(np.prod(leaf.shape[1:]))
            for leaf in jax.tree_util.tree_leaves(state.params)
        ]
        value_bits = 8 if cfg.exchange == "compressed_q8" else 64
        if cfg.partition == "tree":
            bits = pme.tree_message_bits(sizes, rate, value_bits)
        else:
            n_total = sum(sizes)
            s = max(1, int(round(cfg.p * n_total)))
            bits = pme.message_bits(s, n_total, value_bits)
        metrics["wire_bits"] = n_messages.astype(jnp.float32) * float(bits)
    return new_state, metrics


def _stack_params(params0: object, m: int) -> object:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0
    )


def make_pame_runner(
    grad_fn: GradFn,
    topo: Topology,
    cfg: PaMEConfig,
    *,
    objective_fn: Optional[Callable[[object], jax.Array]] = None,
    tol_std: float = 1e-3,
    chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    seed: int = 0,
    param_shardings: Optional[object] = None,
) -> Callable:
    """Build a reusable scan-fused PaME driver (see `repro.core.engine`).

    Returns ``run(key, params0, m, batch_fn, num_steps) -> (state, history)``.
    The compiled chunk executables persist on the runner, so a warm-up call
    followed by a timed call measures steady-state step cost.
    """
    topo_arrays = make_topology_arrays(topo, cfg, seed=seed)

    def step_fn(state, batch):
        return pame_step(state, batch, grad_fn, topo_arrays, cfg,
                         param_shardings=param_shardings)

    runner = engine.make_scan_runner(
        step_fn,
        objective_fn=objective_fn,
        tol_std=tol_std,
        chunk_size=chunk_size,
    )

    def run(key, params0, m, batch_fn, num_steps):
        state = pame_init(key, _stack_params(params0, m), m, cfg)
        state, metrics, info = runner(state, batch_fn, num_steps)
        history = engine.history_from(metrics, info, {
            "loss": "loss_mean",
            "objective": "objective",
            "consensus": "consensus",
        })
        return state, history

    return run


def run_pame(
    key: jax.Array,
    params0: object,  # single-node pytree; will be stacked m times
    m: int,
    grad_fn: GradFn,
    batch_fn: Callable[[int], object],  # step -> per-node batch pytree [m,...]
    topo: Topology,
    cfg: PaMEConfig,
    num_steps: int = 200,
    objective_fn: Optional[Callable[[object], jax.Array]] = None,
    tol_std: float = 1e-3,
    seed: int = 0,
    driver: str = "scan",
    chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
) -> Tuple[PaMEState, dict]:
    """Run PaME with the paper's termination rule:
    stop when std{f(w^{k-2}), f(w^{k-1}), f(w^k)} < tol_std.

    driver="scan" (default) runs `chunk_size` steps per dispatch through the
    fused `lax.scan` engine with donated state and device-side metric
    buffers; driver="host" is the original one-step-per-dispatch reference
    loop, kept for equivalence testing.
    """
    if driver == "scan":
        run = make_pame_runner(
            grad_fn, topo, cfg, objective_fn=objective_fn, tol_std=tol_std,
            chunk_size=chunk_size, seed=seed,
        )
        return run(key, params0, m, batch_fn, num_steps)
    if driver != "host":
        raise ValueError(f"unknown driver {driver!r}")

    topo_arrays = make_topology_arrays(topo, cfg, seed=seed)
    state = pame_init(key, _stack_params(params0, m), m, cfg)
    step = jax.jit(
        lambda s, b: pame_step(s, b, grad_fn, topo_arrays, cfg)
    )
    history = {"loss": [], "objective": [], "consensus": []}
    f_window: list = []
    for k in range(num_steps):
        batch = batch_fn(k)
        state, metrics = step(state, batch)
        history["loss"].append(float(metrics["loss_mean"]))
        history["consensus"].append(float(metrics["consensus"]))
        if objective_fn is not None:
            mean_params = jax.tree_util.tree_map(
                lambda x: x.mean(axis=0), state.params
            )
            fval = float(objective_fn(mean_params))
            history["objective"].append(fval)
            f_window.append(fval)
            if len(f_window) >= 3 and float(np.std(f_window[-3:])) < tol_std:
                break
    history["steps_run"] = len(history["loss"])
    # one schema across drivers: the host loop dispatches exactly the steps
    # it runs (no chunk rounding past an early termination).
    history["steps_dispatched"] = history["steps_run"]
    return state, history
