"""Core of the reproduction: the PaME algorithm and its substrate.

  topology     — communication graphs, doubly-stochastic mixing matrices
  mixing       — gossip operators: dense einsum vs padded neighbor exchange
  pme          — Partial Message Exchange (Algorithm 2)
  pame         — the PaME step (Algorithm 1)
  baselines    — D-PSGD / DFedSAM / CHOCO-SGD / BEER / (AN)Q-NIDS
  algorithms   — unified registry binding all of the above to one contract
  scenarios    — dynamic networks: per-step link churn, dropout, stragglers
  temporal     — Markov link/node processes + bounded-staleness gossip
  compression  — rand-k / top-k / QSGD / one-bit operators
  gossip       — mesh-sharded gossip (dense-masked + compressed payload)
"""
from repro.core.topology import Topology, build_topology  # noqa: F401
from repro.core.mixing import (  # noqa: F401
    Mixer,
    gather_terms,
    make_mixer,
    mix_padded,
)
from repro.core.engine import run_batched  # noqa: F401
from repro.core.pme import (  # noqa: F401
    pme_average,
    pme_average_pytree,
    naive_average,
    sample_coordinate_masks,
    sample_neighbor_selection,
    message_bits,
)
from repro.core.pame import (  # noqa: F401
    PaMEConfig,
    PaMEState,
    pame_init,
    pame_step,
    run_pame,
    make_topology_arrays,
)
from repro.core.algorithms import (  # noqa: F401
    Algorithm,
    BatchedAlgorithm,
    BoundAlgorithm,
    get_algorithm,
    lane_finals,
    list_algorithms,
    register,
)
from repro.core.scenarios import (  # noqa: F401
    Scenario,
    get_scenario,
    list_scenarios,
    make_scenario_arrays,
    realize,
)
from repro.core.temporal import (  # noqa: F401
    TemporalScenario,
    get_temporal_scenario,
    list_temporal_scenarios,
)
