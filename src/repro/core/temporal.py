"""Temporal-dynamics subsystem: Markov network processes + bounded staleness.

`repro.core.scenarios` draws link failures, churn, and stragglers i.i.d.
per step, and a straggler loses the whole round.  Real decentralized
networks are *bursty* (a bad link stays bad for a while), *sessioned* (a
node that leaves stays gone for a geometric holding time), and *late
rather than absent* (a slow node's messages arrive delayed, not never).
This module replaces the i.i.d. draws with device-side Markov processes
whose state rides the scan carry, and adds a bounded-staleness exchange
mode in which a straggling node keeps participating in the realized
doubly-stochastic matrix through its t-delayed parameters, gathered from
a ring buffer of the last D parameter snapshots that also lives in the
carry:

  * `TemporalScenario` — the spec: Gilbert–Elliott two-state burst
    process per base edge (good→bad w.p. `burst_down`, bad→good w.p.
    `burst_up`), geometric node sessions (up→down w.p. `leave`, down→up
    w.p. `rejoin`), optional mobility-style resampling of the active edge
    subset every `resample_every` steps, an i.i.d. straggler draw or a
    Markov straggler *session* chain (late→fresh w.p. `straggle_off`,
    fresh→late w.p. `straggle_on`), and the staleness bound D
    (`staleness`).
  * `TemporalState` — the per-edge/per-node Markov state + consecutive-
    straggle ages; a pure pytree of device arrays, threaded through the
    engine's auxiliary carry slot (no host round-trips per step).
  * `advance` — one traceable transition: advance the chains from the
    step-folded key, then build the step's `scenarios.Realization` with
    Metropolis–Hastings weights over the surviving subgraph.  Delayed
    stragglers (age ≤ D) *participate*; only churned nodes and stragglers
    past the bound self-loop.
  * `ring_init` / `ring_push` — the staleness ring: leaves [D, m, ...];
    slot k mod D holds the parameters at the start of step k, so a node
    delayed by tau ∈ [1, D] is read at slot (k − tau) mod D
    (`repro.core.mixing.ring_gather`).

Mean preservation under staleness is by construction: the delayed copy of
node j is substituted consistently everywhere j's public value is used
(the algorithm step runs on the substituted parameter stack), the
realized matrix is doubly stochastic over the participants, and each
delayed node re-adds its private innovation (fresh − delayed params) to
its own row afterwards — so the per-leaf global parameter sum is exactly
the no-staleness one for every mean-preserving algorithm in the registry
(`repro.core.algorithms.BoundAlgorithm._temporal_step`).

Degenerate-parameter reductions (used by the conformance suite): with
`burst_up = 1 − burst_down` and `rejoin = 1 − leave` the chains forget
their state and every mask equals the i.i.d. `Scenario` draw *bitwise*
(same key folds, same uniform regions); with `staleness = 0` stragglers
are excluded exactly as on the i.i.d. path.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scenarios import (
    Realization,
    ScenarioArrays,
    edge_uniform,
    realization_from_masks,
)

__all__ = [
    "TemporalScenario",
    "TemporalState",
    "TemporalCarry",
    "TEMPORAL_PRESETS",
    "get_temporal_scenario",
    "list_temporal_scenarios",
    "temporal_state_init",
    "temporal_carry_init",
    "advance",
    "ring_init",
    "ring_push",
]

# init-key folds — outside any reachable step index, so the stationary
# initial draws never collide with the per-step fold_in(key, k) stream
_INIT_EDGE_FOLD = 0x7FFFFFFF
_INIT_NODE_FOLD = 0x7FFFFFFE
_MOBILITY_FOLD = 0x7FFFFFFD
_INIT_STRAG_FOLD = 0x7FFFFFFC


@dataclasses.dataclass(frozen=True)
class TemporalScenario:
    """Markov network dynamics + bounded-staleness exchange.

    All rates are python floats baked into the traced step; the per-step
    transition draws are device-side, keyed on fold_in(key, step) with the
    same (edge, node, straggler) key split as the i.i.d. `Scenario` path.
    """

    name: str = "temporal"
    # Gilbert–Elliott per-edge burst process (undirected links)
    burst_down: float = 0.0   # P[good -> bad] per step
    burst_up: float = 0.5     # P[bad -> good] per step (burst recovery)
    # geometric node sessions
    leave: float = 0.0        # P[up -> down] per step
    rejoin: float = 0.5       # P[down -> up] per step
    # mobility-style resampling of the active edge subset
    resample_every: int = 0   # redraw epoch length in steps; 0 = off
    mobility_keep: float = 1.0  # P[base edge active within an epoch]
    # stragglers + bounded staleness
    straggler: float = 0.0    # i.i.d. P[node is late this step]
    # Markov straggler *sessions* (geometric onset/recovery): a node that
    # turns late stays late for a geometric holding time instead of
    # re-drawing lateness i.i.d. every step.  Mutually exclusive with the
    # i.i.d. `straggler` rate; the degenerate pair straggle_off = 1 −
    # straggle_on reproduces the i.i.d. draw bitwise (same uniform region).
    straggle_on: float = 0.0  # P[fresh -> late] per step
    straggle_off: float = 0.5  # P[late -> fresh] per step (recovery)
    staleness: int = 0        # D: max delay mixed from the ring; 0 = the
    #                           i.i.d. semantics (late nodes excluded)
    seed: int = 0

    def __post_init__(self):
        for field in ("burst_down", "burst_up", "leave", "rejoin",
                      "mobility_keep", "straggler", "straggle_on",
                      "straggle_off"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} must be a probability in [0, 1]")
        if self.staleness < 0:
            raise ValueError(f"staleness={self.staleness} must be >= 0")
        if self.resample_every < 0:
            raise ValueError(
                f"resample_every={self.resample_every} must be >= 0"
            )
        if self.burst_down > 0.0 and self.burst_up == 0.0:
            raise ValueError("burst_up=0 would make bad links permanent")
        if self.leave > 0.0 and self.rejoin == 0.0:
            raise ValueError("rejoin=0 would make departures permanent")
        if self.straggle_on > 0.0 and self.straggle_off == 0.0:
            raise ValueError("straggle_off=0 would make lateness permanent")
        if self.straggle_on > 0.0 and self.straggler > 0.0:
            raise ValueError(
                "straggler and straggle_on are mutually exclusive: pick the "
                "i.i.d. rate or the Markov session chain, not both"
            )

    @property
    def is_static(self) -> bool:
        """True iff every step realizes the base graph exactly."""
        return (
            self.burst_down == self.leave == self.straggler == 0.0
            and self.straggle_on == 0.0
            and (self.resample_every == 0 or self.mobility_keep == 1.0)
        )

    @property
    def mobile(self) -> bool:
        return self.resample_every > 0 and self.mobility_keep < 1.0

    @property
    def stationary_bad(self) -> float:
        """Stationary P[edge bad] of the Gilbert–Elliott chain."""
        denom = self.burst_down + self.burst_up
        return self.burst_down / denom if denom > 0.0 else 0.0

    @property
    def stationary_down(self) -> float:
        """Stationary P[node down] of the session chain."""
        denom = self.leave + self.rejoin
        return self.leave / denom if denom > 0.0 else 0.0

    @property
    def stationary_late(self) -> float:
        """Stationary P[node late] of the straggler session chain."""
        denom = self.straggle_on + self.straggle_off
        return self.straggle_on / denom if denom > 0.0 else 0.0

    @property
    def mean_burst_len(self) -> float:
        """Expected bad-burst length (geometric with rate burst_up)."""
        return 1.0 / self.burst_up if self.burst_down > 0.0 else 0.0

    @property
    def mean_session_len(self) -> float:
        """Expected up-session length (geometric with rate leave)."""
        return 1.0 / self.leave if self.leave > 0.0 else float("inf")


TEMPORAL_PRESETS = {
    # mean bad burst of 4 steps, ~17% of links down in stationarity
    "bursty_links": TemporalScenario(
        name="bursty_links", burst_down=0.05, burst_up=0.25),
    # mean session 33 steps up / 5 steps down, ~13% of nodes out
    "sessions": TemporalScenario(name="sessions", leave=0.03, rejoin=0.2),
    # redraw 60% of the base edges every 25 steps (mobility epochs)
    "mobile": TemporalScenario(
        name="mobile", resample_every=25, mobility_keep=0.6),
    # 40% of nodes late each step, mixed at up to 3 steps of delay
    "stale_stragglers": TemporalScenario(
        name="stale_stragglers", straggler=0.4, staleness=3),
    # sessioned lateness: mean late spell of 4 steps, ~29% late nodes in
    # stationarity, mixed at up to 3 steps of delay
    "straggle_sessions": TemporalScenario(
        name="straggle_sessions", straggle_on=0.1, straggle_off=0.25,
        staleness=3),
    "markov_harsh": TemporalScenario(
        name="markov_harsh", burst_down=0.08, burst_up=0.3,
        leave=0.05, rejoin=0.3, straggler=0.3, staleness=2),
}


def get_temporal_scenario(name: str) -> TemporalScenario:
    if name not in TEMPORAL_PRESETS:
        raise ValueError(
            f"unknown temporal scenario {name!r}; "
            f"pick from {sorted(TEMPORAL_PRESETS)}"
        )
    return TEMPORAL_PRESETS[name]


def list_temporal_scenarios() -> Tuple[str, ...]:
    return tuple(TEMPORAL_PRESETS)


class TemporalState(NamedTuple):
    """Markov state carried through the scan (one step behind `advance`)."""

    edge_bad: jax.Array  # [m, d] bool — Gilbert–Elliott bad state per slot
    node_down: jax.Array  # [m] bool — session chain down state
    age: jax.Array        # [m] i32 — consecutive straggle count
    late: jax.Array       # [m] bool — straggler session chain late state


class TemporalCarry(NamedTuple):
    """What rides the engine's auxiliary carry slot for a temporal run:
    the Markov chain state plus the staleness snapshot ring (None when
    staleness is off, which keeps the ring-free traced program)."""

    ts: TemporalState
    ring: Optional[object]


def temporal_carry_init(
    scenario: TemporalScenario,
    arrays: ScenarioArrays,
    params_stacked: object,
) -> TemporalCarry:
    return TemporalCarry(
        ts=temporal_state_init(scenario, arrays),
        ring=ring_init(params_stacked, scenario.staleness),
    )


def temporal_state_init(
    scenario: TemporalScenario, arrays: ScenarioArrays
) -> TemporalState:
    """Stationary initial draw, keyed outside the per-step fold stream, so
    empirical occupancy matches the stationary law from step 0 (the
    conformance suite checks this without a burn-in window)."""
    m, d = arrays.nbrs.shape
    edge_bad = jnp.zeros((m, d), bool)
    if scenario.burst_down > 0.0:
        u = edge_uniform(
            jax.random.fold_in(arrays.key, _INIT_EDGE_FOLD), arrays.nbrs
        )
        edge_bad = u < scenario.stationary_bad
    node_down = jnp.zeros((m,), bool)
    if scenario.leave > 0.0:
        u = jax.random.uniform(
            jax.random.fold_in(arrays.key, _INIT_NODE_FOLD), (m,)
        )
        node_down = u < scenario.stationary_down
    late = jnp.zeros((m,), bool)
    if scenario.straggle_on > 0.0:
        u = jax.random.uniform(
            jax.random.fold_in(arrays.key, _INIT_STRAG_FOLD), (m,)
        )
        late = u < scenario.stationary_late
    return TemporalState(
        edge_bad, node_down, jnp.zeros((m,), jnp.int32), late
    )


def advance(
    scenario: TemporalScenario,
    arrays: ScenarioArrays,
    ts: TemporalState,
    k: jax.Array,
) -> Tuple[TemporalState, Realization, jax.Array, jax.Array]:
    """One traceable temporal transition + realization for step ``k``.

    Returns ``(new_state, realization, delayed, tau)`` where ``delayed``
    [m] marks nodes participating through their ring snapshot this step
    and ``tau`` [m] is each node's current delay (0 for fresh nodes).
    The per-step key split mirrors `scenarios.realize` exactly, and each
    chain's transition reads a single uniform region per state, so the
    degenerate parameters (burst_up = 1 − burst_down, rejoin = 1 − leave)
    reproduce the i.i.d. draws bitwise.
    """
    m, d = arrays.nbrs.shape
    kk = jax.random.fold_in(arrays.key, k)
    k_edge, k_node, k_strag = jax.random.split(kk, 3)

    edge_bad = ts.edge_bad
    if scenario.burst_down > 0.0:
        u = edge_uniform(k_edge, arrays.nbrs)
        edge_bad = jnp.where(
            ts.edge_bad, u < 1.0 - scenario.burst_up, u < scenario.burst_down
        )
    node_down = ts.node_down
    if scenario.leave > 0.0:
        u = jax.random.uniform(k_node, (m,))
        node_down = jnp.where(
            ts.node_down, u < 1.0 - scenario.rejoin, u < scenario.leave
        )
    late = ts.late
    if scenario.straggle_on > 0.0:
        # session chain over the same single k_strag uniform region the
        # i.i.d. draw reads (bernoulli == uniform < p), so the degenerate
        # pair straggle_off = 1 − straggle_on is bitwise the i.i.d. path
        u = jax.random.uniform(k_strag, (m,))
        late = jnp.where(
            ts.late, u < 1.0 - scenario.straggle_off, u < scenario.straggle_on
        )
        straggler = late
    elif scenario.straggler > 0.0:
        straggler = jax.random.bernoulli(k_strag, scenario.straggler, (m,))
        late = straggler
    else:
        straggler = jnp.zeros((m,), bool)
        late = jnp.zeros((m,), bool)

    edge_up = ~edge_bad
    if scenario.mobile:
        epoch = k // scenario.resample_every
        k_mob = jax.random.fold_in(
            jax.random.fold_in(arrays.key, _MOBILITY_FOLD), epoch
        )
        edge_up = edge_up & (
            edge_uniform(k_mob, arrays.nbrs) < scenario.mobility_keep
        )

    alive = ~node_down
    age = jnp.where(straggler, ts.age + 1, 0)
    if scenario.staleness > 0:
        delayed = straggler & alive & (age <= scenario.staleness)
    else:
        delayed = jnp.zeros((m,), bool)
    excluded = straggler & ~delayed
    realization = realization_from_masks(arrays, edge_up, alive, excluded)
    tau = jnp.where(delayed, age, 0)
    return TemporalState(edge_bad, node_down, age, late), realization, delayed, tau


def ring_init(params_stacked: object, staleness: int) -> Optional[object]:
    """[D, m, ...] snapshot ring seeded with the initial parameters (a node
    delayed at step k < tau reads the initial point, the correct t=0
    truncation).  None when staleness is off — the carry stays unchanged
    and the traced program is exactly the ring-free one."""
    if staleness <= 0:
        return None
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (staleness,) + x.shape).copy(),
        params_stacked,
    )


def ring_push(ring: object, params_stacked: object, k: jax.Array,
              staleness: int) -> object:
    """Write the parameters at the start of step ``k`` into slot k mod D
    (done *after* the step's reads: slot (k − tau) mod D still held
    x^{k−tau} for every tau ≤ D while step k was realized)."""
    slot = jnp.mod(k, staleness)
    return jax.tree_util.tree_map(
        lambda r, x: r.at[slot].set(x), ring, params_stacked
    )
