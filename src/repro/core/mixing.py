"""Gossip mixing operators: dense matrix vs padded neighbor exchange.

Every baseline DFL algorithm applies the doubly-stochastic matrix B of
Assumption 1 to node-stacked pytrees: out_i = sum_j B_ji x_j.  Simulated
with a dense einsum that is O(m²·n) even on the rings/grids the DFL
literature targets, where only O(m·deg) entries of B are nonzero.  This
module provides the sparse alternative: a padded-neighbor gather with
Metropolis weights in [m, max_degree+1] form (`Topology.mixing_padded`),
O(m·deg·n), plus the variants the baselines need (lazy B−I for BEER,
(I+B)/2 for NIDS, the off-diagonal/diagonal split for quantized NIDS).

All padded-form gossip — static mixers, per-step scenario mixers, the
temporal/stale path, and PaME's partial exchange (`repro.core.pme`) —
routes through ONE neighbor-contraction core, `gather_terms`, with
three interchangeable implementations:

  * impl="slots"  — one gather + multiply-add per neighbor slot,
    accumulated sequentially in ascending slot order (unrolled under
    `_UNROLL_MAX_SLOTS`, `lax.scan` beyond).  XLA fuses the chain into a
    single pass over the output, which makes this the fastest form on
    CPU, and the sequential order is what the "dense"/"sparse"
    bit-identity guarantee below is predicated on.
  * impl="segsum" — the padded table is flattened once into an [m·k]
    edge list and each term is aggregated with two gathers plus one
    `jax.ops.segment_sum` over receiver-id segments (padding slots are
    routed to a dead segment and discarded).  The traced program is O(1)
    ops regardless of the degree — the form that scales on TPU/GPU where
    scatter-add is parallel.  Results agree with "slots" to fp tolerance
    only (different reduction order).
  * impl="pallas" — the fused kernel (`repro.kernels.gossip`): per
    receiver-row block the padded table is scattered into a dense
    on-chip matrix and contracted with one MXU matmul per term
    (gather→contract→scatter in one kernel, shared-weight terms share
    one scatter build).  Runs under the Pallas interpreter on CPU.
    Agrees with "slots" to fp tolerance (matmul reduction order).

The default is backend-gated (`default_impl`): "slots" on CPU — where
XLA serializes scatter and the fused chain wins at every degree — and
"segsum" elsewhere ("pallas" is opt-in until validated per backend);
override per call, per `Mixer`, or process-wide with the
`REPRO_GOSSIP_IMPL` environment variable.

Three `Mixer` modes:

  * "sparse" — padded gather over N_i ∪ {i}; the default for the
    algorithm registry.  Slots accumulate sequentially in ascending
    sender order.
  * "dense"  — the escape hatch: the *same* padded gather over the full
    [m, m] connectivity (non-edges carry weight exactly 0.0).  Because a
    0.0 contribution is an exact IEEE no-op and both modes sum the real
    terms in the same ascending order, "dense" and "sparse" are
    bit-identical under impl="slots" — the property the equivalence
    tests pin (impl="segsum" agrees to fp tolerance instead).
  * "matrix" — the legacy dense einsum (`jnp.einsum("ji,j...->i...")`).
    What raw `[m, m]` array call sites get via `as_mixer`; kept as the
    BLAS-backed reference and the "dense" column of `bench_mixing`.
"""
from __future__ import annotations

import dataclasses
import os
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = [
    "PaddedMixing", "Mixer", "mix_padded", "make_mixer", "as_mixer",
    "ring_gather", "gather_terms", "default_impl", "mix_replicated",
    "IMPLS",
]

# Above this many slots the per-slot python unroll is replaced by a
# lax.scan (compile-time guard for the full-connectivity "dense" mode at
# very large m).  The scan accumulates in the same ascending order but XLA
# fuses its body differently, so bit-identity with an unrolled counterpart
# only holds below this threshold — tests and the "dense" escape hatch
# stay under it; tolerance-level equivalence holds regardless.
_UNROLL_MAX_SLOTS = 128

# The closed set of gossip contraction implementations.  Every entry
# point that accepts an impl — the env var, `gather_terms(impl=...)`,
# `make_mixer(impl=...)` — validates against this one tuple so a typo
# fails identically loudly everywhere instead of silently falling
# through to a default.
IMPLS = ("slots", "segsum", "pallas")


def _check_impl(impl: str, source: str = "impl") -> str:
    if impl not in IMPLS:
        raise ValueError(
            f"{source}={impl!r}; expected one of {', '.join(map(repr, IMPLS))}"
        )
    return impl


def default_impl() -> str:
    """Resolve the gossip contraction implementation for this process.

    `REPRO_GOSSIP_IMPL` (= "slots" | "segsum" | "pallas") wins; otherwise
    "slots" on CPU (XLA serializes scatter there — measured 10–60× slower
    than the fused chain at every degree) and "segsum" on accelerators
    (O(1) traced ops, parallel scatter-add).  "pallas" — the fused kernel
    — is never the default: it is opt-in per backend until the
    `bench_gossip` roofline race validates it there.
    """
    env = os.environ.get("REPRO_GOSSIP_IMPL")
    if env:
        return _check_impl(env, "REPRO_GOSSIP_IMPL")
    return "slots" if jax.default_backend() == "cpu" else "segsum"


class PaddedMixing(NamedTuple):
    """A mixing matrix in padded neighbor-exchange form.

    nbrs[i, slot] lists N_i ∪ {i} (padding repeats i), w[i, slot] is the
    receive weight B[nbrs[i, slot], i] (exactly 0.0 on padding), and
    is_self marks the slot holding the receiver itself.

    Slot order is layout-defined: `Topology.mixing_padded` lists N_i ∪ {i}
    ascending, which is what the dense/sparse bit-identity guarantee in
    this module's header is predicated on.  Per-step scenario mixers
    (`repro.core.scenarios.scenario_mixer`) use a neighbors-then-self
    layout instead — correct to fp tolerance, but *not* bit-identical to
    an ascending-ordered counterpart.
    """

    nbrs: jax.Array     # [m, k] int32
    w: jax.Array        # [m, k] float32
    is_self: jax.Array  # [m, k] bool
    pad: Optional[jax.Array] = None  # [m, k] bool — structural padding
    #                                  slots (weight exactly 0.0); lets the
    #                                  segment-sum path route them to a
    #                                  dead segment instead of trusting the
    #                                  zero weight.  None = no padding info
    #                                  (e.g. the full-connectivity form).

    @property
    def m(self) -> int:
        return self.nbrs.shape[0]

    @property
    def self_weight(self) -> jax.Array:
        """[m] — the diagonal B_ii, recovered from the self slot."""
        return jnp.sum(jnp.where(self.is_self, self.w, 0.0), axis=1)

    def with_weights(self, w: jax.Array) -> "PaddedMixing":
        return PaddedMixing(self.nbrs, w, self.is_self, self.pad)


def _bcast(v: jax.Array, x: jax.Array) -> jax.Array:
    """Reshape a per-node vector [m] for broadcasting over leaf x [m, ...]."""
    return v.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)


def _gather_terms_slots(
    nbrs: jax.Array, terms: Sequence[Tuple[jax.Array, jax.Array]]
) -> Tuple[jax.Array, ...]:
    """Fused per-slot chain: one gather + multiply-add per slot per term,
    sequential in ascending slot order (bit-stable across padding)."""
    k = nbrs.shape[1]
    if k <= _UNROLL_MAX_SLOTS:
        accs = tuple(
            _bcast(w[:, 0], x) * x[nbrs[:, 0]] for w, x in terms
        )
        for slot in range(1, k):
            j = nbrs[:, slot]
            accs = tuple(
                acc + _bcast(w[:, slot], x) * x[j]
                for acc, (w, x) in zip(accs, terms)
            )
        return accs

    def body(accs, slot):
        j, ws = slot[0], slot[1:]
        return tuple(
            acc + _bcast(wk, x) * x[j]
            for acc, wk, (_, x) in zip(accs, ws, terms)
        ), None

    init = tuple(jnp.zeros_like(x) for _, x in terms)
    xs = (nbrs.T,) + tuple(w.T for w, _ in terms)
    accs, _ = jax.lax.scan(body, init, xs)
    return accs


def _gather_terms_segsum(
    nbrs: jax.Array,
    terms: Sequence[Tuple[jax.Array, jax.Array]],
    pad: Optional[jax.Array],
) -> Tuple[jax.Array, ...]:
    """Edge-list segment-sum: flatten the padded table to an [m·k] edge
    list once, then per term two gathers (sender values, flat weights) +
    one `jax.ops.segment_sum` over receiver-id segments.  Padding slots
    are routed to a dead segment m and sliced away, so poisoned padding
    values can never leak into a real receiver row."""
    m, k = nbrs.shape
    senders = nbrs.reshape(-1)
    rows = jnp.broadcast_to(
        jnp.arange(m, dtype=jnp.int32)[:, None], (m, k)
    )
    if pad is None:
        recv = rows.reshape(-1)
        num_segments, sorted_ids = m, True
    else:
        recv = jnp.where(pad, m, rows).reshape(-1)
        num_segments, sorted_ids = m + 1, False
    outs = []
    for w, x in terms:
        vals = _bcast(w.reshape(-1), x) * x[senders]
        seg = jax.ops.segment_sum(
            vals, recv, num_segments=num_segments,
            indices_are_sorted=sorted_ids,
        )
        outs.append(seg[:m])
    return tuple(outs)


def gather_terms(
    nbrs: jax.Array,                                  # [m, k] padded table
    terms: Sequence[Tuple[jax.Array, jax.Array]],     # ([m, k] w, [m, ...] x)
    *,
    pad: Optional[jax.Array] = None,                  # [m, k] padding slots
    impl: Optional[str] = None,
) -> Tuple[jax.Array, ...]:
    """One-pass neighbor contraction shared by every padded gossip path.

    For each (w, x) term returns out_i = sum_slot w[i, slot] ·
    x[nbrs[i, slot]].  Multiple terms ride the same slot walk (PME needs
    payload *and* mask counts per exchange), so the neighbor table is
    traversed once however many aggregates are needed.

    impl="slots" is the sequential fused chain (CPU default, bit-stable
    slot order); impl="segsum" flattens to an [m·k] edge list and
    aggregates with `jax.ops.segment_sum` per term — O(1) traced ops at
    any degree, padding routed to a dead segment (accelerator default);
    impl="pallas" is the fused gather→contract→scatter kernel
    (`repro.kernels.gossip`, interpret mode on CPU).  See `default_impl`.
    """
    impl = default_impl() if impl is None else _check_impl(impl)
    if impl == "slots":
        return _gather_terms_slots(nbrs, terms)
    if impl == "segsum":
        return _gather_terms_segsum(nbrs, terms, pad)
    from repro.kernels.gossip.ops import gather_terms_pallas

    return gather_terms_pallas(nbrs, terms, pad=pad)


def mix_padded(pm: PaddedMixing, tree: object, impl: Optional[str] = None) -> object:
    """Gossip out_i = sum_slot w[i,slot] · x[nbrs[i,slot]] for every leaf.

    O(m·k·n) data movement instead of the O(m²·n) dense einsum, through
    the shared `gather_terms` core.  Under impl="slots" the accumulation
    order is ascending sender id independent of padding, so sparse and
    full-connectivity padded forms agree bitwise; impl="segsum" agrees to
    fp tolerance.
    """
    return jax.tree_util.tree_map(
        lambda x: gather_terms(
            pm.nbrs, [(pm.w, x)], pad=pm.pad, impl=impl
        )[0],
        tree,
    )


def ring_gather(
    ring: object,        # pytree, leaves [D, m, ...] — snapshot ring buffer
    fresh: object,       # pytree, leaves [m, ...] — this step's live values
    slot: jax.Array,     # [m] i32 — ring slot holding each node's snapshot
    use_ring: jax.Array  # [m] bool — gather from the ring instead of fresh
) -> object:
    """Per-sender delayed gather: node j's effective value is its ring
    snapshot ``ring[slot[j], j]`` where ``use_ring[j]``, else ``fresh[j]``.

    This is how bounded-staleness gossip reads t-delayed parameters out of
    the scan-carried snapshot ring: the substituted tree then flows
    through the ordinary padded mixing (`mix_padded`/`Mixer`), so every
    receiver of a delayed node consistently mixes the same delayed copy —
    the property the mean-preservation argument needs.  All indices are
    per-node gathers (O(m·n)); the ring never leaves the device.
    """
    m = slot.shape[0]
    node = jnp.arange(m, dtype=jnp.int32)

    def one(r, f):
        keep = use_ring.reshape((m,) + (1,) * (f.ndim - 1))
        return jnp.where(keep, r[slot, node], f)

    return jax.tree_util.tree_map(one, ring, fresh)


def mix_replicated(
    w_off: jax.Array,    # [m, d] off-diagonal receive weights (0 on padding)
    self_w: jax.Array,   # [m] diagonal weight B_ii
    replicas: object,    # pytree, leaves [m, d, ...] — receiver-held copies
    own: object,         # pytree, leaves [m, ...] — receiver's own value
) -> object:
    """Mix per-receiver surrogate replicas: out_i = Σ_s w_off[i,s] ·
    replicas[i,s] + self_w[i] · own[i].

    Unlike `mix_padded` there is NO cross-node gather: under message-level
    fault injection (`repro.core.faults`) each receiver mixes the copy *it*
    holds of every neighbor's surrogate — which desyncs from the sender's
    truth when an innovation message is lost — so the contraction is a
    receiver-local weighted sum over the slot axis.  This is the padded
    [m, d, ...] realization of the conceptual [m, m, ...] replica state
    (only actual neighbors hold replicas).
    """

    def one(rep, o):
        w = w_off.reshape(w_off.shape + (1,) * (rep.ndim - 2)).astype(rep.dtype)
        return jnp.sum(w * rep, axis=1) + _bcast(self_w, o) * o

    return jax.tree_util.tree_map(one, replicas, own)


def _dense_padded(bmat: jax.Array) -> PaddedMixing:
    """Full-connectivity padded form: every sender is a slot (ascending)."""
    m = bmat.shape[0]
    nbrs = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (m, m))
    w = bmat.T.astype(jnp.float32)  # w[i, j] = B[j, i]
    is_self = jnp.eye(m, dtype=bool)
    return PaddedMixing(nbrs, w, is_self)


@dataclasses.dataclass(frozen=True)
class Mixer:
    """Gossip operator with interchangeable dense / sparse implementations.

    `b` is the dense [m, m] matrix (reference + wire accounting); it is
    required by the "matrix"/"dense" modes but may be None for "sparse"
    mixers built per step inside a traced scenario step, where
    materializing [m, m] would defeat the padded form.  `pm` is the padded
    form used by the "dense"/"sparse" modes.  `impl` picks the neighbor
    contraction ("slots" | "segsum" | None = `default_impl`).
    """

    mode: str                       # "matrix" | "dense" | "sparse"
    b: Optional[jax.Array]          # [m, m], or None for per-step sparse
    pm: Optional[PaddedMixing] = None
    impl: Optional[str] = None      # gossip contraction implementation

    @property
    def m(self) -> int:
        return self.pm.m if self.b is None else self.b.shape[0]

    def mix(self, tree: object) -> object:
        """out_i = sum_j B_ji x_j."""
        if self.mode == "matrix":
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", self.b.astype(x.dtype), x),
                tree,
            )
        return mix_padded(self.pm, tree, impl=self.impl)

    def mix_lazy(self, tree: object) -> object:
        """(B − I) x — the gossip increment used by BEER."""
        if self.mode == "matrix":
            w = self.b - jnp.eye(self.m, dtype=self.b.dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", w.astype(x.dtype), x), tree
            )
        return jax.tree_util.tree_map(
            lambda mx, x: mx - x, mix_padded(self.pm, tree, impl=self.impl), tree
        )

    def mix_half(self, tree: object) -> object:
        """((I + B)/2) x — the NIDS averaging operator Ã."""
        if self.mode == "matrix":
            a_tilde = 0.5 * (jnp.eye(self.m, dtype=self.b.dtype) + self.b)
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", a_tilde.astype(x.dtype), x),
                tree,
            )
        return jax.tree_util.tree_map(
            lambda mx, x: 0.5 * (mx + x).astype(x.dtype),
            mix_padded(self.pm, tree, impl=self.impl), tree,
        )

    def mix_nids_quantized(self, hats: object, u: object) -> object:
        """off(Ã)·hats + diag(Ã)·u, Ã = (I+B)/2 — quantized NIDS mixing,
        where each node keeps its own exact copy u_i and only off-diagonal
        traffic moves through the lossy surrogates."""
        if self.mode == "matrix":
            a_tilde = 0.5 * (jnp.eye(self.m, dtype=self.b.dtype) + self.b)
            diag = jnp.diag(a_tilde)
            off = a_tilde - jnp.diag(diag)
            return jax.tree_util.tree_map(
                lambda uh, ue: jnp.einsum("ji,j...->i...", off.astype(uh.dtype), uh)
                + ue * diag.reshape((-1,) + (1,) * (ue.ndim - 1)).astype(ue.dtype),
                hats, u,
            )
        sw = self.pm.self_weight  # B_ii
        mixed = mix_padded(self.pm, hats, impl=self.impl)

        def one(mx, h, ue):
            return (0.5 * (mx - _bcast(sw, h) * h)
                    + _bcast(0.5 * (1.0 + sw), ue) * ue).astype(ue.dtype)

        return jax.tree_util.tree_map(one, mixed, hats, u)


def make_mixer(topo, mode: str = "sparse", impl: Optional[str] = None) -> Mixer:
    """Build a Mixer from a `repro.core.topology.Topology`.

    mode="sparse" gathers over N_i ∪ {i} (O(m·deg·n)); mode="dense" runs
    the same gather over full connectivity (bit-identical to "sparse"
    under impl="slots"); mode="matrix" is the legacy dense einsum.
    `impl` picks the neighbor contraction ("slots" | "segsum" |
    "pallas"; None = `default_impl`).
    """
    if impl is not None:
        _check_impl(impl)
    b = jnp.asarray(topo.mixing)
    if mode == "matrix":
        return Mixer("matrix", b)
    if mode == "dense":
        return Mixer("dense", b, _dense_padded(b), impl)
    if mode != "sparse":
        raise ValueError(f"unknown mixing mode {mode!r}")
    nbrs, w, is_self = topo.mixing_padded()
    nbrs = jnp.asarray(nbrs)
    is_self = jnp.asarray(is_self)
    # padding slots repeat the row's own id without being the self slot
    pad = (nbrs == jnp.arange(nbrs.shape[0])[:, None]) & ~is_self
    return Mixer(
        "sparse", b,
        PaddedMixing(nbrs, jnp.asarray(w), is_self, pad),
        impl,
    )


def as_mixer(b: Union[Mixer, jax.Array]) -> Mixer:
    """Normalize a step-function operand: raw [m, m] arrays keep the legacy
    einsum semantics; Mixer instances pass through."""
    if isinstance(b, Mixer):
        return b
    return Mixer("matrix", b)
