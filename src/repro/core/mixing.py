"""Gossip mixing operators: dense matrix vs padded neighbor exchange.

Every baseline DFL algorithm applies the doubly-stochastic matrix B of
Assumption 1 to node-stacked pytrees: out_i = sum_j B_ji x_j.  Simulated
with a dense einsum that is O(m²·n) even on the rings/grids the DFL
literature targets, where only O(m·deg) entries of B are nonzero.  This
module provides the sparse alternative: a padded-neighbor gather with
Metropolis weights in [m, max_degree+1] form (`Topology.mixing_padded`),
O(m·deg·n), plus the variants the baselines need (lazy B−I for BEER,
(I+B)/2 for NIDS, the off-diagonal/diagonal split for quantized NIDS).

Three `Mixer` modes:

  * "sparse" — padded gather over N_i ∪ {i}; the default for the
    algorithm registry.  Slots accumulate sequentially in ascending
    sender order.
  * "dense"  — the escape hatch: the *same* padded gather over the full
    [m, m] connectivity (non-edges carry weight exactly 0.0).  Because a
    0.0 contribution is an exact IEEE no-op and both modes sum the real
    terms in the same ascending order, "dense" and "sparse" are
    bit-identical — the property the equivalence tests pin.
  * "matrix" — the legacy dense einsum (`jnp.einsum("ji,j...->i...")`).
    What raw `[m, m]` array call sites get via `as_mixer`; kept as the
    BLAS-backed reference and the "dense" column of `bench_mixing`.

Sequential slot accumulation (unrolled under ~16 slots, `lax.scan`
beyond) keeps the floating-point order independent of the slot count, so
the "dense"/"sparse" bit-identity holds on any backend.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

__all__ = [
    "PaddedMixing", "Mixer", "mix_padded", "make_mixer", "as_mixer",
    "ring_gather",
]

# Above this many slots the per-slot python unroll is replaced by a
# lax.scan (compile-time guard for the full-connectivity "dense" mode at
# very large m).  The scan accumulates in the same ascending order but XLA
# fuses its body differently, so bit-identity with an unrolled counterpart
# only holds below this threshold — tests and the "dense" escape hatch
# stay under it; tolerance-level equivalence holds regardless.
_UNROLL_MAX_SLOTS = 128


class PaddedMixing(NamedTuple):
    """A mixing matrix in padded neighbor-exchange form.

    nbrs[i, slot] lists N_i ∪ {i} (padding repeats i), w[i, slot] is the
    receive weight B[nbrs[i, slot], i] (exactly 0.0 on padding), and
    is_self marks the slot holding the receiver itself.

    Slot order is layout-defined: `Topology.mixing_padded` lists N_i ∪ {i}
    ascending, which is what the dense/sparse bit-identity guarantee in
    this module's header is predicated on.  Per-step scenario mixers
    (`repro.core.scenarios.scenario_mixer`) use a neighbors-then-self
    layout instead — correct to fp tolerance, but *not* bit-identical to
    an ascending-ordered counterpart.
    """

    nbrs: jax.Array     # [m, k] int32
    w: jax.Array        # [m, k] float32
    is_self: jax.Array  # [m, k] bool

    @property
    def m(self) -> int:
        return self.nbrs.shape[0]

    @property
    def self_weight(self) -> jax.Array:
        """[m] — the diagonal B_ii, recovered from the self slot."""
        return jnp.sum(jnp.where(self.is_self, self.w, 0.0), axis=1)

    def with_weights(self, w: jax.Array) -> "PaddedMixing":
        return PaddedMixing(self.nbrs, w, self.is_self)


def _bcast(v: jax.Array, x: jax.Array) -> jax.Array:
    """Reshape a per-node vector [m] for broadcasting over leaf x [m, ...]."""
    return v.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)


def _leaf_mix_padded(pm: PaddedMixing, x: jax.Array) -> jax.Array:
    k = pm.nbrs.shape[1]
    if k <= _UNROLL_MAX_SLOTS:
        acc = _bcast(pm.w[:, 0], x) * x[pm.nbrs[:, 0]]
        for slot in range(1, k):
            acc = acc + _bcast(pm.w[:, slot], x) * x[pm.nbrs[:, slot]]
        return acc

    def body(acc, slot):
        nb, wk = slot
        return acc + _bcast(wk, x) * x[nb], None

    acc, _ = jax.lax.scan(body, jnp.zeros_like(x), (pm.nbrs.T, pm.w.T))
    return acc


def mix_padded(pm: PaddedMixing, tree: object) -> object:
    """Gossip out_i = sum_slot w[i,slot] · x[nbrs[i,slot]] for every leaf.

    O(m·k·n) gathers + multiply-adds instead of the O(m²·n) dense einsum;
    the per-slot accumulation order is ascending sender id, independent of
    the padding, so sparse and full-connectivity padded forms agree bitwise.
    """
    return jax.tree_util.tree_map(lambda x: _leaf_mix_padded(pm, x), tree)


def ring_gather(
    ring: object,        # pytree, leaves [D, m, ...] — snapshot ring buffer
    fresh: object,       # pytree, leaves [m, ...] — this step's live values
    slot: jax.Array,     # [m] i32 — ring slot holding each node's snapshot
    use_ring: jax.Array  # [m] bool — gather from the ring instead of fresh
) -> object:
    """Per-sender delayed gather: node j's effective value is its ring
    snapshot ``ring[slot[j], j]`` where ``use_ring[j]``, else ``fresh[j]``.

    This is how bounded-staleness gossip reads t-delayed parameters out of
    the scan-carried snapshot ring: the substituted tree then flows
    through the ordinary padded mixing (`mix_padded`/`Mixer`), so every
    receiver of a delayed node consistently mixes the same delayed copy —
    the property the mean-preservation argument needs.  All indices are
    per-node gathers (O(m·n)); the ring never leaves the device.
    """
    m = slot.shape[0]
    node = jnp.arange(m, dtype=jnp.int32)

    def one(r, f):
        keep = use_ring.reshape((m,) + (1,) * (f.ndim - 1))
        return jnp.where(keep, r[slot, node], f)

    return jax.tree_util.tree_map(one, ring, fresh)


def _dense_padded(bmat: jax.Array) -> PaddedMixing:
    """Full-connectivity padded form: every sender is a slot (ascending)."""
    m = bmat.shape[0]
    nbrs = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (m, m))
    w = bmat.T.astype(jnp.float32)  # w[i, j] = B[j, i]
    is_self = jnp.eye(m, dtype=bool)
    return PaddedMixing(nbrs, w, is_self)


@dataclasses.dataclass(frozen=True)
class Mixer:
    """Gossip operator with interchangeable dense / sparse implementations.

    `b` is the dense [m, m] matrix (reference + wire accounting); it is
    required by the "matrix"/"dense" modes but may be None for "sparse"
    mixers built per step inside a traced scenario step, where
    materializing [m, m] would defeat the padded form.  `pm` is the padded
    form used by the "dense"/"sparse" modes.
    """

    mode: str                       # "matrix" | "dense" | "sparse"
    b: Optional[jax.Array]          # [m, m], or None for per-step sparse
    pm: Optional[PaddedMixing] = None

    @property
    def m(self) -> int:
        return self.pm.m if self.b is None else self.b.shape[0]

    def mix(self, tree: object) -> object:
        """out_i = sum_j B_ji x_j."""
        if self.mode == "matrix":
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", self.b.astype(x.dtype), x),
                tree,
            )
        return mix_padded(self.pm, tree)

    def mix_lazy(self, tree: object) -> object:
        """(B − I) x — the gossip increment used by BEER."""
        if self.mode == "matrix":
            w = self.b - jnp.eye(self.m, dtype=self.b.dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", w.astype(x.dtype), x), tree
            )
        return jax.tree_util.tree_map(
            lambda mx, x: mx - x, mix_padded(self.pm, tree), tree
        )

    def mix_half(self, tree: object) -> object:
        """((I + B)/2) x — the NIDS averaging operator Ã."""
        if self.mode == "matrix":
            a_tilde = 0.5 * (jnp.eye(self.m, dtype=self.b.dtype) + self.b)
            return jax.tree_util.tree_map(
                lambda x: jnp.einsum("ji,j...->i...", a_tilde.astype(x.dtype), x),
                tree,
            )
        return jax.tree_util.tree_map(
            lambda mx, x: 0.5 * (mx + x).astype(x.dtype),
            mix_padded(self.pm, tree), tree,
        )

    def mix_nids_quantized(self, hats: object, u: object) -> object:
        """off(Ã)·hats + diag(Ã)·u, Ã = (I+B)/2 — quantized NIDS mixing,
        where each node keeps its own exact copy u_i and only off-diagonal
        traffic moves through the lossy surrogates."""
        if self.mode == "matrix":
            a_tilde = 0.5 * (jnp.eye(self.m, dtype=self.b.dtype) + self.b)
            diag = jnp.diag(a_tilde)
            off = a_tilde - jnp.diag(diag)
            return jax.tree_util.tree_map(
                lambda uh, ue: jnp.einsum("ji,j...->i...", off.astype(uh.dtype), uh)
                + ue * diag.reshape((-1,) + (1,) * (ue.ndim - 1)).astype(ue.dtype),
                hats, u,
            )
        sw = self.pm.self_weight  # B_ii
        mixed = mix_padded(self.pm, hats)

        def one(mx, h, ue):
            return (0.5 * (mx - _bcast(sw, h) * h)
                    + _bcast(0.5 * (1.0 + sw), ue) * ue).astype(ue.dtype)

        return jax.tree_util.tree_map(one, mixed, hats, u)


def make_mixer(topo, mode: str = "sparse") -> Mixer:
    """Build a Mixer from a `repro.core.topology.Topology`.

    mode="sparse" gathers over N_i ∪ {i} (O(m·deg·n)); mode="dense" runs
    the same gather over full connectivity (bit-identical to "sparse");
    mode="matrix" is the legacy dense einsum.
    """
    b = jnp.asarray(topo.mixing)
    if mode == "matrix":
        return Mixer("matrix", b)
    if mode == "dense":
        return Mixer("dense", b, _dense_padded(b))
    if mode != "sparse":
        raise ValueError(f"unknown mixing mode {mode!r}")
    nbrs, w, is_self = topo.mixing_padded()
    return Mixer(
        "sparse", b,
        PaddedMixing(jnp.asarray(nbrs), jnp.asarray(w), jnp.asarray(is_self)),
    )


def as_mixer(b: Union[Mixer, jax.Array]) -> Mixer:
    """Normalize a step-function operand: raw [m, m] arrays keep the legacy
    einsum semantics; Mixer instances pass through."""
    if isinstance(b, Mixer):
        return b
    return Mixer("matrix", b)
