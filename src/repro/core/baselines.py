"""Baseline DFL algorithms the paper compares against (Sec. V-D):

  * D-PSGD      (Lian et al., 2017)       — gossip + local SGD
  * DFedSAM     (Shi et al., 2023)        — SAM local step + gossip
  * CHOCO-SGD   (Koloskova et al., 2020)  — compressed gossip, error feedback
  * BEER        (Zhao et al., 2022)       — compressed gradient tracking
  * (AN)Q-NIDS  (Michelusi et al., 2022)  — NIDS with quantized messages

All operate on node-stacked pytrees [m, ...] and a doubly-stochastic mixing
matrix B (Assumption 1), mirroring `repro.core.pame` so the benchmark
harness can swap algorithms behind one interface.

Every step function takes the gossip operator as `b`: either a raw [m, m]
matrix (legacy dense-einsum semantics) or a `repro.core.mixing.Mixer`,
whose "sparse" mode contracts the node axis through the padded
neighbor-exchange form — O(m·deg·n) instead of O(m²·n).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.compression import Compressor, identity
from repro.core.mixing import Mixer, as_mixer

GradFn = Callable[[object, object, jax.Array], Tuple[jax.Array, object]]
MixOp = Union[jax.Array, Mixer]

__all__ = [
    "DPSGDState", "dpsgd_init", "dpsgd_step",
    "DFedSAMState", "dfedsam_init", "dfedsam_step",
    "ChocoState", "choco_init", "choco_step",
    "BeerState", "beer_init", "beer_step",
    "NidsState", "nids_init", "nids_step",
    "stack_params", "run_algorithm",
]


def stack_params(params0: object, m: int) -> object:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0
    )


def _mix(b: MixOp, tree: object) -> object:
    """Gossip: out_i = sum_j B_ji x_j for every leaf."""
    if isinstance(b, Mixer):
        return b.mix(tree)
    return jax.tree_util.tree_map(
        lambda x: jnp.einsum("ji,j...->i...", b.astype(x.dtype), x), tree
    )


def _axpy(a: float, x: object, y: object) -> object:
    return jax.tree_util.tree_map(lambda u, v: a * u + v, x, y)


def _sub(x: object, y: object) -> object:
    return jax.tree_util.tree_map(lambda u, v: u - v, x, y)


def _add(x: object, y: object) -> object:
    return jax.tree_util.tree_map(lambda u, v: u + v, x, y)


def _compress_tree(comp: Compressor, key: jax.Array, tree: object) -> object:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for idx, leaf in enumerate(leaves):
        m = leaf.shape[0]
        flat = leaf.reshape(m, -1)
        out.append(
            comp.apply(jax.random.fold_in(key, idx), flat).reshape(leaf.shape)
        )
    return jax.tree_util.tree_unflatten(treedef, out)


def _node_grads(grad_fn: GradFn, params: object, batch: object, key: jax.Array):
    leaves = jax.tree_util.tree_leaves(params)
    m = leaves[0].shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(grad_fn)(params, batch, keys)


def _shifted(tree: object, shift: Optional[object]) -> object:
    """Gradient-evaluation point under message-only delay.

    The bounded-staleness wrapper substitutes each delayed node's ring
    snapshot into the parameter stack so the *network* sees the delayed
    copy, and passes ``shift = fresh − delayed`` (exactly zero rows for
    non-delayed nodes).  Adding it back at every gradient call evaluates
    the local gradient on the undelayed iterate — the (1 − B_jj) split:
    the post-step full-innovation re-add restores the self-weighted B_jj
    share plus the (1 − B_jj) mean-bookkeeping share of the fresh point,
    while the gradient never pays the delay.  None = classic semantics.
    """
    return tree if shift is None else _add(tree, shift)


# --------------------------------------------------------------------------
# D-PSGD
# --------------------------------------------------------------------------
class DPSGDState(NamedTuple):
    params: object
    step: jax.Array
    key: jax.Array


def dpsgd_init(key: jax.Array, params_stacked: object) -> DPSGDState:
    return DPSGDState(params_stacked, jnp.zeros((), jnp.int32), key)


def dpsgd_step(
    state: DPSGDState, batch: object, grad_fn: GradFn, b: MixOp, lr: float,
    grad_shift: Optional[object] = None,
) -> Tuple[DPSGDState, dict]:
    key = jax.random.fold_in(state.key, state.step)
    losses, grads = _node_grads(
        grad_fn, _shifted(state.params, grad_shift), batch, key
    )
    mixed = _mix(b, state.params)
    new_params = _axpy(-lr, grads, mixed)
    return (
        DPSGDState(new_params, state.step + 1, state.key),
        {"loss_mean": jnp.mean(losses)},
    )


# --------------------------------------------------------------------------
# DFedSAM — sharpness-aware local step, then gossip
# --------------------------------------------------------------------------
class DFedSAMState(NamedTuple):
    params: object
    step: jax.Array
    key: jax.Array


def dfedsam_init(key: jax.Array, params_stacked: object) -> DFedSAMState:
    return DFedSAMState(params_stacked, jnp.zeros((), jnp.int32), key)


def dfedsam_step(
    state: DFedSAMState,
    batch: object,
    grad_fn: GradFn,
    b: MixOp,
    lr: float,
    rho: float = 0.05,
    local_steps: int = 1,
    grad_shift: Optional[object] = None,
) -> Tuple[DFedSAMState, dict]:
    key = jax.random.fold_in(state.key, state.step)
    params = state.params
    loss0 = None
    for t in range(local_steps):
        k_t = jax.random.fold_in(key, t)
        # grad_shift is constant through the chain, so p_t + shift walks
        # exactly the undelayed local chain (delay hits only the mixed,
        # transmitted iterate): p_t = eff + Σ updates ⇒ p_t + shift =
        # fresh + Σ updates, the very points the fresh chain would visit.
        gp = _shifted(params, grad_shift)
        losses, g1 = _node_grads(grad_fn, gp, batch, k_t)
        if loss0 is None:
            loss0 = jnp.mean(losses)
        # per-node gradient norm for the SAM ascent step
        sq = jax.tree_util.tree_map(
            lambda g: jnp.sum(g.reshape(g.shape[0], -1) ** 2, axis=1), g1
        )
        norm = jnp.sqrt(sum(jax.tree_util.tree_leaves(sq)) + 1e-12)

        def _ascend(p, g):
            s = (rho / norm).reshape((-1,) + (1,) * (p.ndim - 1))
            return p + g * s

        adv = jax.tree_util.tree_map(_ascend, gp, g1)
        _, g2 = _node_grads(grad_fn, adv, batch, jax.random.fold_in(k_t, 1))
        params = _axpy(-lr, g2, params)
    new_params = _mix(b, params)
    return (
        DFedSAMState(new_params, state.step + 1, state.key),
        {"loss_mean": loss0},
    )


# --------------------------------------------------------------------------
# CHOCO-SGD — compressed gossip with error feedback
# --------------------------------------------------------------------------
class ChocoState(NamedTuple):
    params: object   # x_i
    hats: object     # \hat x_i (public surrogates, consistent across nodes)
    step: jax.Array
    key: jax.Array


def choco_init(key: jax.Array, params_stacked: object) -> ChocoState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    return ChocoState(params_stacked, zeros, jnp.zeros((), jnp.int32), key)


def choco_step(
    state: ChocoState,
    batch: object,
    grad_fn: GradFn,
    b: MixOp,
    lr: float,
    comp: Compressor,
    gossip_gamma: float = 0.5,
    grad_shift: Optional[object] = None,
) -> Tuple[ChocoState, dict]:
    key = jax.random.fold_in(state.key, state.step)
    losses, grads = _node_grads(
        grad_fn, _shifted(state.params, grad_shift), batch, key
    )
    half = _axpy(-lr, grads, state.params)               # x^{t+1/2}
    q = _compress_tree(comp, jax.random.fold_in(key, 7), _sub(half, state.hats))
    hats = _add(state.hats, q)                            # \hat x^{t+1}
    mixed = _mix(b, hats)                                 # sum_j B_ji \hat x_j
    correction = jax.tree_util.tree_map(
        lambda mx, h: gossip_gamma * (mx - h), mixed, hats
    )
    new_params = _add(half, correction)
    return (
        ChocoState(new_params, hats, state.step + 1, state.key),
        {"loss_mean": jnp.mean(losses)},
    )


# --------------------------------------------------------------------------
# BEER — compressed gradient tracking (O(1/T) nonconvex rate)
# --------------------------------------------------------------------------
class BeerState(NamedTuple):
    params: object  # x
    h: object       # surrogate of x
    g: object       # gradient tracker
    z: object       # surrogate of g
    prev_grad: object
    step: jax.Array
    key: jax.Array


def beer_init(
    key: jax.Array, params_stacked: object, batch0: object, grad_fn: GradFn
) -> BeerState:
    # distinct buffers per state field: the scan engine donates the carry,
    # and XLA rejects donating an aliased buffer twice (h/z and
    # g/prev_grad share *values* at init, never storage)
    _, g0 = _node_grads(grad_fn, params_stacked, batch0, key)
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    g0_copy = jax.tree_util.tree_map(lambda x: x.copy(), g0)
    return BeerState(
        params_stacked, zeros(), g0, zeros(), g0_copy,
        jnp.zeros((), jnp.int32), key,
    )


def beer_step(
    state: BeerState,
    batch: object,
    grad_fn: GradFn,
    b: MixOp,
    lr: float,
    comp: Compressor,
    gossip_gamma: float = 0.5,
    grad_shift: Optional[object] = None,
) -> Tuple[BeerState, dict]:
    key = jax.random.fold_in(state.key, state.step)
    mx = as_mixer(b)
    # x update: mix surrogates with the lazy operator (B − I), descend tracker
    mix_h = mx.mix_lazy(state.h)
    x_new = jax.tree_util.tree_map(
        lambda x, mh, g: x + gossip_gamma * mh - lr * g,
        state.params, mix_h, state.g,
    )
    h_new = _add(
        state.h,
        _compress_tree(comp, jax.random.fold_in(key, 3), _sub(x_new, state.h)),
    )
    losses, grad_new = _node_grads(
        grad_fn, _shifted(x_new, grad_shift), batch, key
    )
    mix_z = mx.mix_lazy(state.z)
    g_new = jax.tree_util.tree_map(
        lambda g, mz, gn, gp: g + gossip_gamma * mz + gn - gp,
        state.g, mix_z, grad_new, state.prev_grad,
    )
    z_new = _add(
        state.z,
        _compress_tree(comp, jax.random.fold_in(key, 5), _sub(g_new, state.z)),
    )
    return (
        BeerState(x_new, h_new, g_new, z_new, grad_new, state.step + 1, state.key),
        {"loss_mean": jnp.mean(losses)},
    )


# --------------------------------------------------------------------------
# (AN)Q-NIDS — NIDS with (adaptively) quantized messages
# --------------------------------------------------------------------------
class NidsState(NamedTuple):
    params: object  # x^k
    c: object       # running sum of the adapt steps z^s, s < k (memory)
    hat_z: object   # public surrogate of z (quantized innovations)
    hat_c: object   # public surrogate of c (receiver-side accumulation)
    step: jax.Array
    key: jax.Array


def nids_init(
    key: jax.Array,
    params_stacked: object,
    batch0: object = None,
    grad_fn: Optional[GradFn] = None,
    lr: Optional[float] = None,
) -> NidsState:
    """The drop-aware form needs no warm-up gradient: all memory starts at
    zero.  ``batch0``/``grad_fn``/``lr`` are accepted (and ignored) for
    signature compatibility with the pre-rewrite initializer."""
    del batch0, grad_fn, lr
    # distinct zero buffers per field — the donated scan carry must not
    # alias storage across leaves (see beer_init)
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    return NidsState(
        params_stacked, zeros(), zeros(), zeros(),
        jnp.zeros((), jnp.int32), key,
    )


def nids_step(
    state: NidsState,
    batch: object,
    grad_fn: GradFn,
    b: MixOp,
    lr: float,
    comp: Optional[Compressor] = None,
    grad_shift: Optional[object] = None,
) -> Tuple[NidsState, dict]:
    r"""Drop-aware NIDS (exact-diffusion family), Atilde = (I + B)/2:

        z^k     = x^k - lr grad^k                       (adapt)
        x^{k+1} = z^k + (Atilde - I)(2 z^k + c^k)       (correct + combine)
        c^{k+1} = c^k + z^k                             (memory)

    On a static graph this has the same linear-system eigenstructure as
    the textbook ``x^{k+1} = Atilde(2x^k - x^{k-1} - lr (g^k - g^{k-1}))``
    recursion (per Atilde-eigenmode lambda, both contract at sqrt(lambda)),
    but every *memory* term is routed through (Atilde - I) — whose column
    sums over any step's surviving subgraph are exactly zero.  That is the
    drop-aware correction: on time-varying graphs the 2x - x_prev form
    re-injects the pending displacement of nodes that skip a round and
    provably loses the global mean, while this form preserves it for every
    realized doubly-stochastic matrix (see tests/test_invariants.py, which
    now pins NIDS mean preservation under churn instead of xfailing it).

    With comp != None this is the (AN)Q-NIDS variant: nodes transmit the
    quantized *innovation* q = Q(z - hat_z) and both ends update the
    public surrogates (hat_z += q, hat_c += hat_z); only off-diagonal
    traffic is lossy, each node mixes its own exact copy on the diagonal.
    Because z^k converges, innovations (and the quantization error)
    vanish — the paper's "adaptive" finite-bit quantization, emulated
    with difference encoding.

    ``c`` accumulates a consensus component that (Atilde - I) annihilates
    exactly in real arithmetic; over very long runs (>> 10^4 steps) its
    growth puts an fp32 cancellation floor under the correction term.
    """
    key = jax.random.fold_in(state.key, state.step)
    mx = as_mixer(b)
    losses, grad_k = _node_grads(
        grad_fn, _shifted(state.params, grad_shift), batch, key
    )
    z = _axpy(-lr, grad_k, state.params)
    v = jax.tree_util.tree_map(lambda zz, cc: 2.0 * zz + cc, z, state.c)
    if comp is not None:
        q = _compress_tree(comp, jax.random.fold_in(key, 11), _sub(z, state.hat_z))
        hat_z = _add(state.hat_z, q)
        hat_c = _add(state.hat_c, hat_z)
        hat_v = jax.tree_util.tree_map(
            lambda hz, hc: 2.0 * hz + hc, hat_z, state.hat_c
        )
        # (Atilde - I) v with lossy off-diagonal traffic and each node's
        # own exact v on the diagonal: off(A~)·hat_v + (diag(A~) - 1)·v
        corr = _sub(mx.mix_nids_quantized(hat_v, v), v)
    else:
        hat_z, hat_c = state.hat_z, state.hat_c
        # (Atilde - I) v = (B - I) v / 2
        corr = jax.tree_util.tree_map(lambda l: 0.5 * l, mx.mix_lazy(v))
    x_new = _add(z, corr)
    c_new = _add(state.c, z)
    return (
        NidsState(x_new, c_new, hat_z, hat_c, state.step + 1, state.key),
        {"loss_mean": jnp.mean(losses)},
    )


# --------------------------------------------------------------------------
# Generic driver — used by benchmarks to race algorithms fairly
# --------------------------------------------------------------------------
# per-step metrics that join the history only when the step emits them:
# realized wire accounting (dynamic scenarios), staleness, and the
# fault-injection layer's degradation trackers (repro.core.faults)
_OPTIONAL_METRICS = (
    "wire_bits", "alive_nodes", "stale_nodes",
    "col_defect", "mean_drift", "dropped_msgs", "crashed_nodes",
    "repair_bits", "surrogate_desync",
    "queue_depth", "served_reqs", "deferred_nodes",
    "comp_consensus", "comp_mean_gap",
)


def run_algorithm(
    step_fn: Callable,  # (state, batch) -> (state, metrics), already closed over hps
    state,
    batch_fn: Callable[[int], object],
    num_steps: int,
    objective_fn: Optional[Callable[[object], jax.Array]] = None,
    params_of=lambda s: s.params,
    tol_std: float = 1e-3,
    driver: str = "scan",
    chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    step_takes_index: bool = False,
    carries_aux: bool = False,
    aux: object = None,
) -> Tuple[object, dict]:
    """Race driver shared by every baseline.

    driver="scan" (default) uses the fused chunked-`lax.scan` engine
    (`repro.core.engine`): one dispatch per `chunk_size` steps, donated
    state, a single bulk metric readback, and the std termination rule
    evaluated on-device.  driver="host" is the original per-step loop.
    `step_takes_index=True` feeds the global step index as a third step
    argument (dynamic-network scenario steps) on both drivers; their
    realized per-step "wire_bits" metric joins the history when emitted.
    `carries_aux=True` threads the auxiliary carry (temporal Markov state
    + staleness ring) through both drivers; the step then returns
    ``(state, metrics, aux)`` and per-step ``stale_hist`` vectors are
    summed into a run-level ``staleness_hist``.
    """
    import numpy as np

    if driver == "scan":
        state, metrics, info = engine.run_scan_loop(
            step_fn, state, batch_fn, num_steps,
            objective_fn=objective_fn, params_of=params_of,
            tol_std=tol_std, chunk_size=chunk_size,
            step_takes_index=step_takes_index,
            carries_aux=carries_aux, aux=aux,
        )
        history = engine.history_from(
            metrics, info,
            {"loss": "loss_mean", "objective": "objective",
             **{key: key for key in _OPTIONAL_METRICS}},
        )
        for key in _OPTIONAL_METRICS:
            if not history[key]:  # static runs keep the legacy schema
                history.pop(key)
        if "stale_hist" in metrics:
            history["staleness_hist"] = engine.staleness_hist(
                metrics["stale_hist"]
            )
        return state, history
    if driver != "host":
        raise ValueError(f"unknown driver {driver!r}")

    step = jax.jit(step_fn)
    history = {"loss": [], "objective": []}
    hist_rows: list = []
    f_window: list = []
    for k in range(num_steps):
        step_args = (state, batch_fn(k))
        if step_takes_index:
            step_args += (jnp.asarray(k, jnp.int32),)
        if carries_aux:
            state, metrics, aux = step(*step_args, aux)
        else:
            state, metrics = step(*step_args)
        for key in _OPTIONAL_METRICS:
            if key in metrics:
                history.setdefault(key, []).append(float(metrics[key]))
        if "stale_hist" in metrics:
            hist_rows.append(np.asarray(metrics["stale_hist"]))
        history["loss"].append(float(metrics["loss_mean"]))
        if objective_fn is not None:
            mean_params = jax.tree_util.tree_map(
                lambda x: x.mean(axis=0), params_of(state)
            )
            fval = float(objective_fn(mean_params))
            history["objective"].append(fval)
            f_window.append(fval)
            if len(f_window) >= 3 and float(np.std(f_window[-3:])) < tol_std:
                break
    if hist_rows:
        history["staleness_hist"] = engine.staleness_hist(hist_rows)
    history["steps_run"] = len(history["loss"])
    # same schema as the scan driver; the host loop never over-dispatches
    history["steps_dispatched"] = history["steps_run"]
    return state, history
