"""Dynamic-network scenarios: time-varying graphs, churn, and stragglers.

The paper's Assumption 1 only requires the per-round communication matrix
B^k to be doubly stochastic — it never requires the *same* graph every
round.  This module turns a static `Topology` into a per-step realization
sampled on device from a folded PRNG key, so every registered algorithm
can be raced under realistic network dynamics without leaving the scan:

  * `Scenario`       — the spec: per-step link-failure probability, node
                       churn (full dropout), and straggler probability.
                       Per-step edge *resampling* of a graph family is the
                       same mechanism (dynamic Erdős–Rényi = a denser base
                       graph + `edge_drop`).
  * `ScenarioArrays` — the static device-side view (padded neighbor table
                       of the base graph + the scenario PRNG key).
  * `realize`        — fold the key with the global step index and sample
                       the step's masks, then rebuild Metropolis–Hastings
                       weights from the *realized* degrees.  The realized
                       matrix is symmetric and doubly stochastic over the
                       surviving subgraph pointwise: every non-participant
                       self-loops with weight exactly 1, so Assumption 1
                       holds at every step.
  * `scenario_mixer` — wrap a realization as a `repro.core.mixing.Mixer`
                       (padded-gather "sparse", full "dense", or legacy
                       "matrix"), constructed *inside* the traced step —
                       no host round-trips under `jit`/`vmap`/`scan`.
  * `freeze_dropped` — revert every node-stacked floating leaf of an
                       algorithm state for nodes that dropped this step: a
                       dropped node computes nothing, so its entire
                       per-node state is bitwise untouched.

Semantics of the three failure modes:

  * `edge_drop`  — each base edge fails independently per step (both
                   directions together: links are undirected).
  * `churn`      — the node is fully offline for the step: it neither
                   communicates nor applies a local update; its state is
                   frozen and the realized matrix gives it B_ii = 1.
  * `straggler`  — the node misses the exchange window: it is excluded
                   from communication (self-loop in B^k) but still applies
                   its local gradient step.
  * `partitions` — scheduled `PartitionWindow`s: every cross-component
                   edge is cut for start <= k < heal (persistent, not
                   i.i.d.), realizing a *block*-doubly-stochastic matrix
                   per connected component; the heal step restores the
                   base graph and gossip reconciles the drift.

Static scenarios (`is_static`) are handled by `Algorithm.bind` as the
existing fixed-`Topology` path — the exact same program, bit-identical by
construction.

Fidelity note (surrogate-state algorithms): on THIS path the simulation
keeps ONE global copy of each node's public surrogate (CHOCO/BEER's
hats, NIDS's difference-encoded u-hat), so a neighbor that misses an
innovation reads the fully up-to-date surrogate as soon as the link is
back — mildly optimistic convergence, lower-bound wire bits.  Binding a
`repro.core.faults.FaultModel` closes this gap: message-level loss with
per-receiver surrogate replicas that desync on a missed innovation and
resync only through explicit, wire-charged repair traffic.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import Mixer, PaddedMixing, _dense_padded
from repro.core.topology import Topology

__all__ = [
    "Scenario",
    "PartitionWindow",
    "ScenarioArrays",
    "Realization",
    "SCENARIO_PRESETS",
    "get_scenario",
    "list_scenarios",
    "make_scenario_arrays",
    "partition_components",
    "active_components",
    "component_stats",
    "edge_uniform",
    "sample_masks",
    "realize",
    "realization_from_masks",
    "realization_matrix",
    "scenario_mixer",
    "freeze_dropped",
    "expected_matrix",
]


@dataclasses.dataclass(frozen=True)
class PartitionWindow:
    """One network split: every cross-component edge is cut for steps
    ``start <= k < heal``, then the heal event restores the base graph.

    The component map is either explicit (``components`` — a tuple of
    node-id tuples covering every node exactly once) or derived from a
    folded seed: ``n_parts`` seed nodes are drawn uniformly and the
    split is their multi-source BFS Voronoi cells over the base graph,
    so every part is connected by construction (persistent bridge-edge
    cuts, not i.i.d. per-step noise).  Within the window the realized
    matrix is *block*-doubly-stochastic: the Metropolis–Hastings
    rebuild over realized degrees never sees a cross-component edge, so
    each component preserves its own mean — and therefore the global
    mean — for every step of the split.
    """

    start: int
    heal: int
    n_parts: int = 2
    components: Optional[Tuple[Tuple[int, ...], ...]] = None
    seed: int = 0

    def __post_init__(self):
        if self.start < 0:
            raise ValueError("partition start must be non-negative")
        if self.heal <= self.start:
            raise ValueError(
                f"partition heal step {self.heal} must be after start "
                f"{self.start}"
            )
        if self.components is not None:
            parts = tuple(tuple(int(i) for i in c) for c in self.components)
            object.__setattr__(self, "components", parts)
            object.__setattr__(self, "n_parts", len(parts))
        if self.n_parts < 2:
            raise ValueError("a partition needs n_parts >= 2")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Per-step network dynamics, sampled i.i.d. across steps.

    All probabilities are python floats baked into the traced step (the
    per-step *draws* are device-side, keyed on fold_in(key, step)).
    """

    name: str = "custom"
    edge_drop: float = 0.0   # P[a base edge fails this step]
    churn: float = 0.0       # P[a node is fully offline this step]
    straggler: float = 0.0   # P[a node misses the exchange this step]
    seed: int = 0
    # scheduled network splits (persistent cross-component cuts with a
    # heal step each) — non-overlapping, sorted by start
    partitions: Tuple[PartitionWindow, ...] = ()

    def __post_init__(self):
        for field in ("edge_drop", "churn", "straggler"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} must be a probability in [0, 1]")
        wins = tuple(sorted(self.partitions, key=lambda w: w.start))
        object.__setattr__(self, "partitions", wins)
        for a, b in zip(wins, wins[1:]):
            if b.start < a.heal:
                raise ValueError(
                    f"partition windows overlap: [{a.start}, {a.heal}) and "
                    f"[{b.start}, {b.heal})"
                )

    @property
    def is_static(self) -> bool:
        """True iff every step realizes the base graph exactly."""
        return (self.edge_drop == self.churn == self.straggler == 0.0
                and not self.partitions)

    @property
    def max_parts(self) -> int:
        """Most components any scheduled window splits the graph into
        (1 when no partitions — a single connected component)."""
        return max((w.n_parts for w in self.partitions), default=1)


SCENARIO_PRESETS = {
    "static": Scenario(name="static"),
    "flaky_links": Scenario(name="flaky_links", edge_drop=0.2),
    "churn": Scenario(name="churn", churn=0.1),
    "stragglers": Scenario(name="stragglers", straggler=0.3),
    # dynamic Erdős–Rényi: pair with a dense base graph (e.g. complete)
    "dynamic_er": Scenario(name="dynamic_er", edge_drop=0.5),
    "harsh": Scenario(name="harsh", edge_drop=0.2, churn=0.1, straggler=0.2),
}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIO_PRESETS:
        raise ValueError(
            f"unknown scenario {name!r}; pick from {sorted(SCENARIO_PRESETS)}"
        )
    return SCENARIO_PRESETS[name]


def list_scenarios() -> Tuple[str, ...]:
    return tuple(SCENARIO_PRESETS)


class ScenarioArrays(NamedTuple):
    """Static device-side view of the base graph for in-scan realization.

    Slot layout: the first d = max_degree slots are the base graph's padded
    neighbor table (`Topology.neighbor_matrix_padded` — ascending neighbor
    ids, padding repeats the row's own id with `valid` False); slot d is
    the receiver itself.  This layout is shared with PaME's
    `TopologyArrays`, so a realization's `edge_alive` mask applies to both
    directly.
    """

    nbrs: jax.Array       # [m, d] padded neighbor ids (no self slot)
    valid: jax.Array      # [m, d] bool — real base-graph edges
    nbrs_full: jax.Array  # [m, d+1] — neighbors then self
    is_self: jax.Array    # [m, d+1] bool — True only on the last slot
    key: jax.Array        # scenario PRNG key (fold_in with the step index)
    # partition schedule, resolved to device masks (None without windows;
    # trailing defaults keep every existing constructor/_replace call)
    part_cut: Optional[jax.Array] = None     # [P, m, d] bool — cut edges
    part_bounds: Optional[jax.Array] = None  # [P, 2] i32 — (start, heal)
    part_comp: Optional[jax.Array] = None    # [P, m] i32 — component ids

    @property
    def m(self) -> int:
        return self.nbrs.shape[0]


class Realization(NamedTuple):
    """One step's sampled network state (all leaves device-side)."""

    edge_alive: jax.Array      # [m, d] bool — realized bidirectional edges
    alive: jax.Array           # [m] bool — node not dropped by churn
    participating: jax.Array   # [m] bool — alive and not a straggler
    weights: jax.Array         # [m, d+1] f32 — per-slot receive weights
    directed_edges: jax.Array  # i32 scalar — realized directed edge count


def partition_components(topo: Topology, window: PartitionWindow) -> np.ndarray:
    """Resolve one window to a per-node component id array ([m] int32).

    Explicit ``components`` must cover every node exactly once.  Seeded
    splits draw ``n_parts`` distinct seed nodes from
    ``default_rng((seed, m, start))`` and grow them by multi-source BFS
    over the base graph — each part is the Voronoi cell of its seed, so
    parts are connected whenever the base graph is.  Nodes unreachable
    from any seed (a disconnected base graph) join component 0.
    """
    m = topo.m
    comp = np.full(m, -1, np.int32)
    if window.components is not None:
        for c, members in enumerate(window.components):
            for i in members:
                if not 0 <= i < m:
                    raise ValueError(
                        f"partition component {c} names node {i}, but the "
                        f"graph has m={m} nodes (already departed?)"
                    )
                if comp[i] >= 0:
                    raise ValueError(
                        f"node {i} appears in two partition components"
                    )
                comp[i] = c
        if np.any(comp < 0):
            missing = np.nonzero(comp < 0)[0].tolist()
            raise ValueError(
                f"partition components must cover every node; missing "
                f"{missing}"
            )
        return comp
    if window.n_parts > m:
        raise ValueError(
            f"cannot split m={m} nodes into {window.n_parts} components"
        )
    rng = np.random.default_rng((int(window.seed), int(m), int(window.start)))
    seeds = rng.choice(m, size=window.n_parts, replace=False)
    comp[seeds] = np.arange(window.n_parts, dtype=np.int32)
    frontier = list(int(s) for s in seeds)
    while frontier:
        nxt = []
        for i in frontier:
            for j in topo.neighbor_sets[i]:
                if comp[j] < 0:
                    comp[j] = comp[i]
                    nxt.append(j)
        frontier = nxt
    comp[comp < 0] = 0
    return comp


def make_scenario_arrays(topo: Topology, scenario: Scenario) -> ScenarioArrays:
    nbrs, valid = topo.neighbor_matrix_padded()
    m, d = nbrs.shape
    self_col = np.arange(m, dtype=nbrs.dtype)[:, None]
    is_self = np.zeros((m, d + 1), dtype=bool)
    is_self[:, d] = True
    part_cut = part_bounds = part_comp = None
    # TemporalScenario shares this builder but has no partition schedule
    windows = getattr(scenario, "partitions", ())
    if windows:
        comps = np.stack([
            partition_components(topo, w) for w in windows
        ])  # [P, m]
        # an edge is cut while its window is open iff its endpoints land
        # in different components (padding slots compare node-to-self —
        # never cut, and masked by `valid` anyway)
        cut = comps[:, :, None] != comps[:, nbrs]  # [P, m, d]
        part_cut = jnp.asarray(cut)
        part_bounds = jnp.asarray(
            [(w.start, w.heal) for w in windows], jnp.int32
        )
        part_comp = jnp.asarray(comps, jnp.int32)
    return ScenarioArrays(
        nbrs=jnp.asarray(nbrs, jnp.int32),
        valid=jnp.asarray(valid),
        nbrs_full=jnp.asarray(np.concatenate([nbrs, self_col], axis=1), jnp.int32),
        is_self=jnp.asarray(is_self),
        key=jax.random.PRNGKey(scenario.seed),
        part_cut=part_cut,
        part_bounds=part_bounds,
        part_comp=part_comp,
    )


def realization_from_masks(
    arrays: ScenarioArrays,
    edge_up: jax.Array,      # [m, d] bool — link-level survival (symmetric)
    alive: jax.Array,        # [m] bool
    straggler: jax.Array,    # [m] bool
) -> Realization:
    """Build the step's doubly-stochastic weights from explicit masks.

    Metropolis–Hastings over the realized degrees: w_ij = 1/(1 + max(d_i,
    d_j)) on realized edges, the self slot absorbs the remainder.  Both
    the edge mask and the weight formula are symmetric, so the realized
    matrix is symmetric ⇒ doubly stochastic; isolated / non-participating
    nodes get a self-loop of weight exactly 1.
    """
    participating = alive & ~straggler
    edge_alive = (
        arrays.valid
        & edge_up
        & participating[:, None]
        & participating[arrays.nbrs]
    )
    deg = jnp.sum(edge_alive, axis=1).astype(jnp.float32)        # realized d_i
    deg_nbr = deg[arrays.nbrs]                                   # realized d_j
    w_off = jnp.where(
        edge_alive,
        1.0 / (1.0 + jnp.maximum(deg[:, None], deg_nbr)),
        0.0,
    ).astype(jnp.float32)
    self_w = 1.0 - jnp.sum(w_off, axis=1)
    weights = jnp.concatenate([w_off, self_w[:, None]], axis=1)
    return Realization(
        edge_alive=edge_alive,
        alive=alive,
        participating=participating,
        weights=weights,
        directed_edges=jnp.sum(edge_alive.astype(jnp.int32)),
    )


def edge_uniform(key: jax.Array, nbrs: jax.Array) -> jax.Array:
    """One uniform draw per *undirected* base link, shaped like the padded
    neighbor table [m, d].

    Each slot's key is folded with the canonical (lo, hi) edge id, so both
    directions of a link read the same draw and any mask derived from it
    stays symmetric — without ever materializing the O(m²) uniform matrix
    the old scheme drew (only O(m·max_degree) counter-mode hashes).
    Padding slots (nbrs[i, slot] == i) get the self-pair draw, which every
    caller masks out with `valid`.
    """
    m, d = nbrs.shape
    row = jnp.arange(m, dtype=nbrs.dtype)[:, None]
    lo = jnp.minimum(row, nbrs)
    hi = jnp.maximum(row, nbrs)
    if m < (1 << 16):
        # row-major pair id fits uint32: one hash per slot
        edge_id = lo.astype(jnp.uint32) * jnp.uint32(m) + hi.astype(jnp.uint32)
        keys = jax.vmap(lambda e: jax.random.fold_in(key, e))(
            edge_id.reshape(-1)
        )
    else:
        # lo*m + hi would wrap modulo 2^32 and alias distinct links onto
        # one draw; nested folds cost a second hash but never collide
        keys = jax.vmap(
            lambda l, h: jax.random.fold_in(jax.random.fold_in(key, l), h)
        )(lo.reshape(-1), hi.reshape(-1))
    u = jax.vmap(lambda kk: jax.random.uniform(kk, ()))(keys)
    return u.reshape(m, d)


def sample_masks(
    scenario: Scenario, arrays: ScenarioArrays, k: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample step k's raw (edge_up, alive, straggler) masks.

    Factored out of `realize` so layers that *compose* with the scenario
    draw (the fault-injection path folds node crashes into `alive` before
    building weights) reuse the exact same PRNG discipline: same folds,
    same splits, same draw order — a zero-rate scenario skips the draw
    entirely, keeping the traced program identical to the static path.
    """
    m, d = arrays.nbrs.shape
    kk = jax.random.fold_in(arrays.key, k)
    k_edge, k_node, k_strag = jax.random.split(kk, 3)

    alive = jnp.ones((m,), bool)
    if scenario.churn > 0.0:
        alive = ~jax.random.bernoulli(k_node, scenario.churn, (m,))
    straggler = jnp.zeros((m,), bool)
    if scenario.straggler > 0.0:
        straggler = jax.random.bernoulli(k_strag, scenario.straggler, (m,))
    edge_up = jnp.ones((m, d), bool)
    if scenario.edge_drop > 0.0:
        edge_up = edge_uniform(k_edge, arrays.nbrs) >= scenario.edge_drop
    if scenario.partitions:
        # persistent cross-component cuts while a window is open; the
        # cut mask is symmetric (comp(i) != comp(j) both ways), so the
        # realized matrix stays symmetric and goes block-doubly-
        # stochastic per component through the MH rebuild
        in_win = ((k >= arrays.part_bounds[:, 0])
                  & (k < arrays.part_bounds[:, 1]))        # [P]
        cut = jnp.any(arrays.part_cut & in_win[:, None, None], axis=0)
        edge_up = edge_up & ~cut
    return edge_up, alive, straggler


def realize(scenario: Scenario, arrays: ScenarioArrays, k: jax.Array) -> Realization:
    """Sample step k's network realization (traceable; `k` may be traced).

    Edge survival is drawn once per *undirected* link via `edge_uniform`
    (per-edge folded keys over the padded table), so both directions agree
    and the realized adjacency stays symmetric.  Note: this per-edge
    counter-mode draw replaced the original O(m²) uniform matrix; realized
    masks for a given seed differ from the pre-fold goldens, and every
    conformance test recomputes its expectation from this same path.
    """
    edge_up, alive, straggler = sample_masks(scenario, arrays, k)
    return realization_from_masks(arrays, edge_up, alive, straggler)


def active_components(arrays: ScenarioArrays, k: jax.Array) -> jax.Array:
    """Per-node component id at step k ([m] i32, traceable).

    All zeros outside every window (one connected component); inside a
    window, that window's component map.  Windows never overlap
    (validated by `Scenario`), so the sum-over-windows select is exact.
    """
    if arrays.part_comp is None:
        return jnp.zeros((arrays.m,), jnp.int32)
    in_win = ((k >= arrays.part_bounds[:, 0])
              & (k < arrays.part_bounds[:, 1]))            # [P]
    return jnp.sum(
        jnp.where(in_win[:, None], arrays.part_comp, 0), axis=0
    ).astype(jnp.int32)


def component_stats(comp: jax.Array, x: jax.Array, n_comp: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-component consensus / drift scalars from flattened params.

    ``comp`` is the [m] component id, ``x`` the [m, n] node-stacked
    parameter matrix, ``n_comp`` the static component-count bound.
    Returns ``(comp_consensus, comp_mean_gap)``:

      * comp_consensus — mean over nodes of ||x_i − x̄_{comp(i)}||²,
        the *within*-component disagreement (equals plain consensus
        outside a partition window).
      * comp_mean_gap  — max over non-empty components of
        ||x̄_c − x̄_global||₂, the *between*-component drift built up
        during a split (0 outside windows; post-heal decay of this gap
        is the consensus-recovery headline).
    """
    x = x.astype(jnp.float32)
    onehot = (comp[:, None] == jnp.arange(n_comp)[None, :]).astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)                       # [C]
    means = (onehot.T @ x) / jnp.maximum(counts, 1.0)[:, None]
    mine = means[comp]                                     # [m, n]
    comp_consensus = jnp.mean(jnp.sum((x - mine) ** 2, axis=1))
    gap = jnp.sqrt(jnp.sum((means - jnp.mean(x, axis=0)) ** 2, axis=1))
    comp_mean_gap = jnp.max(jnp.where(counts > 0, gap, 0.0))
    return comp_consensus, comp_mean_gap


def realization_matrix(arrays: ScenarioArrays, r: Realization) -> jax.Array:
    """The realized [m, m] doubly-stochastic matrix (row i = receiver i).

    Symmetric, so it equals the B^k of Assumption 1 in either convention.
    Padding slots carry weight exactly 0 and scatter onto the diagonal,
    where they are additive no-ops.
    """
    m = arrays.m
    rows = jnp.broadcast_to(
        jnp.arange(m, dtype=jnp.int32)[:, None], arrays.nbrs_full.shape
    )
    return (
        jnp.zeros((m, m), jnp.float32)
        .at[rows, arrays.nbrs_full]
        .add(r.weights)
    )


def scenario_mixer(
    arrays: ScenarioArrays, r: Realization, mode: str = "sparse",
    impl: Optional[str] = None,
) -> Mixer:
    """Wrap one step's realization as a gossip `Mixer`.

    Constructed inside the traced step — per-step weights only, the
    neighbor table stays static, so this is scan/vmap-safe with no host
    round-trips.  "sparse" gathers over the padded slots (O(m·deg·n))
    through the shared `repro.core.mixing.gather_terms` core (`impl`
    picks "slots"/"segsum"; None = backend default);
    "dense"/"matrix" materialize the [m, m] realized matrix.

    Slot layout is neighbors-then-self (`ScenarioArrays`), not the
    ascending interleaved order of `Topology.mixing_padded`, so sparse
    and dense scenario mixers agree to fp tolerance only — the static
    path's bitwise dense/sparse identity does not extend here (the
    conformance tests compare with tolerance accordingly).
    """
    if mode == "sparse":
        # structural padding of the base table; the self slot is real
        pad = jnp.concatenate(
            [~arrays.valid, jnp.zeros((arrays.m, 1), bool)], axis=1
        )
        pm = PaddedMixing(arrays.nbrs_full, r.weights, arrays.is_self, pad)
        return Mixer("sparse", None, pm, impl)
    b = realization_matrix(arrays, r)
    if mode == "dense":
        return Mixer("dense", b, _dense_padded(b), impl)
    if mode == "matrix":
        return Mixer("matrix", b)
    raise ValueError(f"unknown scenario mixing mode {mode!r}")


def freeze_dropped(alive: jax.Array, old_state: object, new_state: object) -> object:
    """Revert dropped nodes' per-node state: a node offline for the step
    computes nothing, so every floating leaf with a leading node axis is
    restored bitwise from the pre-step state where `alive` is False.
    Scalar counters and PRNG keys (integer dtypes) advance normally.
    """
    m = alive.shape[0]

    def one(old, new):
        if (
            hasattr(new, "ndim")
            and new.ndim >= 1
            and new.shape[0] == m
            and jnp.issubdtype(new.dtype, jnp.inexact)
        ):
            keep = alive.reshape((m,) + (1,) * (new.ndim - 1))
            return jnp.where(keep, new, old)
        return new

    return jax.tree_util.tree_map(one, old_state, new_state)


def expected_matrix(
    topo: Topology,
    scenario: Scenario,
    num_samples: int = 256,
    k_offset: int = 0,
) -> np.ndarray:
    """Empirical E[B^k] over `num_samples` realizations (float64 host array).

    The spectral gap of this matrix lower-bounds the per-step consensus
    contraction of the dynamic process (Jensen); the conformance suite
    checks it against the measured contraction slope.
    """
    arrays = make_scenario_arrays(topo, scenario)
    ks = jnp.arange(k_offset, k_offset + num_samples)
    mats = jax.vmap(
        lambda k: realization_matrix(arrays, realize(scenario, arrays, k))
    )(ks)
    return np.asarray(jnp.mean(mats, axis=0), dtype=np.float64)
