"""Compression operators used by the baseline DFL algorithms.

Each operator maps a vector to its compressed-then-decompressed form (the
simulation works on dense vectors) and reports the wire cost in bits, so the
communication-volume benchmarks (paper Figs. 9–10) can account traffic per
algorithm consistently with PaME's Eq. (8).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Compressor", "identity", "rand_k", "top_k", "qsgd", "one_bit"]


@dataclasses.dataclass(frozen=True)
class Compressor:
    name: str
    # (key, x) -> decompressed x_hat
    apply: Callable[[jax.Array, jax.Array], jax.Array]
    # n -> bits on the wire per message
    bits: Callable[[int], int]


def identity() -> Compressor:
    return Compressor("identity", lambda key, x: x, lambda n: 64 * n)


def rand_k(frac: float, value_bits: int = 64, rescale: bool = True) -> Compressor:
    """rand-k sparsifier.  rescale=True gives the *unbiased* operator
    (E C(x) = x, variance (n/s-1)||x||^2); rescale=False gives the
    *contractive* operator (||C(x)-x||^2 <= (1-s/n)||x||^2) required by
    error-feedback methods such as CHOCO-SGD and BEER."""

    def apply(key: jax.Array, x: jax.Array) -> jax.Array:
        n = x.shape[-1]
        s = max(1, int(round(frac * n)))
        u = jax.random.uniform(key, x.shape)
        ranks = jnp.argsort(jnp.argsort(u, axis=-1), axis=-1)
        mask = ranks < s
        return jnp.where(mask, x * (n / s) if rescale else x, 0.0)

    def bits(n: int) -> int:
        s = max(1, int(round(frac * n)))
        return (value_bits - 1) * s + n

    return Compressor(f"rand{frac:g}", apply, bits)


def top_k(frac: float, value_bits: int = 64) -> Compressor:
    def apply(key: jax.Array, x: jax.Array) -> jax.Array:
        n = x.shape[-1]
        s = max(1, int(round(frac * n)))
        ranks = jnp.argsort(jnp.argsort(-jnp.abs(x), axis=-1), axis=-1)
        return jnp.where(ranks < s, x, 0.0)

    def bits(n: int) -> int:
        s = max(1, int(round(frac * n)))
        return (value_bits - 1) * s + n

    return Compressor(f"top{frac:g}", apply, bits)


def qsgd(levels: int = 16) -> Compressor:
    """QSGD stochastic quantization to `levels` levels per sign."""

    def apply(key: jax.Array, x: jax.Array) -> jax.Array:
        norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
        norm = jnp.maximum(norm, 1e-12)
        y = jnp.abs(x) / norm * levels
        lo = jnp.floor(y)
        prob = y - lo
        bump = jax.random.bernoulli(key, prob, x.shape)
        q = (lo + bump) / levels
        return jnp.sign(x) * q * norm

    import math

    per_coord = 1 + math.ceil(math.log2(levels + 1))
    return Compressor(f"qsgd{levels}", apply, lambda n: 32 + per_coord * n)


def one_bit() -> Compressor:
    """Sign compression with per-message scale (1-bit SGD style)."""

    def apply(key: jax.Array, x: jax.Array) -> jax.Array:
        scale = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
        return jnp.sign(x) * scale

    return Compressor("onebit", apply, lambda n: 32 + n)
