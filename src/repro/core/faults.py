"""Message-level fault injection with per-receiver surrogate replicas.

The scenario layer (`repro.core.scenarios` / `repro.core.temporal`) models
a down link as a *symmetric edge removal known to both ends*: the realized
matrix simply re-weights around it, and the single global copy of each
node's public surrogate means a CHOCO/BEER/ANQ-NIDS neighbor that misses
an innovation silently reads it back for free once the link returns.
Real networks fail at the *message* level — per direction, in bursts,
late, or because the sender crashed — and surrogate-memory algorithms
desync precisely through those losses.  This module makes the failure
model faithful:

  * `FaultModel`       — the spec: i.i.d. per-direction message loss,
                         a Gilbert–Elliott lossy-link burst chain per
                         *directed* slot, delayed delivery (message-only
                         delay through the staleness ring — compute is
                         never delayed), and transient node crashes with
                         geometric rejoin.
  * `FaultState`       — the Markov fault state riding the engine's
                         auxiliary carry (link chains, crash chain, delay
                         ages, and the cumulative mean-drift tracker).
  * `advance_faults`   — one traceable transition: compose with the base
                         scenario masks, draw per-direction losses, build
                         the *per-receiver renormalized* weights (lost
                         mass folds into the self slot, so every row sums
                         to exactly 1 under arbitrary asymmetric loss),
                         and measure the column-sum defect — the matrix
                         is no longer column-stochastic, and the defect
                         is exactly the per-step drift of the global
                         parameter mean that doubly-stochastic gossip
                         would have preserved.
  * `rep_*_init/step`  — per-receiver surrogate replicas for the
                         compressed baselines: receiver i keeps its own
                         copy of every neighbor's surrogate (conceptually
                         [m, m, ...] state, stored in padded [m, d, ...]
                         form — only actual neighbors hold replicas).  A
                         lost innovation desyncs the replica; with
                         `repair=True` the sender detects the missing ack
                         and retransmits its *full* surrogate on the next
                         realized link, charged at the uncompressed
                         Eq.-(8) rate on top of the normal innovation
                         traffic.  With `repair=False` the drift is
                         permanent — the divergence regime the graceful-
                         degradation benchmark races against PaME.

PaME needs no replicas and no repair: its count-normalized PME average is
memoryless, so a lost message only shrinks lambda_{i,l} and the realized
averaging weights stay row-stochastic by construction — the structural
reason it degrades gracefully where surrogate methods desync.

Zero-rate models (`FaultModel.is_static`) are rejected at bind time by
`Algorithm.bind` falling back to the fault-free program, so a zero-loss
run is *bit-identical* to the pre-fault-layer path.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines as B
from repro.core.compression import Compressor
from repro.core.mixing import mix_replicated
from repro.core.pme import message_bits
from repro.core.scenarios import (
    Realization,
    ScenarioArrays,
    realization_from_masks,
    realization_matrix,
)

__all__ = [
    "FaultModel",
    "FaultState",
    "FaultCarry",
    "FaultRealization",
    "FAULT_PRESETS",
    "get_fault_model",
    "list_fault_models",
    "fault_state_init",
    "fault_carry_init",
    "advance_faults",
    "fault_matrix",
    "RepChocoState", "rep_choco_init", "rep_choco_step",
    "RepBeerState", "rep_beer_init", "rep_beer_step",
    "RepNidsState", "rep_nids_init", "rep_nids_step",
]

# init-key fold for the stationary link-chain draw — outside any reachable
# step index (the fault key stream is separate from the scenario key, but
# the same no-collision discipline applies)
_INIT_LINK_FOLD = 0x7FFFFFFB


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Message-level failure spec, sampled on device per step.

    All rates are python floats baked into the traced step; zero-rate
    branches are skipped entirely, so `is_static` models compile to the
    exact fault-free program.  The fault PRNG stream is keyed on
    `PRNGKey(seed)` folded with the step index — independent of the
    scenario stream, so adding faults never perturbs the base network
    draws.
    """

    name: str = "faults"
    # i.i.d. per-*direction* message loss (good link state)
    loss: float = 0.0        # P[a directed message is dropped]
    # Gilbert–Elliott burst chain per directed slot
    burst_down: float = 0.0  # P[good -> lossy] per step
    burst_up: float = 0.5    # P[lossy -> good] per step
    loss_bad: float = 1.0    # P[dropped | link in the lossy state]
    # delayed delivery (message-only: local compute is never delayed)
    delay: float = 0.0       # P[a node's outgoing messages are late]
    max_delay: int = 0       # D: staleness bound; past it the messages
    #                          are dropped outright (0 disables delay)
    # transient node crashes
    crash: float = 0.0       # P[up -> crashed] per step
    rejoin: float = 0.5      # P[crashed -> recovered] per step
    # ack/repair resync of per-receiver replicas (surrogate algorithms)
    repair: bool = True
    seed: int = 0

    def __post_init__(self):
        for field in ("loss", "burst_down", "burst_up", "loss_bad",
                      "delay", "crash", "rejoin"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field}={v} must be a probability in [0, 1]")
        if self.max_delay < 0:
            raise ValueError(f"max_delay={self.max_delay} must be >= 0")
        if self.delay > 0.0 and self.max_delay == 0:
            raise ValueError(
                "delay>0 needs max_delay>=1 (the staleness ring bound)"
            )
        if self.burst_down > 0.0 and self.burst_up == 0.0:
            raise ValueError("burst_up=0 would make lossy links permanent")
        if self.crash > 0.0 and self.rejoin == 0.0:
            raise ValueError("rejoin=0 would make crashes permanent")

    @property
    def is_static(self) -> bool:
        """True iff no fault can ever fire — bind falls back to the
        fault-free program, bit-identical to the pre-fault path."""
        return (
            self.loss == self.burst_down == self.delay == self.crash == 0.0
        )

    @property
    def stationary_lossy(self) -> float:
        """Stationary P[link lossy] of the Gilbert–Elliott chain."""
        denom = self.burst_down + self.burst_up
        return self.burst_down / denom if denom > 0.0 else 0.0


FAULT_PRESETS = {
    "lossy": FaultModel(name="lossy", loss=0.1),
    "bursty_loss": FaultModel(
        name="bursty_loss", burst_down=0.05, burst_up=0.25),
    "crashy": FaultModel(name="crashy", crash=0.02, rejoin=0.2),
    "late": FaultModel(name="late", delay=0.3, max_delay=3),
    "harsh_faults": FaultModel(
        name="harsh_faults", loss=0.1, burst_down=0.05, burst_up=0.3,
        crash=0.02, rejoin=0.25, delay=0.2, max_delay=2),
}


def get_fault_model(name: str) -> FaultModel:
    if name not in FAULT_PRESETS:
        raise ValueError(
            f"unknown fault model {name!r}; pick from {sorted(FAULT_PRESETS)}"
        )
    return FAULT_PRESETS[name]


def list_fault_models() -> Tuple[str, ...]:
    return tuple(FAULT_PRESETS)


class FaultState(NamedTuple):
    """Fault Markov state carried through the scan."""

    link_bad: jax.Array  # [m, d] bool — GE lossy state per *directed* slot
    crashed: jax.Array   # [m] bool — crash chain state
    age: jax.Array       # [m] i32 — consecutive late-delivery count
    drift: jax.Array     # f32 scalar — cumulative column-sum defect (the
    #                      mean-drift tracker exposed through the aux carry)


class FaultCarry(NamedTuple):
    """Auxiliary carry of a fault-injected run: the fault Markov state
    plus the delayed-delivery snapshot ring (None when max_delay == 0)."""

    fs: FaultState
    ring: Optional[object]


class FaultRealization(NamedTuple):
    """One step's message-level outcome, layered over the base realization."""

    base: Realization     # crash-aware scenario realization (symmetric)
    recv_ok: jax.Array    # [m, d] bool — directed messages delivered
    weights: jax.Array    # [m, d+1] f32 — per-receiver renormalized weights
    #                       (rows sum to exactly 1 under asymmetric loss)
    delayed: jax.Array    # [m] bool — senders served from the ring
    tau: jax.Array        # [m] i32 — current delay per sender (0 if fresh)
    dropped: jax.Array    # i32 — realized directed messages lost this step
    col_defect: jax.Array  # f32 — Σ_j |colsum_j − 1| of the faulted matrix


def fault_state_init(
    model: FaultModel, arrays: ScenarioArrays, key: jax.Array
) -> FaultState:
    """Initial fault state: the link chain starts from its stationary law
    (keyed outside the per-step fold stream); nodes start healthy and
    punctual — crashes and delays are transient events, not a steady
    state the run should begin in."""
    m, d = arrays.nbrs.shape
    link_bad = jnp.zeros((m, d), bool)
    if model.burst_down > 0.0:
        u = jax.random.uniform(
            jax.random.fold_in(key, _INIT_LINK_FOLD), (m, d)
        )
        link_bad = u < model.stationary_lossy
    return FaultState(
        link_bad=link_bad,
        crashed=jnp.zeros((m,), bool),
        age=jnp.zeros((m,), jnp.int32),
        drift=jnp.zeros((), jnp.float32),
    )


def fault_carry_init(
    model: FaultModel,
    arrays: ScenarioArrays,
    params_stacked: object,
    key: jax.Array,
) -> FaultCarry:
    from repro.core.temporal import ring_init

    return FaultCarry(
        fs=fault_state_init(model, arrays, key),
        ring=ring_init(params_stacked, model.max_delay),
    )


def advance_faults(
    model: FaultModel,
    arrays: ScenarioArrays,
    fs: FaultState,
    key: jax.Array,
    k: jax.Array,
    edge_up: jax.Array,     # [m, d] bool — base scenario link survival
    alive: jax.Array,       # [m] bool — base scenario churn state
    straggler: jax.Array,   # [m] bool — base scenario stragglers
) -> Tuple[FaultState, FaultRealization]:
    """One traceable fault transition + message-level realization.

    Composes with the base scenario masks (`scenarios.sample_masks`):
    crashes fold into `alive` before the Metropolis–Hastings weights are
    built, so a crashed node self-loops with weight exactly 1 and its
    state freezes — the in-simulation analogue of restoring from its
    local checkpoint on rejoin.  Loss is drawn *per directed slot*
    (independent draws for the two directions of a link: asymmetric by
    construction), and the kept off-diagonal weights are renormalized
    into the self slot per receiver: every row of the realized matrix
    sums to exactly 1, while the column sums defect by the lost mass —
    returned as `col_defect` and accumulated into the `drift` tracker.
    """
    m, d = arrays.nbrs.shape
    kk = jax.random.fold_in(key, k)
    k_loss, k_burst, k_crash, k_delay = jax.random.split(kk, 4)

    link_bad = fs.link_bad
    if model.burst_down > 0.0:
        u = jax.random.uniform(k_burst, (m, d))
        link_bad = jnp.where(
            fs.link_bad, u < 1.0 - model.burst_up, u < model.burst_down
        )
    crashed = fs.crashed
    if model.crash > 0.0:
        u = jax.random.uniform(k_crash, (m,))
        crashed = jnp.where(
            fs.crashed, u < 1.0 - model.rejoin, u < model.crash
        )
    late = jnp.zeros((m,), bool)
    if model.delay > 0.0:
        late = jax.random.bernoulli(k_delay, model.delay, (m,))
    age = jnp.where(late, fs.age + 1, 0)
    delayed = late & alive & ~crashed & (age <= model.max_delay)
    overdue = late & ~delayed  # past the bound: messages dropped outright

    r = realization_from_masks(arrays, edge_up, alive & ~crashed, straggler)

    lost = jnp.zeros((m, d), bool)
    if model.loss > 0.0 or model.burst_down > 0.0:
        p_drop = jnp.where(link_bad, model.loss_bad, model.loss)
        lost = jax.random.uniform(k_loss, (m, d)) < p_drop
    sender_overdue = overdue[arrays.nbrs]
    recv_ok = r.edge_alive & ~lost & ~sender_overdue

    # per-receiver renormalization: zero the lost slots, fold the lost
    # mass into the self slot — rows sum to exactly 1 by construction
    w_off = jnp.where(recv_ok, r.weights[:, :d], 0.0)
    self_w = 1.0 - jnp.sum(w_off, axis=1)
    weights = jnp.concatenate([w_off, self_w[:, None]], axis=1)

    # mean-drift tracker: the faulted matrix is row- but no longer
    # column-stochastic; the column-sum defect is the per-step leak of
    # the global parameter mean under direct parameter mixing
    col = (
        jnp.zeros((m,), jnp.float32)
        .at[arrays.nbrs_full.reshape(-1)]
        .add(weights.reshape(-1))
    )
    col_defect = jnp.sum(jnp.abs(col - 1.0))

    new_fs = FaultState(
        link_bad=link_bad, crashed=crashed, age=age,
        drift=fs.drift + col_defect,
    )
    fr = FaultRealization(
        base=r,
        recv_ok=recv_ok,
        weights=weights,
        delayed=delayed,
        tau=jnp.where(delayed, age, 0),
        dropped=jnp.sum((r.edge_alive & ~recv_ok).astype(jnp.int32)),
        col_defect=col_defect,
    )
    return new_fs, fr


def fault_matrix(arrays: ScenarioArrays, fr: FaultRealization) -> jax.Array:
    """The faulted [m, m] matrix (row i = receiver i): row-stochastic by
    construction, column-defective by the lost mass."""
    return realization_matrix(arrays, fr.base._replace(weights=fr.weights))


# ---------------------------------------------------------------------------
# Per-receiver surrogate replicas for the compressed baselines
# ---------------------------------------------------------------------------
def _mask2(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast an [m, d] mask over a replica leaf [m, d, ...]."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 2))


def _zero_replicas(params_stacked: object, arrays: ScenarioArrays) -> object:
    m, d = arrays.nbrs.shape
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((m, d) + x.shape[1:], x.dtype), params_stacked
    )


def _deliver_stream(
    reps: object,       # [m, d, ...] receiver-held replicas
    q: object,          # [m, ...] this step's innovation per sender
    own_new: object,    # [m, ...] sender's post-innovation surrogate
    nbrs: jax.Array,
    recv_ok: jax.Array,  # [m, d]
    pending: jax.Array,  # [m, d] — replica known-desynced, awaiting repair
    repair: bool,
) -> object:
    """One delivery round of one surrogate stream.

    Delivered innovation on a synced link: replica += q_sender (the
    normal compressed message).  Delivered message on a *pending* link:
    the sender, knowing the ack is missing, sent its full surrogate
    instead — replica := sender's current surrogate (resync).  Lost or
    unrealized: replica untouched (desync persists).
    """
    q_from = jax.tree_util.tree_map(lambda x: x[nbrs], q)
    if not repair:
        return jax.tree_util.tree_map(
            lambda rep, qf: jnp.where(_mask2(recv_ok, rep), rep + qf, rep),
            reps, q_from,
        )
    own_from = jax.tree_util.tree_map(lambda x: x[nbrs], own_new)
    normal = recv_ok & ~pending
    fixed = recv_ok & pending

    def one(rep, qf, of):
        rep = jnp.where(_mask2(normal, rep), rep + qf, rep)
        return jnp.where(_mask2(fixed, rep), of, rep)

    return jax.tree_util.tree_map(one, reps, q_from, own_from)


def _desync(
    valid: jax.Array, nbrs: jax.Array, reps: object, own: object
) -> jax.Array:
    """Σ over real base links of ||replica − sender's surrogate||² — the
    observable surrogate desynchronization this layer exists to model."""
    tot = jnp.zeros((), jnp.float32)
    for rep, o in zip(
        jax.tree_util.tree_leaves(reps), jax.tree_util.tree_leaves(own)
    ):
        of = o[nbrs]
        d2 = jnp.sum(
            (rep - of).astype(jnp.float32) ** 2,
            axis=tuple(range(2, rep.ndim)),
        )
        tot = tot + jnp.sum(jnp.where(valid, d2, 0.0))
    return tot


def _n_total(params_stacked: object) -> int:
    import numpy as np

    return sum(
        int(np.prod(leaf.shape[1:]))
        for leaf in jax.tree_util.tree_leaves(params_stacked)
    )


def _link_traffic(
    arrays: ScenarioArrays,
    fr: FaultRealization,
    pending: jax.Array,
    repair: bool,
    innov_bits: float,
    repair_streams: int,
    n: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Wire accounting + pending update shared by every replicated step.

    Innovations are charged on every realized non-pending directed link
    (bits are spent whether or not the message is then lost); repair
    retransmissions — one full-precision Eq.-(8) message per surrogate
    stream — on every realized pending link.  `pending` after the step is
    simply every real base link that did not deliver this round: the
    sender's surrogate advanced, the receiver's replica did not.
    """
    ea = fr.base.edge_alive
    full = float(message_bits(n, n, 64)) * float(repair_streams)
    if repair:
        n_repair = jnp.sum((pending & ea).astype(jnp.float32))
        n_normal = jnp.sum((ea & ~pending).astype(jnp.float32))
        new_pending = arrays.valid & ~fr.recv_ok
        repair_bits = full * n_repair
    else:
        n_normal = jnp.sum(ea.astype(jnp.float32))
        new_pending = pending  # unused: stays all-False
        repair_bits = jnp.zeros((), jnp.float32)
    wire_bits = float(innov_bits) * n_normal + repair_bits
    return wire_bits, repair_bits, new_pending


# -- CHOCO-SGD with per-receiver replicas -----------------------------------
class RepChocoState(NamedTuple):
    params: object    # x_i
    hats: object      # \hat x_i — the sender's own surrogate (truth)
    reps: object      # [m, d, ...] receiver i's copy of \hat x_{nbrs[i, s]}
    pending: jax.Array  # [m, d] bool — awaiting full-surrogate repair
    step: jax.Array
    key: jax.Array


def rep_choco_init(
    key: jax.Array, params_stacked: object, arrays: ScenarioArrays
) -> RepChocoState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    m, d = arrays.nbrs.shape
    return RepChocoState(
        params=params_stacked,
        hats=zeros,
        reps=_zero_replicas(params_stacked, arrays),
        pending=jnp.zeros((m, d), bool),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


def rep_choco_step(
    state: RepChocoState,
    batch: object,
    grad_fn,
    lr: float,
    comp: Compressor,
    gossip_gamma: float,
    fr: FaultRealization,
    arrays: ScenarioArrays,
    innov_bits: float,
    repair: bool,
    grad_shift: Optional[object] = None,
) -> Tuple[RepChocoState, dict]:
    """CHOCO-SGD where each receiver mixes the surrogate copies *it*
    holds.  Mixing weights are the symmetric realized ones (a receiver
    always has a replica to mix, however stale), so loss shows up as
    replica desync — exactly the real-deployment failure mode — not as a
    reweighting the receiver could not have known to apply."""
    m, d = arrays.nbrs.shape
    key = jax.random.fold_in(state.key, state.step)
    losses, grads = B._node_grads(
        grad_fn, B._shifted(state.params, grad_shift), batch, key
    )
    half = B._axpy(-lr, grads, state.params)
    q = B._compress_tree(
        comp, jax.random.fold_in(key, 7), B._sub(half, state.hats)
    )
    hats = B._add(state.hats, q)
    reps = _deliver_stream(
        state.reps, q, hats, arrays.nbrs, fr.recv_ok, state.pending, repair
    )
    w_off = fr.base.weights[:, :d]
    self_w = fr.base.weights[:, d]
    mixed = mix_replicated(w_off, self_w, reps, hats)
    correction = jax.tree_util.tree_map(
        lambda mx, h: gossip_gamma * (mx - h), mixed, hats
    )
    new_params = B._add(half, correction)
    wire_bits, repair_bits, pending = _link_traffic(
        arrays, fr, state.pending, repair, innov_bits,
        repair_streams=1, n=_n_total(state.params),
    )
    metrics = {
        "loss_mean": jnp.mean(losses),
        "wire_bits": wire_bits,
        "repair_bits": repair_bits,
        "surrogate_desync": _desync(arrays.valid, arrays.nbrs, reps, hats),
    }
    return (
        RepChocoState(new_params, hats, reps, pending, state.step + 1,
                      state.key),
        metrics,
    )


# -- BEER with per-receiver replicas ----------------------------------------
class RepBeerState(NamedTuple):
    params: object     # x
    h: object          # surrogate of x (sender truth)
    g: object          # gradient tracker
    z: object          # surrogate of g (sender truth)
    prev_grad: object
    h_reps: object     # [m, d, ...] replicas of h[nbrs]
    z_reps: object     # [m, d, ...] replicas of z[nbrs]
    pending: jax.Array  # [m, d] bool (both streams ride one link message)
    step: jax.Array
    key: jax.Array


def rep_beer_init(
    key: jax.Array,
    params_stacked: object,
    batch0: object,
    grad_fn,
    arrays: ScenarioArrays,
) -> RepBeerState:
    _, g0 = B._node_grads(grad_fn, params_stacked, batch0, key)
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    g0_copy = jax.tree_util.tree_map(lambda x: x.copy(), g0)
    m, d = arrays.nbrs.shape
    return RepBeerState(
        params=params_stacked, h=zeros(), g=g0, z=zeros(),
        prev_grad=g0_copy,
        h_reps=_zero_replicas(params_stacked, arrays),
        z_reps=_zero_replicas(params_stacked, arrays),
        pending=jnp.zeros((m, d), bool),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


def rep_beer_step(
    state: RepBeerState,
    batch: object,
    grad_fn,
    lr: float,
    comp: Compressor,
    gossip_gamma: float,
    fr: FaultRealization,
    arrays: ScenarioArrays,
    innov_bits: float,
    repair: bool,
    grad_shift: Optional[object] = None,
) -> Tuple[RepBeerState, dict]:
    """BEER with receiver-held h/z replicas.  Both compressed streams ride
    one link message per step, so one pending flag covers the pair and a
    repair retransmits both full surrogates (2 Eq.-(8) messages)."""
    m, d = arrays.nbrs.shape
    key = jax.random.fold_in(state.key, state.step)
    w_off = fr.base.weights[:, :d]
    self_w = fr.base.weights[:, d]
    # lazy mixing of the OLD replicas (classic BEER mixes the pre-update
    # surrogates): (B − I) through the receiver-held copies
    mix_h = B._sub(
        mix_replicated(w_off, self_w, state.h_reps, state.h), state.h
    )
    x_new = jax.tree_util.tree_map(
        lambda x, mh, g: x + gossip_gamma * mh - lr * g,
        state.params, mix_h, state.g,
    )
    qh = B._compress_tree(
        comp, jax.random.fold_in(key, 3), B._sub(x_new, state.h)
    )
    h_new = B._add(state.h, qh)
    losses, grad_new = B._node_grads(
        grad_fn, B._shifted(x_new, grad_shift), batch, key
    )
    mix_z = B._sub(
        mix_replicated(w_off, self_w, state.z_reps, state.z), state.z
    )
    g_new = jax.tree_util.tree_map(
        lambda g, mz, gn, gp: g + gossip_gamma * mz + gn - gp,
        state.g, mix_z, grad_new, state.prev_grad,
    )
    qz = B._compress_tree(
        comp, jax.random.fold_in(key, 5), B._sub(g_new, state.z)
    )
    z_new = B._add(state.z, qz)
    h_reps = _deliver_stream(
        state.h_reps, qh, h_new, arrays.nbrs, fr.recv_ok, state.pending,
        repair,
    )
    z_reps = _deliver_stream(
        state.z_reps, qz, z_new, arrays.nbrs, fr.recv_ok, state.pending,
        repair,
    )
    wire_bits, repair_bits, pending = _link_traffic(
        arrays, fr, state.pending, repair, innov_bits,
        repair_streams=2, n=_n_total(state.params),
    )
    desync = (
        _desync(arrays.valid, arrays.nbrs, h_reps, h_new)
        + _desync(arrays.valid, arrays.nbrs, z_reps, z_new)
    )
    metrics = {
        "loss_mean": jnp.mean(losses),
        "wire_bits": wire_bits,
        "repair_bits": repair_bits,
        "surrogate_desync": desync,
    }
    return (
        RepBeerState(x_new, h_new, g_new, z_new, grad_new, h_reps, z_reps,
                     pending, state.step + 1, state.key),
        metrics,
    )


# -- (AN)Q-NIDS with per-receiver replicas ----------------------------------
class RepNidsState(NamedTuple):
    params: object    # x^k
    c: object         # memory (own, exact)
    hat_z: object     # surrogate of z (sender truth)
    hat_c: object     # surrogate of c (sender truth, receiver-accumulated)
    z_reps: object    # [m, d, ...] replicas of hat_z[nbrs]
    c_reps: object    # [m, d, ...] replicas of hat_c[nbrs]
    pending: jax.Array  # [m, d] bool
    step: jax.Array
    key: jax.Array


def rep_nids_init(
    key: jax.Array, params_stacked: object, arrays: ScenarioArrays
) -> RepNidsState:
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    m, d = arrays.nbrs.shape
    return RepNidsState(
        params=params_stacked, c=zeros(), hat_z=zeros(), hat_c=zeros(),
        z_reps=_zero_replicas(params_stacked, arrays),
        c_reps=_zero_replicas(params_stacked, arrays),
        pending=jnp.zeros((m, d), bool),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


def rep_nids_step(
    state: RepNidsState,
    batch: object,
    grad_fn,
    lr: float,
    comp: Compressor,
    fr: FaultRealization,
    arrays: ScenarioArrays,
    innov_bits: float,
    repair: bool,
    grad_shift: Optional[object] = None,
) -> Tuple[RepNidsState, dict]:
    """Quantized NIDS with receiver-held hat_z / hat_c replicas.

    The receiver-side accumulation hat_c += hat_z is a *local* operation
    on each replica, so a z-desync compounds into the c replica every
    step — the amplification that makes NIDS the sharpest desync case in
    the conformance suite.  A repair resyncs both replicas from the
    sender's current surrogates (2 full Eq.-(8) messages); the repaired
    c replica is used from the *next* step (this step's hat_v reads the
    pre-repair copy, mirroring the classic old-hat_c ordering).
    """
    m, d = arrays.nbrs.shape
    key = jax.random.fold_in(state.key, state.step)
    losses, grad_k = B._node_grads(
        grad_fn, B._shifted(state.params, grad_shift), batch, key
    )
    z = B._axpy(-lr, grad_k, state.params)
    v = jax.tree_util.tree_map(lambda zz, cc: 2.0 * zz + cc, z, state.c)
    q = B._compress_tree(
        comp, jax.random.fold_in(key, 11), B._sub(z, state.hat_z)
    )
    hat_z = B._add(state.hat_z, q)
    hat_c = B._add(state.hat_c, hat_z)
    z_reps = _deliver_stream(
        state.z_reps, q, hat_z, arrays.nbrs, fr.recv_ok, state.pending,
        repair,
    )
    # hat_v mirrors the classic "2·hat_z_new + old hat_c" ordering with
    # the receiver's own copies
    hat_v = jax.tree_util.tree_map(
        lambda zr, cr: 2.0 * zr + cr, z_reps, state.c_reps
    )
    # receiver-local accumulation happens on every replica (delivered or
    # not — it needs no message), then delivered repairs overwrite
    c_reps = jax.tree_util.tree_map(
        lambda cr, zr: cr + zr, state.c_reps, z_reps
    )
    if repair:
        fixed = fr.recv_ok & state.pending
        hat_c_from = jax.tree_util.tree_map(lambda x: x[arrays.nbrs], hat_c)
        c_reps = jax.tree_util.tree_map(
            lambda cr, cf: jnp.where(_mask2(fixed, cr), cf, cr),
            c_reps, hat_c_from,
        )
    # off(A~)·hat_v + diag(A~)·v with A~ = (I + B)/2 through the replicas
    mixed = mix_replicated(
        0.5 * fr.base.weights[:, :d],
        0.5 * (1.0 + fr.base.weights[:, d]),
        hat_v, v,
    )
    corr = B._sub(mixed, v)
    x_new = B._add(z, corr)
    c_new = B._add(state.c, z)
    wire_bits, repair_bits, pending = _link_traffic(
        arrays, fr, state.pending, repair, innov_bits,
        repair_streams=2, n=_n_total(state.params),
    )
    desync = (
        _desync(arrays.valid, arrays.nbrs, z_reps, hat_z)
        + _desync(arrays.valid, arrays.nbrs, c_reps, hat_c)
    )
    metrics = {
        "loss_mean": jnp.mean(losses),
        "wire_bits": wire_bits,
        "repair_bits": repair_bits,
        "surrogate_desync": desync,
    }
    return (
        RepNidsState(x_new, c_new, hat_z, hat_c, z_reps, c_reps, pending,
                     state.step + 1, state.key),
        metrics,
    )
