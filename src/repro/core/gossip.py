"""Compressed PME exchange — the beyond-paper TPU-native wire format.

The paper's PME transmits s uniformly-sampled coordinates per neighbor.
Simulated densely (core.pme), the node-axis einsum all-gathers FULL masked
tensors: per-device collective traffic is ~m x shard_bytes regardless of s
— the simulation pays what the real wire saves.

This module restores the wire saving with *block-systematic sampling*:
each leaf's leading parameter axis (axis 1 — the layer-scan axis for block
stacks, the vocab axis for embeddings) is split into k = round(1/p)
contiguous classes; node j transmits exactly class o_j^t, an offset drawn
per round from its counter-based seed (only the seed + the [n/k]-sized
slab cross the wire).  Properties:

  * marginal selection probability of every coordinate is exactly
    1/k = p — Theorem 1's count-weighted estimator stays unbiased;
  * the payload is a contiguous slab: no dense masks, no argsort, and the
    node-axis collective moves m x n/k bytes instead of m x n — the
    paper's s/n wire saving realised on the ICI;
  * lambda_{i,c} = |{j in N_i^k : o_j = c}| is a tiny [m, k] count matrix.

Difference vs the paper (DESIGN.md §5): within a round, coordinates move
in blocks (class-correlated) rather than as independent draws; across
rounds every coordinate is exchanged at the same rate.  tests/test_gossip
checks unbiasedness, the self-fill fallback, and convergence parity with
the dense reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_pme_average_pytree", "systematic_offsets"]


def systematic_offsets(key: jax.Array, m: int, k: int) -> jax.Array:
    """Per-node class offset o_j ~ U[0, k)."""
    return jax.random.randint(key, (m,), 0, k)


def _moved_sharding(sharding, axis: int, ndim: int):
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = list(tuple(sharding.spec) + (None,) * (ndim - len(sharding.spec)))
    entry = spec.pop(axis)
    spec.insert(1, entry)
    return NamedSharding(sharding.mesh, P(*spec))


def _leaf_average(
    leaf: jax.Array,      # [m, d1, ...rest]
    offsets: jax.Array,   # [m] int
    a: jax.Array,         # [m, m] selection, A[j, i] = j in N_i^k
    k: int,
    sharding=None,        # leaf's NamedSharding: payload is gathered over
    # the node axis only (the wire exchange), keeping tensor shards intact
    quantize_bits: int = 0,  # 8 -> int8 payloads (+1 f32 scale per message)
) -> jax.Array:
    m = leaf.shape[0]
    if leaf.ndim == 1:  # [m] scalars-per-node: gossip densely (negligible)
        sel = a.astype(jnp.float32)
        cnt = jnp.sum(sel, axis=0)
        agg = jnp.einsum("j,ji->i", leaf.astype(jnp.float32), sel)
        return jnp.where(cnt > 0, agg / jnp.maximum(cnt, 1.0), leaf).astype(leaf.dtype)
    # block along the first UNSHARDED trailing axis: splitting a sharded dim
    # would force a reshard of the whole leaf and erase the wire saving.
    axis = 1
    if sharding is not None:
        spec = tuple(sharding.spec) + (None,) * (leaf.ndim - len(sharding.spec))
        for cand in range(1, leaf.ndim):
            if spec[cand] is None and leaf.shape[cand] >= min(k, 2):
                axis = cand
                break
    if axis != 1:
        leaf_t = jnp.moveaxis(leaf, axis, 1)
        out_t = _leaf_average(
            leaf_t, offsets, a, k,
            sharding=_moved_sharding(sharding, axis, leaf.ndim) if sharding else None,
            quantize_bits=quantize_bits,
        )
        return jnp.moveaxis(out_t, 1, axis)
    d1 = leaf.shape[1]
    rest = leaf.shape[2:]
    kk = min(k, d1)
    pad = (-d1) % kk
    x = leaf
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * len(rest))
    b1 = (d1 + pad) // kk
    classes = x.reshape((m, kk, b1) + rest)
    off = jnp.minimum(offsets, kk - 1)

    idx = off.reshape((m, 1, 1) + (1,) * len(rest))
    payload = jnp.take_along_axis(classes, idx, axis=1)[:, 0]  # [m, b1, *rest]
    if quantize_bits == 8:
        # int8 wire: per-sender absmax scale (one f32 per message).  The
        # all-gather moves 1 byte/coord instead of 2 (bf16) — composable
        # with the paper's privacy discussion (coarser coordinates leak
        # less; cf. Sec. III-D).  Dequantised before averaging.
        red_axes = tuple(range(1, payload.ndim))
        scale = jnp.max(jnp.abs(payload.astype(jnp.float32)), axis=red_axes,
                        keepdims=True)
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(
            jnp.round(payload.astype(jnp.float32) / scale * 127.0), -127, 127
        ).astype(jnp.int8)
        if sharding is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            spec = sharding.spec
            gathered = P(*((None,) + tuple(spec[1:])))
            q = jax.lax.with_sharding_constraint(
                q, NamedSharding(sharding.mesh, gathered)
            )
        payload = (q.astype(jnp.float32) * scale / 127.0).astype(leaf.dtype)
    elif sharding is not None:
        # explicit wire exchange: all-gather ONLY the [m, n/k] payloads over
        # the node axis; every other axis keeps the leaf's tensor sharding.
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = sharding.spec
        gathered = P(*((None,) + tuple(spec[1:])))
        payload = jax.lax.with_sharding_constraint(
            payload, NamedSharding(sharding.mesh, gathered)
        )

    onehot = jax.nn.one_hot(off, kk, dtype=leaf.dtype)          # [m, kk]
    af = a.astype(leaf.dtype)
    # every (receiver, class) pair at once: fold the class one-hot into the
    # selection matrix ([m, m, kk] — tiny: nodes x nodes x classes) and run
    # ONE batched contraction over the sender axis instead of kk separate
    # [m, m] x [m, n/k] einsums dispatched from a Python loop.
    sel = af[:, :, None] * onehot[:, None, :]                    # [j, i, c]
    flat_payload = payload.reshape(m, -1)                        # [j, b1*rest]
    agg = jnp.einsum(
        "jb,jic->icb", flat_payload, sel,
        preferred_element_type=jnp.float32,
    ).reshape((m, kk, b1) + rest)                                # [i, c, b1, *rest]
    cnt = jnp.einsum(
        "ji,jc->ic", af, onehot, preferred_element_type=jnp.float32
    )                                                            # [i, c]
    cnt_b = cnt.reshape((m, kk, 1) + (1,) * len(rest))
    avg = jnp.where(
        cnt_b > 0,
        (agg / jnp.maximum(cnt_b, 1.0)).astype(leaf.dtype),
        classes,
    )
    out = avg.reshape((m, d1 + pad) + rest)
    if pad:
        out = out[:, :d1]
    return out


def compressed_pme_average_pytree(
    key: jax.Array,
    params: object,  # pytree with [m, ...] leaves
    a: jax.Array,    # [m, m]
    p: float,
    shardings: object = None,  # optional matching pytree of NamedShardings
    quantize_bits: int = 0,
) -> object:
    """Drop-in replacement for pme.pme_average_pytree (bernoulli mode)."""
    k = max(2, int(round(1.0 / p)))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for idx, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        lkey = jax.random.fold_in(key, idx)
        m = leaf.shape[0]
        offsets = systematic_offsets(lkey, m, k)
        out.append(
            _leaf_average(leaf, offsets, a, k, sharding=sh,
                          quantize_bits=quantize_bits)
        )
    return jax.tree_util.tree_unflatten(treedef, out)
