"""Scan-fused execution engine for iterative DFL algorithms.

The host-loop drivers (`run_pame`, `run_algorithm`) used to dispatch one
jitted step per Python iteration and block on several `float()` device
syncs every step — on small problems the wall time was dispatch overhead,
not algorithm math.  This engine instead runs `chunk_size` steps per
dispatch inside a single `jax.lax.scan`:

  * the algorithm state is the scan carry and is **donated** back to the
    runtime (`donate_argnums=0`), so multi-MB parameter stacks are updated
    in place across chunks;
  * per-step metrics (loss / consensus / objective / ...) accumulate in
    device-side stacked buffers; the host reads them back with a single
    `jax.device_get` after the run;
  * the paper's std-based termination rule (stop when
    std{f(w^{k-2}), f(w^{k-1}), f(w^k)} < tol) is evaluated *inside* the
    scan on a rolling 3-value window.  Once it fires, the carried state is
    frozen (`jnp.where` select per leaf), so the returned state is exactly
    the state at the triggering step even though the chunk runs to its
    static length.  The host only inspects a single boolean per chunk
    boundary to decide whether to dispatch the next chunk.

`make_scan_runner` returns a closure with a *persistent* jit cache: build
the runner once per (step_fn, objective_fn, chunk_size) combination, warm
it up, and every subsequent run with the same chunk length reuses the
compiled executable — this is what lets benchmarks measure steady-state
`us_per_call` instead of compile time.

Batches are prefetched per chunk on the host (`batch_fn(k)` for each step
of the chunk).  When `batch_fn` returns the *same object* every step (the
common full-batch case) the chunk is compiled with the batch closed over
as a single non-scanned operand instead of stacking `chunk_size` copies.
"""
from __future__ import annotations

import os
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "make_scan_runner", "run_scan_loop", "run_batched", "history_from",
    "staleness_hist", "setup_compilation_cache",
]

DEFAULT_CHUNK_SIZE = 32


def setup_compilation_cache(cache_dir: Optional[str] = None) -> Optional[str]:
    """Point XLA's persistent compilation cache at `cache_dir`.

    Compile time is the dominant fixed cost of every `bind_batched` grid
    dispatch: a fresh process (or a fresh runner closure) re-traces AND
    re-compiles the whole scan even though the program is byte-identical
    to the last run.  With a persistent cache, tracing still happens but
    the XLA compile is replaced by a disk read keyed on the serialized
    HLO + compile options — measured 2.9 s → 0.4 s for the sweep-bench
    grid on CPU.

    `cache_dir` defaults to the `REPRO_COMPILE_CACHE` env var; if neither
    is set this is a no-op returning None (cache disabled).  The two
    min-threshold knobs are zeroed so even sub-second programs are
    cached — this repo's workloads are many small scans, not one big XLA
    program.  The directory fills with `jit_<name>-<fingerprint>` entries
    (plus `-atime` stamps jax uses for LRU eviction); it is safe to
    delete wholesale at any time.

    Returns the directory actually configured (for logging).
    """
    if cache_dir is None:
        cache_dir = os.environ.get("REPRO_COMPILE_CACHE")
    if not cache_dir:
        return None
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _reset_cache_object()
    return cache_dir


def _reset_cache_object() -> None:
    """Make a runtime cache-dir change take effect immediately.

    jax initializes its persistent-cache object lazily ONCE per process;
    after any compile has touched it, flipping
    `jax_compilation_cache_dir` is silently ignored until the object is
    reset.  Without this, `bench_sweep`'s cold-vs-warm race would keep
    reading the previously configured directory.
    """
    try:
        from jax._src.compilation_cache import reset_cache
    except ImportError:  # pragma: no cover - future jax relocation
        return
    reset_cache()


def history_from(metrics: dict, info: dict, keys: dict) -> dict:
    """Assemble a driver `history` dict from a runner's (metrics, info).

    `keys` maps history names to metric names (e.g. {"loss": "loss_mean"});
    values become plain float lists to keep the host-loop schema.
    """
    history = {
        out: [float(v) for v in metrics.get(src, ())]
        for out, src in keys.items()
    }
    history["steps_run"] = info["steps_run"]
    history["steps_dispatched"] = info["steps_dispatched"]
    return history


def staleness_hist(rows) -> list:
    """Collapse per-step ``stale_hist`` rows ([steps, D+1] or an iterable
    of [D+1] rows) into the run-level staleness histogram — the one
    schema every driver (scan, host, training CLI) logs."""
    return [float(v) for v in np.sum(np.asarray(rows), axis=0)]


class _Carry(NamedTuple):
    state: object      # algorithm state pytree (donated across chunks)
    done: jax.Array    # bool scalar — termination rule has fired
    win: jax.Array     # [3] f32 rolling window of objective values
    aux: object = None  # auxiliary user carry (e.g. temporal-process state
    #                     + staleness ring) — threads through the scan with
    #                     the state, frozen by the same termination select


def _sel(pred: jax.Array, t: jax.Array, f: jax.Array) -> jax.Array:
    """jnp.where with `pred` broadcast from the *left*: a scalar pred
    selects whole trees (single-lane runs), a [L] pred selects per lane
    over [L, ...] leaves (batched runs)."""
    p = pred.reshape(pred.shape + (1,) * (t.ndim - pred.ndim))
    return jnp.where(p, t, f)


def _tree_select(pred: jax.Array, on_true: object, on_false: object) -> object:
    return jax.tree_util.tree_map(
        lambda t, f: _sel(pred, t, f), on_true, on_false
    )


def make_scan_runner(
    step_fn: Callable,  # (state, batch) -> (state, metrics dict of scalars)
    *,
    objective_fn: Optional[Callable[[object], jax.Array]] = None,
    params_of: Callable = lambda s: s.params,
    tol_std: float = 1e-3,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    donate: bool = True,
    step_takes_index: bool = False,
    carries_aux: bool = False,
    lanes: Optional[int] = None,
) -> Callable[..., Tuple[object, dict, dict]]:
    """Build a reusable chunked-scan driver.

    Returns ``run(state, batch_fn, num_steps) -> (state, metrics, info)``
    where ``metrics`` maps each key of the step's metric dict (plus
    ``"objective"`` when ``objective_fn`` is given) to a host ``np.ndarray``
    of length ``info["steps_run"]``, and ``info["steps_dispatched"]`` counts
    the steps actually executed on device (chunk-rounded past an early
    termination — the right denominator for wall-clock-per-step).  Compiled
    chunk executables are cached on the runner, so repeat runs with the
    same shapes skip compilation.

    ``lanes=L`` turns the runner into the vmap-over-lanes batched engine:
    ``step_fn`` is expected to be lane-batched (state leaves ``[L, m,
    ...]``, per-step metric values of shape ``[L]`` — see
    ``Algorithm.bind_batched``), ``objective_fn`` stays per-lane (it is
    vmapped here over the lane axis of the node-mean parameters), the
    std-termination rule runs per lane with the frozen-state select
    applied lane-wise (a finished lane's state/aux stop moving while the
    other lanes run on), and the chunk loop stops only when *every* lane
    has fired.  ``metrics`` values then come back as ``[steps, L]``
    arrays (untruncated — per-lane lengths live in ``info["steps_run"]``,
    an ``[L]`` int array).  One traced program, one compile, S·C lanes.

    ``step_takes_index=True`` calls ``step_fn(state, batch, k)`` with the
    global step index as a traced i32 scalar — dynamic-network scenario
    steps fold it into their PRNG key to realize the step's graph inside
    the scan (the scenario's counter rides the scan carry alongside the
    algorithm state).  ``run(..., k_start=)`` offsets the index for
    callers that drive chunks manually (e.g. the training CLI), so
    realizations stay aligned with the global step across runner calls.
    The default (False) leaves the traced program unchanged.

    ``carries_aux=True`` adds an auxiliary user-carry slot: ``run(...,
    aux=aux0)`` seeds it, the step is called as ``step_fn(state, batch,
    [k,] aux)`` and must return ``(new_state, metrics, new_aux)``, and the
    final aux comes back in ``info["aux"]``.  The aux pytree lives in the
    scan carry next to the algorithm state — temporal-process Markov state
    and the bounded-staleness parameter ring ride it across steps with no
    host round-trips — and is frozen by the same termination select as the
    state.
    """

    def _scan_body(carry: _Carry, k: jax.Array, k_rel: jax.Array, batch: object):
        step_args = (carry.state, batch)
        if step_takes_index:
            step_args += (k,)
        if carries_aux:
            new_state, metrics, new_aux = step_fn(*step_args, carry.aux)
        else:
            new_state, metrics = step_fn(*step_args)
            new_aux = carry.aux
        if objective_fn is not None:
            # node axis is 0 for single runs, 1 behind the lane axis
            mean_params = jax.tree_util.tree_map(
                lambda x: x.mean(axis=0 if lanes is None else 1),
                params_of(new_state),
            )
            obj_fn = objective_fn if lanes is None else jax.vmap(objective_fn)
            obj = obj_fn(mean_params).astype(jnp.float32)  # [] or [L]
            win = jnp.concatenate([carry.win[..., 1:], obj[..., None]], -1)
            # guard on steps into *this run* (k_rel), not the global index:
            # each run() starts a fresh zero window, and a k_start > 0 run
            # must still fill all three slots before the rule can fire.
            trigger = (k_rel >= 2) & (jnp.std(win, axis=-1) < tol_std)
        else:
            obj = None
            win = carry.win
            trigger = jnp.zeros((() if lanes is None else (lanes,)), bool)
        # A step that runs *after* the rule fired is a no-op: keep the frozen
        # state so the returned state is exactly the triggering step's (per
        # lane, when batched).
        frozen = carry.done
        out_state = _tree_select(frozen, carry.state, new_state)
        out_aux = _tree_select(frozen, carry.aux, new_aux)
        out_win = _sel(frozen, carry.win, win)
        done = carry.done | trigger
        ys = dict(metrics)
        if obj is not None:
            ys["objective"] = obj
        ys["_stopped"] = done
        return _Carry(out_state, done, out_win, out_aux), ys

    compiled: dict = {}  # (length, const_batch) -> jitted chunk fn

    def _chunk_fn(length: int, const_batch: bool):
        key = (length, const_batch)
        if key not in compiled:

            def chunk(carry, batch, k0, r0):
                ks = k0 + jnp.arange(length)
                rs = r0 + jnp.arange(length)
                if const_batch:
                    body = lambda c, kr: _scan_body(c, kr[0], kr[1], batch)
                    return jax.lax.scan(body, carry, (ks, rs))
                body = lambda c, krb: _scan_body(c, krb[0], krb[1], krb[2])
                return jax.lax.scan(body, carry, (ks, rs, batch))

            compiled[key] = jax.jit(
                chunk, donate_argnums=(0,) if donate else ()
            )
        return compiled[key]

    def run(
        state: object,
        batch_fn: Callable[[int], object],
        num_steps: int,
        *,
        copy_state: bool = True,
        k_start: int = 0,
        aux: object = None,
    ) -> Tuple[object, dict, dict]:
        if carries_aux and aux is None:
            raise ValueError("carries_aux runner needs run(..., aux=aux0)")
        if donate and copy_state:
            # The first chunk donates the carry's buffers; copy so the
            # caller's initial state (often shared across runs) survives.
            # Callers that hand over ownership (e.g. a training loop that
            # immediately rebinds to the returned state) pass
            # copy_state=False and skip the deep copy.
            state, aux = jax.tree_util.tree_map(
                lambda x: x.copy() if isinstance(x, jax.Array) else x,
                (state, aux),
            )
        carry = _Carry(
            state=state,
            done=jnp.zeros((() if lanes is None else (lanes,)), bool),
            win=jnp.zeros(
                ((3,) if lanes is None else (lanes, 3)), jnp.float32
            ),
            aux=aux,
        )
        leaves0, treedef0 = None, None

        def _same_batch(b, first):
            # identity on the *leaves*, not the container: batch_fn often
            # rebuilds the tuple/dict around the same arrays each step, and
            # stacking chunk_size aliases of a big batch would be an
            # accidental chunk_size-fold device allocation.
            if b is first:
                return True
            lv, td = jax.tree_util.tree_flatten(b)
            return (
                td == treedef0
                and len(lv) == len(leaves0)
                and all(x is y for x, y in zip(lv, leaves0))
            )

        ys_chunks = []
        k0 = k_start
        end = k_start + num_steps
        while k0 < end:
            length = min(chunk_size, end - k0)
            batches = [batch_fn(k) for k in range(k0, k0 + length)]
            leaves0, treedef0 = jax.tree_util.tree_flatten(batches[0])
            const = all(_same_batch(b, batches[0]) for b in batches[1:])
            if const:
                batch = batches[0]
            else:
                batch = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *batches
                )
            carry, ys = _chunk_fn(length, const)(
                carry, batch, jnp.asarray(k0, jnp.int32),
                jnp.asarray(k0 - k_start, jnp.int32),
            )
            ys_chunks.append(ys)
            k0 += length
            # one scalar sync per chunk boundary — the only mid-run readback
            # (batched runs stop once *every* lane's rule has fired)
            if objective_fn is not None and bool(
                jax.device_get(carry.done.all())
            ):
                break
        if not ys_chunks:
            zero_steps = 0 if lanes is None else np.zeros(lanes, np.int64)
            return carry.state, {}, {
                "steps_run": zero_steps, "steps_dispatched": 0,
                "aux": carry.aux,
            }
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *ys_chunks
        )
        host = jax.device_get(stacked)  # single bulk readback of all metrics
        stopped = host.pop("_stopped")  # [steps] or [steps, L]
        if lanes is None:
            steps_run = (
                int(np.argmax(stopped)) + 1 if stopped.any()
                else int(len(stopped))
            )
            metrics = {key: val[:steps_run] for key, val in host.items()}
        else:
            fired = stopped.any(axis=0)  # [L]
            steps_run = np.where(
                fired, np.argmax(stopped, axis=0) + 1, len(stopped)
            ).astype(np.int64)
            # per-lane lengths differ; hand back the full [steps, L] buffers
            metrics = dict(host)
        return carry.state, metrics, {
            "steps_run": steps_run,
            "steps_dispatched": k0 - k_start,
            "aux": carry.aux,
        }

    return run


def run_scan_loop(
    step_fn: Callable,
    state: object,
    batch_fn: Callable[[int], object],
    num_steps: int,
    *,
    objective_fn: Optional[Callable] = None,
    params_of: Callable = lambda s: s.params,
    tol_std: float = 1e-3,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    donate: bool = True,
    step_takes_index: bool = False,
    carries_aux: bool = False,
    aux: object = None,
) -> Tuple[object, dict, dict]:
    """One-shot convenience wrapper over `make_scan_runner`."""
    runner = make_scan_runner(
        step_fn,
        objective_fn=objective_fn,
        params_of=params_of,
        tol_std=tol_std,
        chunk_size=chunk_size,
        donate=donate,
        step_takes_index=step_takes_index,
        carries_aux=carries_aux,
    )
    return runner(state, batch_fn, num_steps, aux=aux)


def run_batched(
    step_fn: Callable,   # lane-batched: state leaves [L, m, ...], metrics [L]
    state: object,
    batch_fn: Callable[[int], object],
    num_steps: int,
    *,
    lanes: int,
    objective_fn: Optional[Callable] = None,
    params_of: Callable = lambda s: s.params,
    tol_std: float = 1e-3,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    donate: bool = True,
    step_takes_index: bool = False,
    carries_aux: bool = False,
    aux: object = None,
) -> Tuple[object, dict, dict]:
    """One-shot batched (vmap-over-lanes) scan run.

    The lane axis — S seeds × C hyperparameter configs, flattened — is
    threaded through the scan carry (state, aux, per-lane termination
    window) so the whole sweep is ONE jitted program: one compile, one
    dispatch stream, per-lane metric buffers coming back as ``[steps,
    L]`` arrays with per-lane ``info["steps_run"]``.  ``step_fn`` must
    already be lane-batched; ``Algorithm.bind_batched`` builds one from
    any registered algorithm (per-lane PRNG folds via per-lane state
    keys, per-lane hyperparameters as traced scalars).
    ``objective_fn`` remains the per-run callable — it is vmapped over
    the lane axis of the node-mean parameters here.
    """
    runner = make_scan_runner(
        step_fn,
        objective_fn=objective_fn,
        params_of=params_of,
        tol_std=tol_std,
        chunk_size=chunk_size,
        donate=donate,
        step_takes_index=step_takes_index,
        carries_aux=carries_aux,
        lanes=lanes,
    )
    return runner(state, batch_fn, num_steps, aux=aux)
