"""Unified algorithm registry for decentralized FL.

The paper's headline claims are comparative (PaME vs D-PSGD / DFedSAM /
CHOCO-SGD / BEER / (AN)Q-NIDS, Figs. 8–10), yet each implementation used a
bespoke ``*_init``/``*_step`` signature that every harness hand-wired with
lambdas.  This module gives all six one contract:

  * :class:`Algorithm` — a named spec with per-algorithm hyperparameter
    dataclasses, ``init``/``step`` glue, per-step :func:`wire_bits`
    accounting (expected bits on the wire per step, network-wide), and
    ``params_of`` for reading the node-stacked parameters out of any state.
  * :func:`register` / :func:`get_algorithm` / :func:`list_algorithms` —
    the registry the launcher (``--algo``) and the benchmark race iterate.
  * :meth:`Algorithm.bind` — closes a spec over (grad_fn, topology, hps,
    mixing mode) and returns a :class:`BoundAlgorithm` whose ``step`` is
    engine-ready: run it through ``repro.core.engine`` scan chunks or the
    host loop via :meth:`BoundAlgorithm.run`.

Gossip in every bound baseline routes through ``repro.core.mixing``:
``mixing="sparse"`` (default) contracts the node axis in padded
neighbor-exchange form, O(m·deg·n); ``mixing="dense"`` is the
bit-identical full-connectivity escape hatch; ``mixing="matrix"`` keeps
the legacy dense einsum.

Extending::

    @dataclasses.dataclass(frozen=True)
    class MyHp:
        lr: float = 0.1

    register(Algorithm(
        name="mine", hp_cls=MyHp,
        init=lambda key, stacked, ctx, batch0: my_init(key, stacked),
        step=lambda state, batch, ctx: my_step(
            state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr),
        wire_bits=lambda topo, hps, n: float(topo.degrees.sum()) * 64 * n,
    ))
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as B
from repro.core import engine
from repro.core import pame as pame_mod
from repro.core import scenarios as scen_mod
from repro.core import temporal as temp_mod
from repro.core.compression import qsgd, rand_k
from repro.core.mixing import Mixer, make_mixer, ring_gather
from repro.core.pme import message_bits
from repro.core.topology import Topology

AnyScenario = Union[scen_mod.Scenario, temp_mod.TemporalScenario]

__all__ = [
    "Algorithm", "BoundAlgorithm", "AlgoContext",
    "register", "get_algorithm", "list_algorithms",
    "PaMEHp", "DPSGDHp", "DFedSAMHp", "ChocoHp", "BeerHp", "AnqNidsHp",
]


# ---------------------------------------------------------------------------
# Per-algorithm hyperparameters.  PaME reuses its paper-Table-II config.
# ---------------------------------------------------------------------------
PaMEHp = pame_mod.PaMEConfig


@dataclasses.dataclass(frozen=True)
class DPSGDHp:
    lr: float = 0.1


@dataclasses.dataclass(frozen=True)
class DFedSAMHp:
    lr: float = 0.1
    rho: float = 0.05       # SAM ascent radius
    local_steps: int = 1


@dataclasses.dataclass(frozen=True)
class ChocoHp:
    lr: float = 0.05
    gossip_gamma: float = 0.3
    comp_frac: float = 0.3  # contractive rand-k keep fraction
    value_bits: int = 64


@dataclasses.dataclass(frozen=True)
class BeerHp:
    lr: float = 0.05
    gossip_gamma: float = 0.4
    comp_frac: float = 0.2
    value_bits: int = 64


@dataclasses.dataclass(frozen=True)
class AnqNidsHp:
    lr: float = 0.1
    qsgd_levels: int = 16


@dataclasses.dataclass(frozen=True)
class AlgoContext:
    """Everything a registered step needs beyond (state, batch)."""

    grad_fn: Callable
    topo: Topology
    hps: object
    mixer: Mixer
    extras: dict


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered DFL algorithm.

    ``init(key, params_stacked, ctx, batch0) -> state`` (``batch0`` is only
    consulted when ``needs_batch0``), ``step(state, batch, ctx) -> (state,
    metrics)`` with a ``loss_mean`` metric, ``wire_bits(topo, hps, n) ->
    float`` expected bits transmitted network-wide per *step* for an
    n-coordinate model, and ``params_of(state)`` the node-stacked pytree.
    """

    name: str
    hp_cls: type
    init: Callable
    step: Callable
    wire_bits: Callable
    params_of: Callable = staticmethod(lambda s: s.params)
    needs_batch0: bool = False
    # optional (topo, hps, mixing, seed) -> dict merged into ctx.extras
    setup: Optional[Callable] = None
    # optional (hps, n) -> bits per realized *directed* edge per step; used
    # by dynamic-network scenario runs to charge only surviving links.
    # Algorithms whose step emits its own "wire_bits" metric (PaME) or that
    # send nothing leave this None.
    edge_bits: Optional[Callable] = None

    def bind(
        self,
        grad_fn: Callable,
        topo: Topology,
        hps: Optional[object] = None,
        *,
        mixing: str = "sparse",
        seed: int = 0,
        scenario: Optional[AnyScenario] = None,
    ) -> "BoundAlgorithm":
        """Close the spec over (grad_fn, topology, hps, mixing, scenario).

        ``scenario=None`` or a static scenario keeps the existing
        fixed-``Topology`` program exactly (bit-identical); a dynamic
        scenario wraps the step so each global step k realizes its own
        doubly-stochastic mixing matrix on device (see
        ``repro.core.scenarios``), freezes dropped nodes' state, and logs
        realized per-step ``wire_bits``.  A ``TemporalScenario``
        (``repro.core.temporal``) additionally threads Markov link/node
        state and the bounded-staleness snapshot ring through the
        engine's auxiliary carry slot; its step signature grows to
        ``step(state, batch, k, aux) -> (state, metrics, aux)``.
        """
        hps = self.hp_cls() if hps is None else hps
        if not isinstance(hps, self.hp_cls):
            raise TypeError(
                f"{self.name} expects {self.hp_cls.__name__}, got {type(hps).__name__}"
            )
        extras = dict(self.setup(topo, hps, mixing, seed)) if self.setup else {}
        if "hps" in extras:  # setup may rewrite hps (e.g. PaME's mixing field)
            hps = extras.pop("hps")
        mixer = make_mixer(topo, "matrix" if mixing == "matrix" else mixing)
        ctx = AlgoContext(grad_fn=grad_fn, topo=topo, hps=hps, mixer=mixer,
                          extras=extras)
        if scenario is not None and not scenario.is_static:
            return BoundAlgorithm(
                self, ctx, scenario=scenario,
                scen_arrays=scen_mod.make_scenario_arrays(topo, scenario),
                mixing_mode=mixing,
            )
        return BoundAlgorithm(self, ctx)


class BoundAlgorithm:
    """An Algorithm closed over (grad_fn, topology, hps, mixer).

    ``step`` is a plain ``(state, batch) -> (state, metrics)`` closure,
    directly consumable by ``engine.make_scan_runner`` or ``jax.jit``.
    When a dynamic scenario is bound, ``step`` instead takes ``(state,
    batch, k)`` — the global step index realizes the step's network — and
    the engine must be built with ``step_takes_index=True`` (``run`` /
    ``make_runner`` do this automatically).  A ``TemporalScenario`` bind
    further extends the signature to ``step(state, batch, k, aux) ->
    (state, metrics, aux)``, where ``aux`` is the ``TemporalCarry``
    (Markov chain state + staleness ring) built by :meth:`aux_init` and
    threaded through the engine's auxiliary carry slot
    (``carries_aux=True``).
    """

    def __init__(
        self,
        spec: Algorithm,
        ctx: AlgoContext,
        scenario: Optional[AnyScenario] = None,
        scen_arrays: Optional[scen_mod.ScenarioArrays] = None,
        mixing_mode: str = "sparse",
    ):
        self.spec = spec
        self.ctx = ctx
        self.scenario = scenario
        self.scen_arrays = scen_arrays
        self._mixing_mode = mixing_mode

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def hps(self) -> object:
        return self.ctx.hps

    @property
    def dynamic(self) -> bool:
        """True when a non-static scenario is bound (step takes k)."""
        return self.scenario is not None

    @property
    def temporal(self) -> bool:
        """True when the bound scenario is a TemporalScenario (step
        threads the auxiliary carry — run/make_runner pass it to the
        engine as ``carries_aux``)."""
        return isinstance(self.scenario, temp_mod.TemporalScenario)

    @property
    def params_of(self) -> Callable:
        return self.spec.params_of

    def init(self, key: jax.Array, params_stacked: object,
             batch0: Optional[object] = None) -> object:
        if self.spec.needs_batch0 and batch0 is None:
            raise ValueError(f"{self.name} needs batch0 at init")
        return self.spec.init(key, params_stacked, self.ctx, batch0)

    def aux_init(self, state: object) -> temp_mod.TemporalCarry:
        """Initial auxiliary carry for a temporal bind: stationary Markov
        draws + the staleness ring seeded with the initial parameters."""
        if not self.temporal:
            raise TypeError(f"{self.name} is not bound to a TemporalScenario")
        return temp_mod.temporal_carry_init(
            self.scenario, self.scen_arrays, self.spec.params_of(state)
        )

    def step(self, state: object, batch: object,
             k: Optional[jax.Array] = None,
             aux: Optional[temp_mod.TemporalCarry] = None):
        if not self.dynamic:
            return self.spec.step(state, batch, self.ctx)
        if k is None:
            raise TypeError(
                f"{self.name} is bound to scenario {self.scenario.name!r}: "
                "step(state, batch, k) needs the global step index"
            )
        if self.temporal:
            if aux is None:
                raise TypeError(
                    f"{self.name} is bound to temporal scenario "
                    f"{self.scenario.name!r}: step(state, batch, k, aux) "
                    "needs the TemporalCarry (see aux_init)"
                )
            return self._temporal_step(state, batch,
                                       jnp.asarray(k, jnp.int32), aux)
        return self._dynamic_step(state, batch, jnp.asarray(k, jnp.int32))

    def _realized_metrics(self, r: scen_mod.Realization, state: object,
                          metrics: dict) -> dict:
        """Realized wire accounting shared by the i.i.d. and temporal paths:
        algorithms without their own per-message metric are charged
        edge_bits on every realized directed edge."""
        if "wire_bits" not in metrics:
            n = sum(
                int(np.prod(leaf.shape[1:]))
                for leaf in jax.tree_util.tree_leaves(self.spec.params_of(state))
            )
            eb = self.spec.edge_bits(self.ctx.hps, n) if self.spec.edge_bits else 0.0
            metrics["wire_bits"] = (
                r.directed_edges.astype(jnp.float32) * float(eb)
            )
        metrics["alive_nodes"] = jnp.sum(r.alive.astype(jnp.int32))
        return metrics

    def _dynamic_step(self, state: object, batch: object,
                      k: jax.Array) -> Tuple[object, dict]:
        """One step under the bound scenario (fully traceable).

        Realizes step k's graph from the folded scenario key, swaps the
        per-step mixer into the context, reverts dropped nodes' state
        bitwise, and charges only realized edges on the wire.
        """
        r = scen_mod.realize(self.scenario, self.scen_arrays, k)
        mixer = scen_mod.scenario_mixer(self.scen_arrays, r, self._mixing_mode)
        ctx_t = dataclasses.replace(
            self.ctx, mixer=mixer,
            extras={**self.ctx.extras, "realization": r},
        )
        new_state, metrics = self.spec.step(state, batch, ctx_t)
        new_state = scen_mod.freeze_dropped(r.alive, state, new_state)
        return new_state, self._realized_metrics(r, state, metrics)

    def _temporal_step(self, state: object, batch: object, k: jax.Array,
                       aux: temp_mod.TemporalCarry):
        """One step under the bound TemporalScenario (fully traceable).

        Advances the Markov chains from the carried state, realizes the
        step's doubly-stochastic matrix with delayed stragglers still
        participating, substitutes their ring-gathered t-delayed
        parameters into the exchange (consistently: the whole step runs
        on the substituted stack, so every public quantity derived from a
        delayed node's parameters is the delayed version), and afterwards
        re-adds each delayed node's private innovation (fresh − delayed)
        to its own row — which restores the global parameter sum exactly,
        for every realized matrix.  Requires the algorithm state to carry
        its node-stacked parameters in a ``params`` field (all built-in
        registrations do).
        """
        new_ts, r, delayed, tau = temp_mod.advance(
            self.scenario, self.scen_arrays, aux.ts, k
        )
        mixer = scen_mod.scenario_mixer(self.scen_arrays, r, self._mixing_mode)
        ctx_t = dataclasses.replace(
            self.ctx, mixer=mixer,
            extras={**self.ctx.extras, "realization": r},
        )
        d_max = self.scenario.staleness
        ring = aux.ring
        if d_max > 0:
            fresh = self.spec.params_of(state)
            slot = jnp.mod(k - tau, d_max)
            eff = ring_gather(ring, fresh, slot, delayed)
            state_in = state._replace(params=eff)
        else:
            state_in = state
        new_state, metrics = self.spec.step(state_in, batch, ctx_t)
        if d_max > 0:
            def _readd(p, f, e):
                keep = delayed.reshape((-1,) + (1,) * (p.ndim - 1))
                return p + jnp.where(keep, f - e, jnp.zeros_like(p))

            new_params = jax.tree_util.tree_map(
                _readd, self.spec.params_of(new_state), fresh, eff
            )
            new_state = new_state._replace(params=new_params)
            ring = temp_mod.ring_push(ring, fresh, k, d_max)
            tgrid = jnp.arange(d_max + 1, dtype=jnp.int32)
            metrics["stale_hist"] = jnp.sum(
                (tau[:, None] == tgrid[None, :]) & r.participating[:, None],
                axis=0,
            ).astype(jnp.float32)
            metrics["stale_nodes"] = jnp.sum(delayed.astype(jnp.int32))
        new_state = scen_mod.freeze_dropped(r.alive, state, new_state)
        metrics = self._realized_metrics(r, state, metrics)
        return new_state, metrics, temp_mod.TemporalCarry(new_ts, ring)

    def wire_bits(self, n: int) -> float:
        """Expected bits on the wire per step, summed over the network."""
        return float(self.spec.wire_bits(self.ctx.topo, self.ctx.hps, n))

    def make_runner(
        self,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Callable:
        """Persistent scan runner (compiled chunks cached across calls):
        ``run(key, params0, m, batch_fn, num_steps) -> (state, history)``."""
        runner = engine.make_scan_runner(
            self.step, objective_fn=objective_fn, params_of=self.spec.params_of,
            tol_std=tol_std, chunk_size=chunk_size,
            step_takes_index=self.dynamic, carries_aux=self.temporal,
        )

        def run(key, params0, m, batch_fn, num_steps):
            stacked = B.stack_params(params0, m)
            batch0 = batch_fn(0) if self.spec.needs_batch0 else None
            state = self.init(key, stacked, batch0)
            aux = self.aux_init(state) if self.temporal else None
            state, metrics, info = runner(state, batch_fn, num_steps, aux=aux)
            info = dict(info)
            info.pop("aux", None)
            history = {
                key_: [float(v) for v in vals]
                for key_, vals in metrics.items()
                if key_ != "stale_hist"
            }
            if "stale_hist" in metrics:
                history["staleness_hist"] = engine.staleness_hist(
                    metrics["stale_hist"]
                )
            history["loss"] = history.pop("loss_mean", [])
            history.update(info)
            self._account_wire(history, params0)
            return state, history

        return run

    def run(
        self,
        key: jax.Array,
        params0: object,
        m: int,
        batch_fn: Callable[[int], object],
        num_steps: int,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        driver: str = "scan",
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Tuple[object, dict]:
        """One-shot race driver (scan or host), with wire accounting."""
        stacked = B.stack_params(params0, m)
        batch0 = batch_fn(0) if self.spec.needs_batch0 else None
        state = self.init(key, stacked, batch0)
        aux = self.aux_init(state) if self.temporal else None
        state, history = B.run_algorithm(
            self.step, state, batch_fn, num_steps,
            objective_fn=objective_fn, params_of=self.spec.params_of,
            tol_std=tol_std, driver=driver, chunk_size=chunk_size,
            step_takes_index=self.dynamic,
            carries_aux=self.temporal, aux=aux,
        )
        self._account_wire(history, params0)
        return state, history

    def _account_wire(self, history: dict, params0: object) -> None:
        per_step = history.get("wire_bits")
        if per_step:
            # dynamic scenario: only realized (surviving) edges were charged
            history["wire_bits_total"] = float(np.sum(per_step))
            history["wire_bits_per_step"] = (
                history["wire_bits_total"] / max(len(per_step), 1)
            )
            return
        history.pop("wire_bits", None)  # static runs keep the legacy schema
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params0))
        history["wire_bits_per_step"] = self.wire_bits(n)
        history["wire_bits_total"] = (
            history["wire_bits_per_step"] * history["steps_run"]
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(alg: Algorithm) -> Algorithm:
    if alg.name in _REGISTRY:
        raise ValueError(f"algorithm {alg.name!r} already registered")
    _REGISTRY[alg.name] = alg
    return alg


def get_algorithm(name: str) -> Algorithm:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown algorithm {name!r}; pick from {list(_REGISTRY)}"
        )
    return _REGISTRY[name]


def list_algorithms() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# Wire accounting helpers (Eq. (8) + per-algorithm message formats)
# ---------------------------------------------------------------------------
def _dense_edges_bits(topo: Topology, n: int, bits_per_msg: float) -> float:
    """Every node sends one message to every neighbor each step."""
    return float(topo.degrees.sum()) * bits_per_msg


# bits per *directed* edge per step for the gossip baselines; the static
# wire_bits formulas below are (base directed edge count) × these, and the
# dynamic scenario path charges (realized directed edge count) × these.
def _full_msg_bits(hps, n: int) -> float:
    return float(message_bits(n, n))


def _choco_edge_bits(hps, n: int) -> float:
    return float(rand_k(hps.comp_frac, hps.value_bits, rescale=False).bits(n))


def _beer_edge_bits(hps, n: int) -> float:
    # two compressed streams per edge per step (x and gradient surrogates)
    return 2.0 * _choco_edge_bits(hps, n)


def _anq_edge_bits(hps, n: int) -> float:
    return float(qsgd(hps.qsgd_levels).bits(n))


def _pame_wire_bits(topo: Topology, hps: PaMEHp, n: int) -> float:
    """Expected bits/step: receiver i pulls t_i sparse messages of
    message_bits(s, n) in the 1/kappa_i fraction of steps it communicates
    (int8 message format when exchange="compressed_q8")."""
    s = max(1, int(round(hps.p * n)))
    t = np.maximum(1, np.floor(hps.nu * topo.degrees))
    if hps.homogeneous_kappa is not None:
        inv_kappa = 1.0 / float(hps.homogeneous_kappa)
    else:
        ks = np.arange(hps.kappa_lo, hps.kappa_hi + 1, dtype=np.float64)
        inv_kappa = float(np.mean(1.0 / ks))
    value_bits = 8 if hps.exchange == "compressed_q8" else 64
    return float(t.sum()) * inv_kappa * message_bits(s, n, value_bits)


# ---------------------------------------------------------------------------
# Registrations — PaME + the five baselines of Figs. 8–10
# ---------------------------------------------------------------------------
def _pame_setup(topo, hps, mixing, seed):
    # the bind-level mixing mode governs the node-axis contraction
    mode = "sparse" if mixing == "sparse" else "dense"
    hps = dataclasses.replace(hps, mixing=mode)
    return {
        "hps": hps,
        "topo_arrays": pame_mod.make_topology_arrays(topo, hps, seed=seed),
    }


register(Algorithm(
    name="pame",
    hp_cls=PaMEHp,
    init=lambda key, stacked, ctx, batch0: pame_mod.pame_init(
        key, stacked, ctx.topo.m, ctx.hps),
    step=lambda state, batch, ctx: pame_mod.pame_step(
        state, batch, ctx.grad_fn, ctx.extras["topo_arrays"], ctx.hps,
        realization=ctx.extras.get("realization")),
    wire_bits=_pame_wire_bits,
    setup=_pame_setup,
    # PaME's step emits its own realized "wire_bits" (per-message Eq. (8)
    # on the selected surviving neighbors), so no per-edge rate here.
))

register(Algorithm(
    name="dpsgd",
    hp_cls=DPSGDHp,
    init=lambda key, stacked, ctx, batch0: B.dpsgd_init(key, stacked),
    step=lambda state, batch, ctx: B.dpsgd_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _full_msg_bits(hps, n)),
    edge_bits=_full_msg_bits,
))

register(Algorithm(
    name="dfedsam",
    hp_cls=DFedSAMHp,
    init=lambda key, stacked, ctx, batch0: B.dfedsam_init(key, stacked),
    step=lambda state, batch, ctx: B.dfedsam_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        rho=ctx.hps.rho, local_steps=ctx.hps.local_steps),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _full_msg_bits(hps, n)),
    edge_bits=_full_msg_bits,
))


def _choco_setup(topo, hps, mixing, seed):
    return {"comp": rand_k(hps.comp_frac, hps.value_bits, rescale=False)}


register(Algorithm(
    name="choco",
    hp_cls=ChocoHp,
    init=lambda key, stacked, ctx, batch0: B.choco_init(key, stacked),
    step=lambda state, batch, ctx: B.choco_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        ctx.extras["comp"], ctx.hps.gossip_gamma),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _choco_edge_bits(hps, n)),
    edge_bits=_choco_edge_bits,
    setup=_choco_setup,
))

register(Algorithm(
    name="beer",
    hp_cls=BeerHp,
    init=lambda key, stacked, ctx, batch0: B.beer_init(
        key, stacked, batch0, ctx.grad_fn),
    step=lambda state, batch, ctx: B.beer_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        ctx.extras["comp"], ctx.hps.gossip_gamma),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _beer_edge_bits(hps, n)),
    edge_bits=_beer_edge_bits,
    needs_batch0=True,
    setup=_choco_setup,
))

register(Algorithm(
    name="anq_nids",
    hp_cls=AnqNidsHp,
    init=lambda key, stacked, ctx, batch0: B.nids_init(
        key, stacked, batch0, ctx.grad_fn, ctx.hps.lr),
    step=lambda state, batch, ctx: B.nids_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr, ctx.extras["q"]),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _anq_edge_bits(hps, n)),
    edge_bits=_anq_edge_bits,
    needs_batch0=True,
    setup=lambda topo, hps, mixing, seed: {"q": qsgd(hps.qsgd_levels)},
))
