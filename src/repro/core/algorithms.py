"""Unified algorithm registry for decentralized FL.

The paper's headline claims are comparative (PaME vs D-PSGD / DFedSAM /
CHOCO-SGD / BEER / (AN)Q-NIDS, Figs. 8–10), yet each implementation used a
bespoke ``*_init``/``*_step`` signature that every harness hand-wired with
lambdas.  This module gives all six one contract:

  * :class:`Algorithm` — a named spec with per-algorithm hyperparameter
    dataclasses, ``init``/``step`` glue, per-step :func:`wire_bits`
    accounting (expected bits on the wire per step, network-wide), and
    ``params_of`` for reading the node-stacked parameters out of any state.
  * :func:`register` / :func:`get_algorithm` / :func:`list_algorithms` —
    the registry the launcher (``--algo``) and the benchmark race iterate.
  * :meth:`Algorithm.bind` — closes a spec over (grad_fn, topology, hps,
    mixing mode) and returns a :class:`BoundAlgorithm` whose ``step`` is
    engine-ready: run it through ``repro.core.engine`` scan chunks or the
    host loop via :meth:`BoundAlgorithm.run`.

Gossip in every bound baseline routes through ``repro.core.mixing``:
``mixing="sparse"`` (default) contracts the node axis in padded
neighbor-exchange form, O(m·deg·n); ``mixing="dense"`` is the
bit-identical full-connectivity escape hatch; ``mixing="matrix"`` keeps
the legacy dense einsum.

Extending::

    @dataclasses.dataclass(frozen=True)
    class MyHp:
        lr: float = 0.1

    register(Algorithm(
        name="mine", hp_cls=MyHp,
        init=lambda key, stacked, ctx, batch0: my_init(key, stacked),
        step=lambda state, batch, ctx: my_step(
            state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr),
        wire_bits=lambda topo, hps, n: float(topo.degrees.sum()) * 64 * n,
    ))
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as B
from repro.core import engine
from repro.core import faults as flt_mod
from repro.core import pame as pame_mod
from repro.core import scenarios as scen_mod
from repro.core import temporal as temp_mod
from repro.core.compression import qsgd, rand_k
from repro.core.mixing import Mixer, make_mixer, ring_gather
from repro.core.pme import leaf_rates as pme_leaf_rates
from repro.core.pme import message_bits, tree_message_bits
from repro.core.topology import Topology
from repro.serve.events import PacedCarry, ServePacing

AnyScenario = Union[scen_mod.Scenario, temp_mod.TemporalScenario]

__all__ = [
    "Algorithm", "BoundAlgorithm", "BatchedAlgorithm", "AlgoContext",
    "register", "get_algorithm", "list_algorithms", "lane_finals",
    "PaMEHp", "DPSGDHp", "DFedSAMHp", "ChocoHp", "BeerHp", "AnqNidsHp",
]


# ---------------------------------------------------------------------------
# Per-algorithm hyperparameters.  PaME reuses its paper-Table-II config.
# ---------------------------------------------------------------------------
PaMEHp = pame_mod.PaMEConfig


@dataclasses.dataclass(frozen=True)
class DPSGDHp:
    lr: float = 0.1


@dataclasses.dataclass(frozen=True)
class DFedSAMHp:
    lr: float = 0.1
    rho: float = 0.05       # SAM ascent radius
    local_steps: int = 1


@dataclasses.dataclass(frozen=True)
class ChocoHp:
    lr: float = 0.05
    gossip_gamma: float = 0.3
    comp_frac: float = 0.3  # contractive rand-k keep fraction
    value_bits: int = 64


@dataclasses.dataclass(frozen=True)
class BeerHp:
    lr: float = 0.05
    gossip_gamma: float = 0.4
    comp_frac: float = 0.2
    value_bits: int = 64


@dataclasses.dataclass(frozen=True)
class AnqNidsHp:
    lr: float = 0.1
    qsgd_levels: int = 16


@dataclasses.dataclass(frozen=True)
class AlgoContext:
    """Everything a registered step needs beyond (state, batch)."""

    grad_fn: Callable
    topo: Topology
    hps: object
    mixer: Mixer
    extras: dict


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered DFL algorithm.

    ``init(key, params_stacked, ctx, batch0) -> state`` (``batch0`` is only
    consulted when ``needs_batch0``), ``step(state, batch, ctx) -> (state,
    metrics)`` with a ``loss_mean`` metric, ``wire_bits(topo, hps, n) ->
    float`` expected bits transmitted network-wide per *step* for an
    n-coordinate model, and ``params_of(state)`` the node-stacked pytree.
    """

    name: str
    hp_cls: type
    init: Callable
    step: Callable
    wire_bits: Callable
    params_of: Callable = staticmethod(lambda s: s.params)
    needs_batch0: bool = False
    # optional (topo, hps, sizes) -> float: per-leaf Eq.-(8) accounting for
    # algorithms whose wire format partitions over the model pytree;
    # ``sizes`` is the per-leaf coordinate count of the (unstacked) model
    # in tree_flatten order.  None falls back to wire_bits(topo, hps,
    # sum(sizes)) wherever the leaf structure is known.
    wire_bits_sizes: Optional[Callable] = None
    # optional (topo, hps, mixing, seed) -> dict merged into ctx.extras
    setup: Optional[Callable] = None
    # optional (hps, n) -> bits per realized *directed* edge per step; used
    # by dynamic-network scenario runs to charge only surviving links.
    # Algorithms whose step emits its own "wire_bits" metric (PaME) or that
    # send nothing leave this None.
    edge_bits: Optional[Callable] = None
    # hyperparameter fields that shape the traced program (payload sizes,
    # python loop counts, wire formats): bind_batched refuses configs that
    # differ in these — they cannot share one compiled sweep.
    static_hp_fields: Tuple[str, ...] = ()
    # fields realized into device arrays by `setup` (e.g. PaME's nu /
    # kappa_* -> TopologyArrays): configs may differ in them without the
    # scalar itself entering the trace — the stacked per-config extras
    # carry the difference.
    setup_hp_fields: Tuple[str, ...] = ()
    # optional (hps) -> bool: the step consumes the delayed-delivery
    # extras itself (``fresh_params`` fresh self-view + ``delivered``
    # message masks) instead of the wrapper's post-hoc innovation re-add
    # — PaME's memoryless exchange needs no mean bookkeeping.
    handles_delay: Optional[Callable] = None
    # optional replicated variants for fault-injected binds (surrogate-
    # memory algorithms): ``rep_init(key, stacked, ctx, batch0, arrays)``
    # and ``rep_step(state, batch, ctx)`` reading the FaultRealization
    # from ``ctx.extras["fault"]`` (see ``repro.core.faults``).
    rep_init: Optional[Callable] = None
    rep_step: Optional[Callable] = None

    def bind(
        self,
        grad_fn: Callable,
        topo: Topology,
        hps: Optional[object] = None,
        *,
        mixing: str = "sparse",
        seed: int = 0,
        scenario: Optional[AnyScenario] = None,
        faults: Optional[flt_mod.FaultModel] = None,
        pacing: Optional[ServePacing] = None,
    ) -> "BoundAlgorithm":
        """Close the spec over (grad_fn, topology, hps, mixing, scenario).

        ``scenario=None`` or a static scenario keeps the existing
        fixed-``Topology`` program exactly (bit-identical); a dynamic
        scenario wraps the step so each global step k realizes its own
        doubly-stochastic mixing matrix on device (see
        ``repro.core.scenarios``), freezes dropped nodes' state, and logs
        realized per-step ``wire_bits``.  A ``TemporalScenario``
        (``repro.core.temporal``) additionally threads Markov link/node
        state and the bounded-staleness snapshot ring through the
        engine's auxiliary carry slot; its step signature grows to
        ``step(state, batch, k, aux) -> (state, metrics, aux)``.

        A non-static ``faults`` model (``repro.core.faults``) layers
        message-level failures over the (possibly static) base scenario:
        per-direction loss, lossy-link bursts, delayed delivery and
        transient crashes, with per-receiver renormalized weights and —
        for algorithms registered with replicated variants — per-receiver
        surrogate replicas with wire-charged ack/repair resync.  The step
        signature is the temporal one (aux carries the ``FaultCarry``).
        A zero-rate ``FaultModel`` binds the plain fault-free program,
        bit-identical to ``faults=None``.

        ``pacing`` (``repro.serve.events.ServePacing``) layers the
        serve-while-train event clock over the (possibly static) base
        scenario: per-round request arrivals queue against each node,
        and a node whose backlog exceeds the defer threshold *defers its
        gossip exchange* that round exactly like a scenario straggler
        (local update still applied, self-loop in B^k — mean-preserving
        by construction).  The event clock threads through the engine's
        auxiliary carry slot (``PacedCarry``), composing with a bound
        ``FaultModel`` whose carry rides in the ``inner`` slot.  A
        zero-rate pacing binds the plain unpaced program, bit-identical
        to ``pacing=None``.
        """
        hps = self.hp_cls() if hps is None else hps
        if not isinstance(hps, self.hp_cls):
            raise TypeError(
                f"{self.name} expects {self.hp_cls.__name__}, got {type(hps).__name__}"
            )
        extras = dict(self.setup(topo, hps, mixing, seed)) if self.setup else {}
        if "hps" in extras:  # setup may rewrite hps (e.g. PaME's mixing field)
            hps = extras.pop("hps")
        mixer = make_mixer(topo, "matrix" if mixing == "matrix" else mixing)
        ctx = AlgoContext(grad_fn=grad_fn, topo=topo, hps=hps, mixer=mixer,
                          extras=extras)
        if faults is not None and faults.is_static:
            faults = None  # zero-rate model == the fault-free program
        if pacing is not None and pacing.is_static:
            pacing = None  # zero-rate process == the unpaced program
        if faults is not None or pacing is not None:
            if isinstance(scenario, temp_mod.TemporalScenario):
                what = "faults" if faults is not None else "pacing"
                raise NotImplementedError(
                    f"{what} cannot stack on a TemporalScenario: fold the "
                    "staleness into FaultModel(delay=..., max_delay=...) "
                    "and the link/node dynamics into a base Scenario"
                )
            base = scenario if scenario is not None else scen_mod.Scenario(
                name="static")
            return BoundAlgorithm(
                self, ctx, scenario=base,
                scen_arrays=scen_mod.make_scenario_arrays(topo, base),
                mixing_mode=mixing, faults=faults, pacing=pacing,
            )
        if scenario is not None and not scenario.is_static:
            return BoundAlgorithm(
                self, ctx, scenario=scenario,
                scen_arrays=scen_mod.make_scenario_arrays(topo, scenario),
                mixing_mode=mixing,
            )
        return BoundAlgorithm(self, ctx)

    def bind_batched(
        self,
        grad_fn: Callable,
        topo: Topology,
        hps_list: Optional[Sequence[object]] = None,
        *,
        seeds: Sequence[int] = (0,),
        mixing: str = "sparse",
        seed: int = 0,
        scenario: Optional[AnyScenario] = None,
        faults: Optional[flt_mod.FaultModel] = None,
        pacing: Optional[ServePacing] = None,
    ) -> "BatchedAlgorithm":
        """Close the spec over S seeds × C configs as ONE lane-batched step.

        The returned :class:`BatchedAlgorithm` runs every (seed, config)
        cell of the grid as one lane of a single jitted scan
        (``engine.make_scan_runner(lanes=L)``): per-lane PRNG streams
        enter through per-lane state keys (lane (s, c) reproduces the
        unbatched ``bind(hps_c)`` run under ``PRNGKey(s)`` to fp
        tolerance), per-config hyperparameters enter either as traced
        per-lane scalars (float fields: lr, gamma, sigma0, ...) or
        through per-config device arrays stacked out of ``setup`` (PaME's
        nu / kappa draws via ``TopologyArrays``), and the whole grid
        compiles once instead of once per cell.

        Fields named in ``static_hp_fields`` shape the traced program
        (payload sizes, loop counts) and must therefore be equal across
        ``hps_list`` — differing values raise.  Lane order is
        config-major: ``lane = c * len(seeds) + s``.

        A dynamic ``scenario`` is supported: each lane folds its seed
        into the scenario key, so different seeds draw independent
        network sample paths (and the same seed under different configs
        sees the same path — paired comparisons).  A non-static
        ``faults`` model likewise folds each lane's seed into the fault
        key — independent fault sample paths per seed, shared across
        configs; a non-static ``pacing`` folds each lane's seed into the
        arrival-process key the same way — independent request traces
        per seed, shared across configs.
        """
        hps_list = [self.hp_cls() if h is None else h
                    for h in (hps_list or [None])]
        for h in hps_list:
            if not isinstance(h, self.hp_cls):
                raise TypeError(
                    f"{self.name} expects {self.hp_cls.__name__}, "
                    f"got {type(h).__name__}"
                )
        seeds = [int(s) for s in seeds]
        if not seeds:
            raise ValueError("bind_batched needs at least one seed")

        # per-config setup -> (effective hps, extras)
        extras_list, eff_hps = [], []
        for h in hps_list:
            extras = dict(self.setup(topo, h, mixing, seed)) if self.setup else {}
            if "hps" in extras:
                h = extras.pop("hps")
            extras_list.append(extras)
            eff_hps.append(h)
        hps0 = eff_hps[0]

        # classify differing hp fields: static -> refuse, setup-realized ->
        # carried by the stacked extras, float -> traced per-lane scalar
        swept: dict = {}
        for field in dataclasses.fields(self.hp_cls):
            vals = [getattr(h, field.name) for h in eff_hps]
            if all(v == vals[0] for v in vals[1:]):
                continue
            if field.name in self.static_hp_fields:
                raise ValueError(
                    f"{self.name}: hp field {field.name!r} shapes the traced "
                    f"program and must be equal across batched configs "
                    f"(got {vals})"
                )
            if field.name in self.setup_hp_fields:
                continue  # realized via the stacked setup extras
            if isinstance(vals[0], float) and not isinstance(vals[0], bool):
                swept[field.name] = np.asarray(vals, np.float32)
                continue
            raise ValueError(
                f"{self.name}: cannot batch over non-float hp field "
                f"{field.name!r} (got {vals}); sweep it across separate "
                "binds instead"
            )

        # split extras into per-config array stacks vs shared objects
        shared_extras: dict = {}
        stacked_extras: dict = {}
        for key in extras_list[0]:
            values = [ex[key] for ex in extras_list]
            leaves = jax.tree_util.tree_leaves(values[0])
            if leaves and all(
                isinstance(leaf, (jax.Array, np.ndarray))
                for v in values for leaf in jax.tree_util.tree_leaves(v)
            ):
                stacked_extras[key] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                    *values,
                )
            else:
                shared_extras[key] = values[0]

        mixer = make_mixer(topo, "matrix" if mixing == "matrix" else mixing)
        ctx0 = AlgoContext(grad_fn=grad_fn, topo=topo, hps=hps0, mixer=mixer,
                           extras=shared_extras)
        if faults is not None and faults.is_static:
            faults = None  # zero-rate model == the fault-free program
        if pacing is not None and pacing.is_static:
            pacing = None  # zero-rate process == the unpaced program
        scen_arrays = None
        if faults is not None or pacing is not None:
            if isinstance(scenario, temp_mod.TemporalScenario):
                what = "faults" if faults is not None else "pacing"
                raise NotImplementedError(
                    f"{what} cannot stack on a TemporalScenario: fold the "
                    "staleness into FaultModel(delay=..., max_delay=...) "
                    "and the link/node dynamics into a base Scenario"
                )
            if scenario is None:
                scenario = scen_mod.Scenario(name="static")
            scen_arrays = scen_mod.make_scenario_arrays(topo, scenario)
        elif scenario is not None and not scenario.is_static:
            scen_arrays = scen_mod.make_scenario_arrays(topo, scenario)
        elif scenario is not None:
            scenario = None  # static scenario == the fixed-Topology path
        return BatchedAlgorithm(
            self, ctx0, eff_hps, seeds, swept, stacked_extras,
            mixing_mode=mixing, scenario=scenario, scen_arrays=scen_arrays,
            faults=faults, pacing=pacing,
        )


class BoundAlgorithm:
    """An Algorithm closed over (grad_fn, topology, hps, mixer).

    ``step`` is a plain ``(state, batch) -> (state, metrics)`` closure,
    directly consumable by ``engine.make_scan_runner`` or ``jax.jit``.
    When a dynamic scenario is bound, ``step`` instead takes ``(state,
    batch, k)`` — the global step index realizes the step's network — and
    the engine must be built with ``step_takes_index=True`` (``run`` /
    ``make_runner`` do this automatically).  A ``TemporalScenario`` bind
    further extends the signature to ``step(state, batch, k, aux) ->
    (state, metrics, aux)``, where ``aux`` is the ``TemporalCarry``
    (Markov chain state + staleness ring) built by :meth:`aux_init` and
    threaded through the engine's auxiliary carry slot
    (``carries_aux=True``).
    """

    def __init__(
        self,
        spec: Algorithm,
        ctx: AlgoContext,
        scenario: Optional[AnyScenario] = None,
        scen_arrays: Optional[scen_mod.ScenarioArrays] = None,
        mixing_mode: str = "sparse",
        faults: Optional[flt_mod.FaultModel] = None,
        fault_key: Optional[jax.Array] = None,
        pacing: Optional[ServePacing] = None,
        pace_key: Optional[jax.Array] = None,
    ):
        self.spec = spec
        self.ctx = ctx
        self.scenario = scenario
        self.scen_arrays = scen_arrays
        self._mixing_mode = mixing_mode
        self.faults = faults
        if faults is not None and fault_key is None:
            fault_key = jax.random.PRNGKey(faults.seed)
        self.fault_key = fault_key
        self.pacing = pacing
        if pacing is not None and pace_key is None:
            pace_key = jax.random.PRNGKey(pacing.process.seed)
        self.pace_key = pace_key

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def hps(self) -> object:
        return self.ctx.hps

    @property
    def dynamic(self) -> bool:
        """True when a non-static scenario is bound (step takes k)."""
        return self.scenario is not None

    @property
    def temporal(self) -> bool:
        """True when the bound scenario is a TemporalScenario (step
        threads the auxiliary carry — run/make_runner pass it to the
        engine as ``carries_aux``)."""
        return isinstance(self.scenario, temp_mod.TemporalScenario)

    @property
    def faulty(self) -> bool:
        """True when a non-static FaultModel is bound (step threads the
        FaultCarry through the engine's auxiliary carry slot)."""
        return self.faults is not None

    @property
    def paced(self) -> bool:
        """True when a non-static ServePacing is bound (step threads the
        serve-event clock through the engine's auxiliary carry slot)."""
        return self.pacing is not None

    @property
    def carries_aux(self) -> bool:
        return self.temporal or self.faulty or self.paced

    @property
    def params_of(self) -> Callable:
        return self.spec.params_of

    def init(self, key: jax.Array, params_stacked: object,
             batch0: Optional[object] = None) -> object:
        if self.spec.needs_batch0 and batch0 is None:
            raise ValueError(f"{self.name} needs batch0 at init")
        if self.faulty and self.spec.rep_init is not None:
            return self.spec.rep_init(key, params_stacked, self.ctx, batch0,
                                      self.scen_arrays)
        return self.spec.init(key, params_stacked, self.ctx, batch0)

    def aux_init(self, state: object):
        """Initial auxiliary carry: the FaultCarry of a fault-injected
        bind, the TemporalCarry of a temporal bind (stationary Markov
        draws + the staleness ring seeded with the initial parameters),
        or — for a paced bind — a PacedCarry wrapping the fresh serve
        event clock around the inner FaultCarry (None when no faults)."""
        inner = None
        if self.faulty:
            inner = flt_mod.fault_carry_init(
                self.faults, self.scen_arrays, self.spec.params_of(state),
                self.fault_key,
            )
        if self.paced:
            return PacedCarry(
                events=self.pacing.init(self.scen_arrays.m, self.pace_key),
                inner=inner,
            )
        if inner is not None:
            return inner
        if not self.temporal:
            raise TypeError(f"{self.name} is not bound to a TemporalScenario")
        return temp_mod.temporal_carry_init(
            self.scenario, self.scen_arrays, self.spec.params_of(state)
        )

    def step(self, state: object, batch: object,
             k: Optional[jax.Array] = None,
             aux: Optional[object] = None):
        if not self.dynamic:
            return self.spec.step(state, batch, self.ctx)
        if k is None:
            raise TypeError(
                f"{self.name} is bound to scenario {self.scenario.name!r}: "
                "step(state, batch, k) needs the global step index"
            )
        if self.paced:
            if aux is None:
                raise TypeError(
                    f"{self.name} is bound to pacing "
                    f"{self.pacing.process.name!r}: step(state, batch, k, "
                    "aux) needs the PacedCarry (see aux_init)"
                )
            k = jnp.asarray(k, jnp.int32)
            new_ev, busy, ev_metrics = self.pacing.advance(aux.events, k)
            if self.faulty:
                new_state, metrics, new_inner = self._fault_step(
                    state, batch, k, aux.inner, extra_straggler=busy
                )
            else:
                new_state, metrics = self._dynamic_step(
                    state, batch, k, extra_straggler=busy
                )
                new_inner = None
            metrics.update(ev_metrics)
            return new_state, metrics, PacedCarry(new_ev, new_inner)
        if self.faulty:
            if aux is None:
                raise TypeError(
                    f"{self.name} is bound to fault model "
                    f"{self.faults.name!r}: step(state, batch, k, aux) "
                    "needs the FaultCarry (see aux_init)"
                )
            return self._fault_step(state, batch,
                                    jnp.asarray(k, jnp.int32), aux)
        if self.temporal:
            if aux is None:
                raise TypeError(
                    f"{self.name} is bound to temporal scenario "
                    f"{self.scenario.name!r}: step(state, batch, k, aux) "
                    "needs the TemporalCarry (see aux_init)"
                )
            return self._temporal_step(state, batch,
                                       jnp.asarray(k, jnp.int32), aux)
        return self._dynamic_step(state, batch, jnp.asarray(k, jnp.int32))

    def _realized_metrics(self, r: scen_mod.Realization, state: object,
                          metrics: dict) -> dict:
        """Realized wire accounting shared by the i.i.d. and temporal paths:
        algorithms without their own per-message metric are charged
        edge_bits on every realized directed edge."""
        if "wire_bits" not in metrics:
            n = sum(
                int(np.prod(leaf.shape[1:]))
                for leaf in jax.tree_util.tree_leaves(self.spec.params_of(state))
            )
            eb = self.spec.edge_bits(self.ctx.hps, n) if self.spec.edge_bits else 0.0
            metrics["wire_bits"] = (
                r.directed_edges.astype(jnp.float32) * float(eb)
            )
        metrics["alive_nodes"] = jnp.sum(r.alive.astype(jnp.int32))
        return metrics

    def _partition_metrics(self, k: jax.Array, new_state: object,
                           metrics: dict) -> dict:
        """Per-component consensus / mean-drift scalars when the bound
        scenario schedules partition windows: within-component
        disagreement (``comp_consensus``) and the between-component mean
        gap (``comp_mean_gap``) whose post-heal decay is the recovery
        headline.  A partition-free scenario adds nothing — the traced
        program is unchanged."""
        scen = self.scenario
        if not getattr(scen, "partitions", ()):
            return metrics
        comp = scen_mod.active_components(self.scen_arrays, k)
        x = jnp.concatenate([
            jnp.reshape(leaf, (leaf.shape[0], -1)).astype(jnp.float32)
            for leaf in jax.tree_util.tree_leaves(
                self.spec.params_of(new_state))
        ], axis=1)
        cc, gap = scen_mod.component_stats(comp, x, scen.max_parts)
        metrics["comp_consensus"] = cc
        metrics["comp_mean_gap"] = gap
        return metrics

    def _dynamic_step(self, state: object, batch: object, k: jax.Array,
                      extra_straggler: Optional[jax.Array] = None,
                      ) -> Tuple[object, dict]:
        """One step under the bound scenario (fully traceable).

        Realizes step k's graph from the folded scenario key, swaps the
        per-step mixer into the context, reverts dropped nodes' state
        bitwise, and charges only realized edges on the wire.
        ``extra_straggler`` (the pacing layer's busy mask) ORs into the
        scenario's straggler draw before the weights are built — same
        sample_masks PRNG discipline, so a no-op mask realizes the same
        matrix as the plain scenario path.
        """
        if extra_straggler is None:
            r = scen_mod.realize(self.scenario, self.scen_arrays, k)
        else:
            edge_up, alive, straggler = scen_mod.sample_masks(
                self.scenario, self.scen_arrays, k
            )
            r = scen_mod.realization_from_masks(
                self.scen_arrays, edge_up, alive,
                straggler | extra_straggler,
            )
        mixer = scen_mod.scenario_mixer(self.scen_arrays, r, self._mixing_mode)
        ctx_t = dataclasses.replace(
            self.ctx, mixer=mixer,
            extras={**self.ctx.extras, "realization": r},
        )
        new_state, metrics = self.spec.step(state, batch, ctx_t)
        new_state = scen_mod.freeze_dropped(r.alive, state, new_state)
        metrics = self._realized_metrics(r, state, metrics)
        return new_state, self._partition_metrics(k, new_state, metrics)

    def _temporal_step(self, state: object, batch: object, k: jax.Array,
                       aux: temp_mod.TemporalCarry):
        """One step under the bound TemporalScenario (fully traceable).

        Advances the Markov chains from the carried state, realizes the
        step's doubly-stochastic matrix with delayed stragglers still
        participating, and substitutes their ring-gathered t-delayed
        parameters into the exchange — message-only delay: receivers see
        the stale values, but a delayed node's *local compute* never
        waits.  Gradients are steered back to the fresh iterate via the
        ``grad_shift`` extra (fresh − delayed, zero rows for punctual
        nodes), and after the step each delayed node's private innovation
        (fresh − delayed) is re-added to its own row.  On the substituted
        stack ``mixed_j = B_jj·eff_j + Σ off-terms``, so the re-add makes
        the self-view ``B_jj·fresh_j + (1−B_jj)·(fresh_j − eff_j)`` on
        top of the off-diagonal terms: exactly the fresh self-view plus
        the (1−B_jj)-scaled innovation correction that restores the
        global parameter sum for every realized matrix.  Algorithms whose
        ``handles_delay(hps)`` is true (PaME's dense exchange) instead
        consume the fresh stack directly (``fresh_params`` extra → the
        lambda=0 / uncovered-coordinate fallback) and skip the re-add —
        their exchange is memoryless, so there is no surrogate mean to
        rebalance.  Requires the algorithm state to carry its
        node-stacked parameters in a ``params`` field (all built-in
        registrations do).
        """
        new_ts, r, delayed, tau = temp_mod.advance(
            self.scenario, self.scen_arrays, aux.ts, k
        )
        mixer = scen_mod.scenario_mixer(self.scen_arrays, r, self._mixing_mode)
        extras = {**self.ctx.extras, "realization": r}
        hd = (self.spec.handles_delay is not None
              and self.spec.handles_delay(self.ctx.hps))
        d_max = self.scenario.staleness
        ring = aux.ring
        if d_max > 0:
            fresh = self.spec.params_of(state)
            slot = jnp.mod(k - tau, d_max)
            eff = ring_gather(ring, fresh, slot, delayed)
            state_in = state._replace(params=eff)
            if hd:
                extras["fresh_params"] = fresh
            else:
                # zero rows for punctual nodes: every gradient call point
                # becomes the undelayed iterate, no masking needed
                extras["grad_shift"] = jax.tree_util.tree_map(
                    lambda f, e: f - e, fresh, eff
                )
        else:
            state_in = state
        ctx_t = dataclasses.replace(self.ctx, mixer=mixer, extras=extras)
        new_state, metrics = self.spec.step(state_in, batch, ctx_t)
        if d_max > 0:
            if not hd:
                def _readd(p, f, e):
                    keep = delayed.reshape((-1,) + (1,) * (p.ndim - 1))
                    return p + jnp.where(keep, f - e, jnp.zeros_like(p))

                new_params = jax.tree_util.tree_map(
                    _readd, self.spec.params_of(new_state), fresh, eff
                )
                new_state = new_state._replace(params=new_params)
            ring = temp_mod.ring_push(ring, fresh, k, d_max)
            tgrid = jnp.arange(d_max + 1, dtype=jnp.int32)
            metrics["stale_hist"] = jnp.sum(
                (tau[:, None] == tgrid[None, :]) & r.participating[:, None],
                axis=0,
            ).astype(jnp.float32)
            metrics["stale_nodes"] = jnp.sum(delayed.astype(jnp.int32))
        new_state = scen_mod.freeze_dropped(r.alive, state, new_state)
        metrics = self._realized_metrics(r, state, metrics)
        return new_state, metrics, temp_mod.TemporalCarry(new_ts, ring)

    def _fault_step(self, state: object, batch: object, k: jax.Array,
                    aux: flt_mod.FaultCarry,
                    extra_straggler: Optional[jax.Array] = None):
        """One step under the bound FaultModel (fully traceable).

        Samples the base scenario masks, advances the fault Markov state
        (lossy-link bursts, crashes, delivery delays), draws the
        per-direction message losses, and realizes the *per-receiver
        renormalized* row-stochastic weights (``repro.core.faults``).
        Direct parameter mixers (D-PSGD / DFedSAM) gossip under those
        renormalized weights; algorithms registered with replicated
        variants run their ``rep_step`` — per-receiver surrogate replicas
        that desync on lost messages and resync through wire-charged
        repair traffic — and PaME consumes the delivery masks natively
        (``delivered`` extra: sent messages are charged, only delivered
        ones enter the count-normalized average).  Delayed delivery
        reuses the temporal snapshot ring with the same fresh-self-view
        semantics as :meth:`_temporal_step`; crashed nodes' state freezes
        bitwise (the local checkpoint they rejoin from).
        """
        fm = self.faults
        edge_up, alive, straggler = scen_mod.sample_masks(
            self.scenario, self.scen_arrays, k
        )
        if extra_straggler is not None:
            # the pacing layer's busy mask: a backlogged node defers its
            # exchange exactly like a scenario straggler
            straggler = straggler | extra_straggler
        new_fs, fr = flt_mod.advance_faults(
            fm, self.scen_arrays, aux.fs, self.fault_key, k,
            edge_up, alive, straggler,
        )
        r = fr.base
        use_rep = self.spec.rep_step is not None
        # the renormalized weights keep direct parameter mixing
        # row-stochastic under asymmetric loss; replicated steps and PaME
        # read the symmetric base weights / delivery masks from `fr`
        mixer = scen_mod.scenario_mixer(
            self.scen_arrays, r._replace(weights=fr.weights),
            self._mixing_mode,
        )
        extras = {**self.ctx.extras, "realization": r, "fault": fr,
                  "fault_arrays": self.scen_arrays,
                  "delivered": fr.recv_ok, "repair": fm.repair}
        hd = (self.spec.handles_delay is not None
              and self.spec.handles_delay(self.ctx.hps))
        d_max = fm.max_delay
        ring = aux.ring
        if d_max > 0:
            fresh = self.spec.params_of(state)
            slot = jnp.mod(k - fr.tau, d_max)
            eff = ring_gather(ring, fresh, slot, fr.delayed)
            state_in = state._replace(params=eff)
            if hd:
                extras["fresh_params"] = fresh
            else:
                extras["grad_shift"] = jax.tree_util.tree_map(
                    lambda f, e: f - e, fresh, eff
                )
        else:
            state_in = state
        if use_rep:
            n = sum(
                int(np.prod(leaf.shape[1:]))
                for leaf in jax.tree_util.tree_leaves(
                    self.spec.params_of(state))
            )
            extras["innov_bits"] = float(self.spec.edge_bits(self.ctx.hps, n))
        ctx_t = dataclasses.replace(self.ctx, mixer=mixer, extras=extras)
        step_fn = self.spec.rep_step if use_rep else self.spec.step
        new_state, metrics = step_fn(state_in, batch, ctx_t)
        if d_max > 0:
            if not hd:
                def _readd(p, f, e):
                    keep = fr.delayed.reshape((-1,) + (1,) * (p.ndim - 1))
                    return p + jnp.where(keep, f - e, jnp.zeros_like(p))

                new_params = jax.tree_util.tree_map(
                    _readd, self.spec.params_of(new_state), fresh, eff
                )
                new_state = new_state._replace(params=new_params)
            ring = temp_mod.ring_push(ring, fresh, k, d_max)
            metrics["stale_nodes"] = jnp.sum(fr.delayed.astype(jnp.int32))
        new_state = scen_mod.freeze_dropped(r.alive, state, new_state)
        metrics = self._realized_metrics(r, state, metrics)
        metrics = self._partition_metrics(k, new_state, metrics)
        metrics["col_defect"] = fr.col_defect
        metrics["mean_drift"] = new_fs.drift
        metrics["dropped_msgs"] = fr.dropped.astype(jnp.float32)
        metrics["crashed_nodes"] = jnp.sum(new_fs.crashed.astype(jnp.int32))
        return new_state, metrics, flt_mod.FaultCarry(new_fs, ring)

    def wire_bits(self, n: int) -> float:
        """Expected bits on the wire per step, summed over the network."""
        return float(self.spec.wire_bits(self.ctx.topo, self.ctx.hps, n))

    def wire_bits_for(self, params0: object) -> float:
        """Expected bits/step for a concrete model pytree: routes through
        the per-leaf ``wire_bits_sizes`` accounting when the algorithm
        registers one (tree-partitioned formats), else the flat formula."""
        sizes = tuple(
            int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params0)
        )
        if self.spec.wire_bits_sizes is not None:
            return float(
                self.spec.wire_bits_sizes(self.ctx.topo, self.ctx.hps, sizes)
            )
        return self.wire_bits(sum(sizes))

    def make_runner(
        self,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Callable:
        """Persistent scan runner (compiled chunks cached across calls):
        ``run(key, params0, m, batch_fn, num_steps) -> (state, history)``."""
        runner = engine.make_scan_runner(
            self.step, objective_fn=objective_fn, params_of=self.spec.params_of,
            tol_std=tol_std, chunk_size=chunk_size,
            step_takes_index=self.dynamic, carries_aux=self.carries_aux,
        )

        def run(key, params0, m, batch_fn, num_steps):
            stacked = B.stack_params(params0, m)
            batch0 = batch_fn(0) if self.spec.needs_batch0 else None
            state = self.init(key, stacked, batch0)
            aux = self.aux_init(state) if self.carries_aux else None
            state, metrics, info = runner(state, batch_fn, num_steps, aux=aux)
            info = dict(info)
            info.pop("aux", None)
            history = {
                key_: [float(v) for v in vals]
                for key_, vals in metrics.items()
                if key_ != "stale_hist"
            }
            if "stale_hist" in metrics:
                history["staleness_hist"] = engine.staleness_hist(
                    metrics["stale_hist"]
                )
            history["loss"] = history.pop("loss_mean", [])
            history.update(info)
            self._account_wire(history, params0)
            return state, history

        return run

    def run(
        self,
        key: jax.Array,
        params0: object,
        m: int,
        batch_fn: Callable[[int], object],
        num_steps: int,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        driver: str = "scan",
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Tuple[object, dict]:
        """One-shot race driver (scan or host), with wire accounting."""
        stacked = B.stack_params(params0, m)
        batch0 = batch_fn(0) if self.spec.needs_batch0 else None
        state = self.init(key, stacked, batch0)
        aux = self.aux_init(state) if self.carries_aux else None
        state, history = B.run_algorithm(
            self.step, state, batch_fn, num_steps,
            objective_fn=objective_fn, params_of=self.spec.params_of,
            tol_std=tol_std, driver=driver, chunk_size=chunk_size,
            step_takes_index=self.dynamic,
            carries_aux=self.carries_aux, aux=aux,
        )
        self._account_wire(history, params0)
        return state, history

    def _account_wire(self, history: dict, params0: object) -> None:
        per_step = history.get("wire_bits")
        if per_step:
            # dynamic scenario: only realized (surviving) edges were charged
            history["wire_bits_total"] = float(np.sum(per_step))
            history["wire_bits_per_step"] = (
                history["wire_bits_total"] / max(len(per_step), 1)
            )
            return
        history.pop("wire_bits", None)  # static runs keep the legacy schema
        history["wire_bits_per_step"] = self.wire_bits_for(params0)
        history["wire_bits_total"] = (
            history["wire_bits_per_step"] * history["steps_run"]
        )


class BatchedAlgorithm:
    """S seeds × C configs of one Algorithm as a single lane-batched step.

    Built by :meth:`Algorithm.bind_batched`.  ``step`` has the exact
    signature the engine expects of a lane-batched step — ``(state,
    batch[, k][, aux]) -> (state, metrics[, aux])`` with state leaves
    ``[L, m, ...]`` and per-step metric values ``[L]`` — implemented as a
    single ``jax.vmap`` over (state, per-lane hp scalars, per-config
    extras stacks[, per-lane scenario key, aux]); the batch and global
    step index broadcast.  ``run``/``make_runner`` drive it through
    ``engine.make_scan_runner(lanes=L)``: one compile for the whole
    grid, per-lane termination, per-lane metric buffers and wire-bit
    accounting.

    Lane order is config-major: ``lane = c * S + s`` — ``lane_config``
    / ``lane_seed`` in the returned history map lanes back to grid
    cells, and :func:`lane_finals` reduces a per-lane metric buffer at
    each lane's own stopping step.
    """

    def __init__(
        self,
        spec: Algorithm,
        ctx0: AlgoContext,
        hps_list: Sequence[object],
        seeds: Sequence[int],
        swept: dict,            # field -> [C] np.float32 of per-config values
        stacked_extras: dict,   # extras key -> pytree with leading [C] axis
        mixing_mode: str = "sparse",
        scenario: Optional[AnyScenario] = None,
        scen_arrays: Optional[scen_mod.ScenarioArrays] = None,
        faults: Optional[flt_mod.FaultModel] = None,
        pacing: Optional[ServePacing] = None,
    ):
        self.spec = spec
        self.ctx0 = ctx0
        self.hps_list = list(hps_list)
        self.seeds = list(seeds)
        self.scenario = scenario
        self.scen_arrays = scen_arrays
        self._mixing_mode = mixing_mode
        self.faults = faults
        self.pacing = pacing
        c, s = len(self.hps_list), len(self.seeds)
        self.lane_config = np.repeat(np.arange(c), s)       # [L]
        self.lane_seed = np.asarray(self.seeds * c)         # [L]
        # per-lane traced hp scalars (configs expanded over seeds)
        self._lane_hp = {
            f: jnp.asarray(vals[self.lane_config])
            for f, vals in swept.items()
        }
        # per-lane setup extras ([C, ...] stacks expanded over seeds)
        self._lane_extras = jax.tree_util.tree_map(
            lambda x: jnp.take(x, jnp.asarray(self.lane_config), axis=0),
            stacked_extras,
        )
        # per-lane PRNG: lane (s, c) starts from PRNGKey(s), exactly the
        # key an unbatched run for that seed would get
        self._lane_keys = jnp.stack(
            [jax.random.PRNGKey(int(s)) for s in self.lane_seed]
        )
        self._scen_keys = None
        if scen_arrays is not None:
            # per-seed network sample paths (shared across configs)
            self._scen_keys = jax.vmap(
                lambda s: jax.random.fold_in(scen_arrays.key, s)
            )(jnp.asarray(self.lane_seed, jnp.uint32))
        self._fault_keys = None
        if faults is not None:
            # per-seed fault sample paths (shared across configs)
            fk = jax.random.PRNGKey(faults.seed)
            self._fault_keys = jax.vmap(
                lambda s: jax.random.fold_in(fk, s)
            )(jnp.asarray(self.lane_seed, jnp.uint32))
        self._pace_keys = None
        if pacing is not None:
            # per-seed request traces (shared across configs)
            pk = jax.random.PRNGKey(pacing.process.seed)
            self._pace_keys = jax.vmap(
                lambda s: jax.random.fold_in(pk, s)
            )(jnp.asarray(self.lane_seed, jnp.uint32))

    # -- grid geometry ------------------------------------------------------
    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def lanes(self) -> int:
        return len(self.hps_list) * len(self.seeds)

    @property
    def dynamic(self) -> bool:
        return self.scenario is not None

    @property
    def temporal(self) -> bool:
        return isinstance(self.scenario, temp_mod.TemporalScenario)

    @property
    def faulty(self) -> bool:
        return self.faults is not None

    @property
    def paced(self) -> bool:
        return self.pacing is not None

    @property
    def carries_aux(self) -> bool:
        return self.temporal or self.faulty or self.paced

    @property
    def params_of(self) -> Callable:
        return self.spec.params_of

    # -- lane plumbing ------------------------------------------------------
    def _lane_bound(self, hp_vals: dict, ex_arrays: dict,
                    scen_key: Optional[jax.Array],
                    fault_key: Optional[jax.Array] = None) -> BoundAlgorithm:
        """Rebuild the single-lane BoundAlgorithm inside the vmapped body:
        traced hp scalars replace the dataclass fields, the lane's slice
        of the stacked setup extras joins the shared ones.  The pacing
        spec is shared across lanes — each lane's event stream diverges
        through the per-lane key carried in its EventState."""
        hps = (dataclasses.replace(self.ctx0.hps, **hp_vals)
               if hp_vals else self.ctx0.hps)
        ctx = dataclasses.replace(
            self.ctx0, hps=hps, extras={**self.ctx0.extras, **ex_arrays}
        )
        scen_arrays = self.scen_arrays
        if scen_key is not None and scen_arrays is not None:
            scen_arrays = scen_arrays._replace(key=scen_key)
        return BoundAlgorithm(
            self.spec, ctx, scenario=self.scenario,
            scen_arrays=scen_arrays, mixing_mode=self._mixing_mode,
            faults=self.faults, fault_key=fault_key, pacing=self.pacing,
        )

    def init(self, params0: object, m: int,
             batch0: Optional[object] = None) -> object:
        """Lane-stacked initial state ([L, m, ...] leaves)."""
        stacked = B.stack_params(params0, m)

        def lane(key, hp_vals, ex_arrays):
            return self._lane_bound(hp_vals, ex_arrays, None).init(
                key, stacked, batch0
            )

        return jax.vmap(lane)(self._lane_keys, self._lane_hp,
                              self._lane_extras)

    def aux_init(self, state: object) -> object:
        """Lane-stacked auxiliary carry (FaultCarry, TemporalCarry, or a
        PacedCarry wrapping per-lane event clocks)."""
        if self.paced:
            m = self.scen_arrays.m

            def lane(st, scen_key, fkey, pkey):
                inner = None
                if self.faulty:
                    inner = flt_mod.fault_carry_init(
                        self.faults, self.scen_arrays._replace(key=scen_key),
                        self.spec.params_of(st), fkey,
                    )
                return PacedCarry(self.pacing.init(m, pkey), inner)

            return jax.vmap(lane)(state, self._scen_keys, self._fault_keys,
                                  self._pace_keys)
        if self.faulty:
            def lane(st, scen_key, fkey):
                return flt_mod.fault_carry_init(
                    self.faults, self.scen_arrays._replace(key=scen_key),
                    self.spec.params_of(st), fkey,
                )

            return jax.vmap(lane)(state, self._scen_keys, self._fault_keys)
        if not self.temporal:
            raise TypeError(f"{self.name} is not bound to a TemporalScenario")

        def lane(st, scen_key):
            return temp_mod.temporal_carry_init(
                self.scenario, self.scen_arrays._replace(key=scen_key),
                self.spec.params_of(st),
            )

        return jax.vmap(lane)(state, self._scen_keys)

    def step(self, state: object, batch: object,
             k: Optional[jax.Array] = None, aux: Optional[object] = None):
        """Lane-batched step — one vmap over the lane axis; the batch and
        the global step index broadcast to every lane."""

        def lane(st, hp_vals, ex_arrays, scen_key, fkey, ax):
            ba = self._lane_bound(hp_vals, ex_arrays, scen_key, fkey)
            if self.carries_aux:
                return ba.step(st, batch, k, ax)
            if self.dynamic:
                return ba.step(st, batch, k)
            return ba.step(st, batch)

        return jax.vmap(lane)(
            state, self._lane_hp, self._lane_extras, self._scen_keys,
            self._fault_keys, aux,
        )

    def wire_bits(self, n: int) -> float:
        """Expected bits/step (network-wide) of config 0 — the scalar the
        training log prints; per-lane accounting lives in the history."""
        return float(self.spec.wire_bits(self.ctx0.topo, self.hps_list[0], n))

    def wire_bits_for(self, params0: object) -> float:
        """Config-0 expected bits/step for a concrete model pytree (see
        :meth:`BoundAlgorithm.wire_bits_for`)."""
        sizes = tuple(
            int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params0)
        )
        if self.spec.wire_bits_sizes is not None:
            return float(self.spec.wire_bits_sizes(
                self.ctx0.topo, self.hps_list[0], sizes
            ))
        return self.wire_bits(sum(sizes))

    # -- drivers ------------------------------------------------------------
    def make_runner(
        self,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Callable:
        """Persistent lane-batched scan runner:
        ``run(params0, m, batch_fn, num_steps) -> (state, history)`` with
        per-lane ``[steps, L]`` metric buffers in the history."""
        runner = engine.make_scan_runner(
            self.step, objective_fn=objective_fn,
            params_of=self.spec.params_of, tol_std=tol_std,
            chunk_size=chunk_size, step_takes_index=self.dynamic,
            carries_aux=self.carries_aux, lanes=self.lanes,
        )

        def run(params0, m, batch_fn, num_steps):
            batch0 = batch_fn(0) if self.spec.needs_batch0 else None
            state = self.init(params0, m, batch0)
            aux = self.aux_init(state) if self.carries_aux else None
            state, metrics, info = runner(state, batch_fn, num_steps,
                                          aux=aux)
            return state, self._assemble_history(metrics, info, params0)

        return run

    def run(
        self,
        params0: object,
        m: int,
        batch_fn: Callable[[int], object],
        num_steps: int,
        *,
        objective_fn: Optional[Callable] = None,
        tol_std: float = 1e-3,
        chunk_size: int = engine.DEFAULT_CHUNK_SIZE,
    ) -> Tuple[object, dict]:
        """One-shot batched grid run (see `make_runner`)."""
        return self.make_runner(
            objective_fn=objective_fn, tol_std=tol_std,
            chunk_size=chunk_size,
        )(params0, m, batch_fn, num_steps)

    def _assemble_history(self, metrics: dict, info: dict,
                          params0: object) -> dict:
        history = {k: np.asarray(v) for k, v in metrics.items()
                   if k != "stale_hist"}
        steps_run = np.asarray(info["steps_run"])
        if "stale_hist" in metrics:
            # [steps, L, D+1] -> per-lane run-level histogram [L, D+1],
            # each lane truncated at its own stopping step (a frozen lane
            # keeps emitting rows until the last dispatched chunk)
            rows = np.asarray(metrics["stale_hist"])
            history["staleness_hist"] = np.stack([
                rows[: steps_run[l], l].sum(axis=0)
                for l in range(self.lanes)
            ])
        if "loss_mean" in history:
            history["loss"] = history.pop("loss_mean")
        history["steps_run"] = steps_run
        history["steps_dispatched"] = info["steps_dispatched"]
        history["lane_config"] = self.lane_config
        history["lane_seed"] = self.lane_seed
        sizes = tuple(
            int(np.prod(leaf.shape))
            for leaf in jax.tree_util.tree_leaves(params0)
        )
        if "wire_bits" in history:
            # dynamic: per-step realized bits [steps, L], truncated per lane
            per = history["wire_bits"]
            total = np.array([
                per[: steps_run[l], l].sum() for l in range(self.lanes)
            ])
            history["wire_bits_total"] = total
            history["wire_bits_per_step"] = total / np.maximum(steps_run, 1)
        else:
            per_cfg = np.array([
                float(self.spec.wire_bits_sizes(self.ctx0.topo, h, sizes))
                if self.spec.wire_bits_sizes is not None
                else float(self.spec.wire_bits(self.ctx0.topo, h, sum(sizes)))
                for h in self.hps_list
            ])
            history["wire_bits_per_step"] = per_cfg[self.lane_config]
            history["wire_bits_total"] = (
                history["wire_bits_per_step"] * steps_run
            )
        return history


def lane_finals(history: dict, key: str = "objective") -> np.ndarray:
    """Per-lane final value of a batched metric buffer: entry l is
    ``history[key][steps_run[l] - 1, l]`` — each lane read at its own
    stopping step (the buffers run to the last dispatched chunk)."""
    buf = np.asarray(history[key])
    steps_run = np.asarray(history["steps_run"])
    lanes = buf.shape[1]
    return np.array([
        buf[max(int(steps_run[l]) - 1, 0), l] for l in range(lanes)
    ])


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(alg: Algorithm) -> Algorithm:
    if alg.name in _REGISTRY:
        raise ValueError(f"algorithm {alg.name!r} already registered")
    _REGISTRY[alg.name] = alg
    return alg


def get_algorithm(name: str) -> Algorithm:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown algorithm {name!r}; pick from {list(_REGISTRY)}"
        )
    return _REGISTRY[name]


def list_algorithms() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# Wire accounting helpers (Eq. (8) + per-algorithm message formats)
# ---------------------------------------------------------------------------
def _dense_edges_bits(topo: Topology, n: int, bits_per_msg: float) -> float:
    """Every node sends one message to every neighbor each step."""
    return float(topo.degrees.sum()) * bits_per_msg


# bits per *directed* edge per step for the gossip baselines; the static
# wire_bits formulas below are (base directed edge count) × these, and the
# dynamic scenario path charges (realized directed edge count) × these.
def _full_msg_bits(hps, n: int) -> float:
    return float(message_bits(n, n))


def _choco_edge_bits(hps, n: int) -> float:
    return float(rand_k(hps.comp_frac, hps.value_bits, rescale=False).bits(n))


def _beer_edge_bits(hps, n: int) -> float:
    # two compressed streams per edge per step (x and gradient surrogates)
    return 2.0 * _choco_edge_bits(hps, n)


def _anq_edge_bits(hps, n: int) -> float:
    return float(qsgd(hps.qsgd_levels).bits(n))


def _pame_msgs_per_step(topo: Topology, hps: PaMEHp) -> float:
    """Expected sparse messages on the wire per step: receiver i pulls t_i
    messages in the 1/kappa_i fraction of steps it communicates."""
    t = np.maximum(1, np.floor(hps.nu * topo.degrees))
    if hps.homogeneous_kappa is not None:
        inv_kappa = 1.0 / float(hps.homogeneous_kappa)
    else:
        ks = np.arange(hps.kappa_lo, hps.kappa_hi + 1, dtype=np.float64)
        inv_kappa = float(np.mean(1.0 / ks))
    return float(t.sum()) * inv_kappa


def _pame_wire_bits(topo: Topology, hps: PaMEHp, n: int) -> float:
    """Expected bits/step pricing one flat n-coordinate message of
    message_bits(s, n) per transmission (int8 when exchange="compressed_q8").
    The flat-partition formula; multi-leaf models route through
    _pame_wire_bits_sizes wherever the leaf structure is known."""
    s = max(1, int(round(hps.p * n)))
    value_bits = 8 if hps.exchange == "compressed_q8" else 64
    return _pame_msgs_per_step(topo, hps) * message_bits(s, n, value_bits)


def _pame_wire_bits_sizes(topo: Topology, hps: PaMEHp, sizes) -> float:
    """Expected bits/step for a concrete model pytree: flat partition keeps
    the single-vector formula exactly (bit-compatible history schema); tree
    partition sums the per-leaf Eq.-(8) segments at their p_leaf rates."""
    if hps.partition != "tree":
        return _pame_wire_bits(topo, hps, sum(sizes))
    value_bits = 8 if hps.exchange == "compressed_q8" else 64
    rates = pme_leaf_rates(len(sizes), hps.p, hps.p_leaf)
    return _pame_msgs_per_step(topo, hps) * tree_message_bits(
        sizes, rates, value_bits
    )


# ---------------------------------------------------------------------------
# Registrations — PaME + the five baselines of Figs. 8–10
# ---------------------------------------------------------------------------
def _pame_setup(topo, hps, mixing, seed):
    # the bind-level mixing mode governs the node-axis contraction
    mode = "sparse" if mixing == "sparse" else "dense"
    hps = dataclasses.replace(hps, mixing=mode)
    return {
        "hps": hps,
        "topo_arrays": pame_mod.make_topology_arrays(topo, hps, seed=seed),
    }


register(Algorithm(
    name="pame",
    hp_cls=PaMEHp,
    init=lambda key, stacked, ctx, batch0: pame_mod.pame_init(
        key, stacked, ctx.topo.m, ctx.hps),
    step=lambda state, batch, ctx: pame_mod.pame_step(
        state, batch, ctx.grad_fn, ctx.extras["topo_arrays"], ctx.hps,
        realization=ctx.extras.get("realization"),
        self_params=ctx.extras.get("fresh_params"),
        delivered=ctx.extras.get("delivered")),
    wire_bits=_pame_wire_bits,
    wire_bits_sizes=_pame_wire_bits_sizes,
    setup=_pame_setup,
    # dense-exchange PaME consumes message-only delay natively: senders
    # transmit the ring-delayed stack while the lambda=0 / uncovered-
    # coordinate fallback reads the fresh self-view — no innovation
    # re-add (the count-normalized average is memoryless).  The
    # compressed exchange paths keep the wrapper's re-add semantics.
    handles_delay=lambda hps: hps.exchange == "dense",
    # PaME's step emits its own realized "wire_bits" (per-message Eq. (8)
    # on the selected surviving neighbors), so no per-edge rate here.
    # p fixes the message payload size s = round(p·n) (shape-static);
    # nu / kappa_* are realized into TopologyArrays by setup, so batched
    # configs may sweep them without the scalars entering the trace.
    static_hp_fields=("p", "mask_mode", "exchange", "mixing",
                      "partition", "p_leaf"),
    setup_hp_fields=("nu", "kappa_lo", "kappa_hi", "homogeneous_kappa"),
))

register(Algorithm(
    name="dpsgd",
    hp_cls=DPSGDHp,
    init=lambda key, stacked, ctx, batch0: B.dpsgd_init(key, stacked),
    step=lambda state, batch, ctx: B.dpsgd_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        grad_shift=ctx.extras.get("grad_shift")),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _full_msg_bits(hps, n)),
    edge_bits=_full_msg_bits,
    # lr is a traced per-lane scalar under bind_batched
))

register(Algorithm(
    name="dfedsam",
    hp_cls=DFedSAMHp,
    init=lambda key, stacked, ctx, batch0: B.dfedsam_init(key, stacked),
    step=lambda state, batch, ctx: B.dfedsam_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        rho=ctx.hps.rho, local_steps=ctx.hps.local_steps,
        grad_shift=ctx.extras.get("grad_shift")),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _full_msg_bits(hps, n)),
    edge_bits=_full_msg_bits,
    static_hp_fields=("local_steps",),  # python loop count in the step
))


def _choco_setup(topo, hps, mixing, seed):
    return {"comp": rand_k(hps.comp_frac, hps.value_bits, rescale=False)}


register(Algorithm(
    name="choco",
    hp_cls=ChocoHp,
    init=lambda key, stacked, ctx, batch0: B.choco_init(key, stacked),
    step=lambda state, batch, ctx: B.choco_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        ctx.extras["comp"], ctx.hps.gossip_gamma,
        grad_shift=ctx.extras.get("grad_shift")),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _choco_edge_bits(hps, n)),
    edge_bits=_choco_edge_bits,
    setup=_choco_setup,
    # the rand-k sparsifier's keep count round(frac·n) is shape-static
    static_hp_fields=("comp_frac", "value_bits"),
    rep_init=lambda key, stacked, ctx, batch0, arrays:
        flt_mod.rep_choco_init(key, stacked, arrays),
    rep_step=lambda state, batch, ctx: flt_mod.rep_choco_step(
        state, batch, ctx.grad_fn, ctx.hps.lr, ctx.extras["comp"],
        ctx.hps.gossip_gamma, ctx.extras["fault"],
        ctx.extras["fault_arrays"], ctx.extras["innov_bits"],
        ctx.extras["repair"], grad_shift=ctx.extras.get("grad_shift")),
))

register(Algorithm(
    name="beer",
    hp_cls=BeerHp,
    init=lambda key, stacked, ctx, batch0: B.beer_init(
        key, stacked, batch0, ctx.grad_fn),
    step=lambda state, batch, ctx: B.beer_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr,
        ctx.extras["comp"], ctx.hps.gossip_gamma,
        grad_shift=ctx.extras.get("grad_shift")),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _beer_edge_bits(hps, n)),
    edge_bits=_beer_edge_bits,
    needs_batch0=True,
    setup=_choco_setup,
    static_hp_fields=("comp_frac", "value_bits"),
    rep_init=lambda key, stacked, ctx, batch0, arrays:
        flt_mod.rep_beer_init(key, stacked, batch0, ctx.grad_fn, arrays),
    rep_step=lambda state, batch, ctx: flt_mod.rep_beer_step(
        state, batch, ctx.grad_fn, ctx.hps.lr, ctx.extras["comp"],
        ctx.hps.gossip_gamma, ctx.extras["fault"],
        ctx.extras["fault_arrays"], ctx.extras["innov_bits"],
        ctx.extras["repair"], grad_shift=ctx.extras.get("grad_shift")),
))

register(Algorithm(
    name="anq_nids",
    hp_cls=AnqNidsHp,
    init=lambda key, stacked, ctx, batch0: B.nids_init(
        key, stacked, batch0, ctx.grad_fn, ctx.hps.lr),
    step=lambda state, batch, ctx: B.nids_step(
        state, batch, ctx.grad_fn, ctx.mixer, ctx.hps.lr, ctx.extras["q"],
        grad_shift=ctx.extras.get("grad_shift")),
    wire_bits=lambda topo, hps, n: _dense_edges_bits(
        topo, n, _anq_edge_bits(hps, n)),
    edge_bits=_anq_edge_bits,
    needs_batch0=True,
    setup=lambda topo, hps, mixing, seed: {"q": qsgd(hps.qsgd_levels)},
    static_hp_fields=("qsgd_levels",),  # quantizer wire format
    rep_init=lambda key, stacked, ctx, batch0, arrays:
        flt_mod.rep_nids_init(key, stacked, arrays),
    rep_step=lambda state, batch, ctx: flt_mod.rep_nids_step(
        state, batch, ctx.grad_fn, ctx.hps.lr, ctx.extras["q"],
        ctx.extras["fault"], ctx.extras["fault_arrays"],
        ctx.extras["innov_bits"], ctx.extras["repair"],
        grad_shift=ctx.extras.get("grad_shift")),
))
