"""Partial Message Exchange (PME) — Algorithm 2 of the PaME paper.

Every selected neighbor j of node i transmits only s_j randomly chosen
coordinates of w_j; node i averages coordinate l over the lambda_{i,l}
neighbors that sent it and fills missing coordinates from its own w_i.

Two mask samplers are provided:
  * "exact"     — s coordinates chosen uniformly *without replacement*
                  (the paper's scheme; Theorem 1 applies verbatim);
  * "bernoulli" — each coordinate kept i.i.d. with prob p = s/n
                  (same mean traffic, used for very large parameter leaves
                  where an argsort over n is wasteful).

The aggregation itself is written as dense masked matmuls over the node
axis — TPU-native (MXU) data movement; under GSPMD the node-axis einsums
lower to all-gathers across the (pod, data) mesh axes.  A compressed
payload path (values + PRNG seed instead of dense masked vectors) lives in
`repro.core.gossip` and `repro.kernels.pme_average`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "sample_coordinate_masks",
    "sample_neighbor_selection",
    "sample_neighbor_selection_padded",
    "pme_average",
    "pme_average_pytree",
    "pme_average_pytree_padded",
    "naive_average",
    "message_bits",
    "leaf_rates",
    "tree_message_bits",
]


# exact-mode leaves at least this large route through the fused Pallas
# kernel (kernels.pme_average); smaller ones stay on the plain einsum.
_KERNEL_MIN_ELEMS = 1 << 17


def sample_coordinate_masks(
    key: jax.Array,
    m: int,
    n: int,
    s: int,
    mode: str = "exact",
) -> jax.Array:
    """Per-sender coordinate masks M: [m, n] bool, |M_j| = s (exact mode).

    Node j draws T_j^k subset of [n] with |T_j^k| = s, uniformly without
    replacement, independently across nodes (Setup 1.3).
    """
    if mode == "exact":
        if s >= n:  # dense exchange (s = n): every coordinate is sent
            return jnp.ones((m, n), bool)
        u = jax.random.uniform(key, (m, n))
        # keep the s smallest entries per row: one O(n log s) top_k pass on
        # -u instead of two full argsorts (selects the same set of
        # coordinates as the rank-based formulation for any draw of u).
        _, idx = jax.lax.top_k(-u, s)
        rows = jnp.arange(m)[:, None]
        return jnp.zeros((m, n), bool).at[rows, idx].set(True)
    elif mode == "bernoulli":
        p = s / n
        return jax.random.bernoulli(key, p, (m, n))
    raise ValueError(f"unknown mask mode {mode!r}")


def sample_neighbor_selection_padded(
    key: jax.Array,
    nbrs: jax.Array,  # [m, d] padded neighbor ids
    valid: jax.Array,  # [m, d] bool
    t: jax.Array,  # [m] int — t_i = floor(nu_i * |N_i|), >= 1
    comm_mask: jax.Array,  # [m] bool — k in K_i?
    survivors: Optional[jax.Array] = None,  # [m, d] bool — realized edges
) -> jax.Array:
    """Random neighbor selection N_i^k (Alg. 1 line 5) in padded form.

    Returns sel: [m, d] bool where sel[i, slot] marks nbrs[i, slot] as a
    selected neighbor of receiver i this round.  Rows of non-communicating
    receivers are all-zero — the "local parameter tracking" branch (Alg. 1
    line 9) with no per-node cond.  Same PRNG draws as the dense variant,
    which is just this selection scattered into an [m, m] matrix.

    Under a dynamic-network scenario, `survivors` restricts selection to
    the step's realized edge set (`Realization.edge_alive`): dropped links
    and offline neighbors can never be picked, and a receiver with fewer
    than t_i surviving neighbors simply pulls from all of them.
    """
    if survivors is not None:
        valid = valid & survivors
    m, d = nbrs.shape
    u = jax.random.uniform(key, (m, d))
    u = jnp.where(valid, u, jnp.inf)  # never pick padding
    # receiver i keeps its t_i smallest draws: a single top_k pass over the
    # (small) padded-degree axis, then scatter "position < t_i" back through
    # the sort order — picks the same neighbors as the double-argsort rank
    # formulation without materialising two full sorts.
    _, order = jax.lax.top_k(-u, d)  # ascending u per row
    take = jnp.arange(d)[None, :] < t[:, None]
    sel = jnp.zeros((m, d), bool).at[jnp.arange(m)[:, None], order].set(take)
    sel = sel & valid  # [m, d] — receiver i picks these
    return sel & comm_mask[:, None]


def sample_neighbor_selection(
    key: jax.Array,
    nbrs: jax.Array,  # [m, d] padded neighbor ids
    valid: jax.Array,  # [m, d] bool
    t: jax.Array,  # [m] int — t_i = floor(nu_i * |N_i|), >= 1
    comm_mask: jax.Array,  # [m] bool — k in K_i?
    survivors: Optional[jax.Array] = None,  # [m, d] bool — realized edges
) -> jax.Array:
    """Random neighbor selection N_i^k (Alg. 1 line 5) as a matrix A.

    Returns A: [m, m] float where A[j, i] = 1 iff node j is a selected
    neighbor of receiver i this round (column i describes N_i^k).  Columns
    of non-communicating receivers are all-zero, which makes every
    coordinate count lambda_{i,l} = 0 and PME fall back to w_i — exactly
    the "local parameter tracking" branch (Alg. 1 line 9).  `survivors`
    restricts selection to a scenario's realized edge set.
    """
    m, d = nbrs.shape
    sel = sample_neighbor_selection_padded(
        key, nbrs, valid, t, comm_mask, survivors=survivors
    )
    # edge-list scatter into dense A[sender, receiver]: m·d scalar adds
    # instead of the old [m, d, m] one-hot einsum, whose O(m²·d) operand
    # dominated memory at large m.  Padding slots scatter sel=False (0.0)
    # onto A[i, i], an additive no-op (a node is never its own neighbor,
    # so the true diagonal is 0).
    rows = jnp.broadcast_to(jnp.arange(m, dtype=nbrs.dtype)[:, None], (m, d))
    return (
        jnp.zeros((m, m), jnp.float32)
        .at[nbrs, rows]
        .add(sel.astype(jnp.float32))
    )


def pme_average(
    w: jax.Array,  # [m, n] node-stacked parameters
    masks: jax.Array,  # [m, n] bool per-sender coordinate masks
    a: jax.Array,  # [m, m] selection matrix, A[j, i] = j in N_i^k
    own: Optional[jax.Array] = None,  # [m, n] receiver's own view (default w)
) -> jax.Array:
    """Count-weighted PME average — Alg. 2 line 6, Eq. (6)/(7).

    v_bar[i, l] = sum_{j in N_i^k, l in T_j} w[j, l] / lambda_{i,l}
    with fallback own[i, l] (= w[i, l] unless overridden) when
    lambda_{i,l} = 0.
    """
    wm = jnp.where(masks, w, 0.0)
    agg = jnp.einsum("jn,ji->in", wm, a)  # sum of received coords
    cnt = jnp.einsum("jn,ji->in", masks.astype(w.dtype), a)  # lambda_{i,l}
    return jnp.where(cnt > 0, agg / jnp.maximum(cnt, 1.0), w if own is None else own)


def naive_average(
    w: jax.Array,
    masks: jax.Array,
    a: jax.Array,
) -> jax.Array:
    """The *biased* strawman of Theorem 1: divide by |N_i^k| instead of
    lambda_{i,l}.  Expectation is (s/n) * mean — kept for tests/benchmarks."""
    wm = jnp.where(masks, w, 0.0)
    agg = jnp.einsum("jn,ji->in", wm, a)
    t = jnp.maximum(a.sum(axis=0), 1.0)  # |N_i^k| per receiver
    return agg / t[:, None]


def pme_average_pytree(
    key: jax.Array,
    params: object,  # pytree with [m, ...] leaves
    a: jax.Array,
    p,  # float, or per-leaf rate sequence (tree partition — see leaf_rates)
    mode: str = "bernoulli",
    self_params: Optional[object] = None,
) -> object:
    """Apply PME leaf-wise to a node-stacked parameter pytree.

    Each leaf is treated as its own message segment with the same keep
    fraction p = s/n; the coordinate mask of sender j is regenerated from
    `key` fold_in'd with the leaf index, mirroring the seed-based wire
    format (only values + a seed move between nodes).  Passing a sequence
    of rates instead of a scalar gives each leaf its own keep fraction
    (the tree-partitioned exchange; order = tree_flatten leaf order).

    `self_params` overrides the receiver's *own* view: the lambda=0
    fallback reads from it instead of `params`.  The bounded-staleness
    path passes the delayed sender stack as `params` (what the network
    transports) and the fresh parameters as `self_params` (a node always
    knows its own current point) — delay then hits only communication,
    never the local fill.  None keeps the classic single-stack semantics.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    self_leaves = (
        leaves if self_params is None
        else jax.tree_util.tree_flatten(self_params)[0]
    )
    m = leaves[0].shape[0]
    per_leaf = isinstance(p, (tuple, list))
    out = []
    for idx, leaf in enumerate(leaves):
        lkey = jax.random.fold_in(key, idx)
        own = self_leaves[idx]
        p_i = p[idx] if per_leaf else p
        if mode == "exact":
            flat = leaf.reshape(m, -1)
            n = flat.shape[1]
            s = max(1, int(round(p_i * n)))
            masks = sample_coordinate_masks(lkey, m, n, s, mode="exact")
            from repro.core.mixing import default_impl

            if self_params is None and (
                default_impl() == "pallas"
                or (
                    flat.size >= _KERNEL_MIN_ELEMS
                    and jax.default_backend() != "cpu"
                )
            ):
                # hot path: fused Pallas kernel (1 HBM read + 1 write of the
                # [m, n] operand).  By size/backend gate, tiny leaves stay on
                # the einsum path — kernel launch overhead dominates — and so
                # does CPU, where the kernel only exists in (much slower)
                # interpret mode.  REPRO_GOSSIP_IMPL="pallas" overrides both
                # gates so the whole dense-exchange path runs through the
                # kernel (interpret on CPU) alongside the fused gossip
                # contraction.  (The kernel computes the fallback from `w`
                # internally, so a self-view override routes through the
                # einsum instead.)
                from repro.kernels.pme_average.ops import (
                    pme_average as pme_average_fused,
                )

                avg = pme_average_fused(flat, masks, a)
            elif self_params is None:
                # positional-only call: drop-in average variants (e.g. the
                # naive_average ablation) need not know about `own`
                avg = pme_average(flat, masks, a)
            else:
                avg = pme_average(flat, masks, a, own=own.reshape(m, -1))
            out.append(avg.reshape(leaf.shape))
        else:
            # No reshape: keep the leaf's trailing structure (and thus its
            # tensor sharding) intact; only the node axis is contracted.
            # Operands stay in the leaf dtype (bf16 at model scale) with f32
            # accumulation — counts <= m are exactly representable.
            masks = jax.random.bernoulli(lkey, p_i, leaf.shape)
            mask_t = masks.astype(leaf.dtype)
            a_t = a.astype(leaf.dtype)
            agg = jnp.einsum(
                "j...,ji->i...", leaf * mask_t, a_t,
                preferred_element_type=jnp.float32,
            )
            cnt = jnp.einsum(
                "j...,ji->i...", mask_t, a_t, preferred_element_type=jnp.float32
            )
            avg = jnp.where(
                cnt > 0, (agg / jnp.maximum(cnt, 1.0)).astype(leaf.dtype), own
            )
            out.append(avg)
    return jax.tree_util.tree_unflatten(treedef, out)


def pme_average_pytree_padded(
    key: jax.Array,
    params: object,  # pytree with [m, ...] leaves
    nbrs: jax.Array,  # [m, d] padded neighbor ids
    sel: jax.Array,   # [m, d] bool — sample_neighbor_selection_padded output
    p,  # float, or per-leaf rate sequence (tree partition)
    mode: str = "bernoulli",
    pad: Optional[jax.Array] = None,  # [m, d] bool — structural padding
    impl: Optional[str] = None,       # gossip contraction (see core.mixing)
    self_params: Optional[object] = None,
) -> object:
    """PME applied leaf-wise through the padded neighbor-exchange form.

    Same estimator as `pme_average_pytree` with a dense selection matrix —
    v_bar[i, l] = sum over selected neighbors of masked w[j, l] / count,
    falling back to w[i, l] where the count is zero — but the node-axis
    contraction runs through the shared `repro.core.mixing.gather_terms`
    core over the d = max_degree slots: O(m·deg·n) instead of the
    O(m²·n) einsum, with the payload sum and the lambda_{i,l} coordinate
    counts aggregated in one slot walk (two gathers per slot).
    Coordinate masks are drawn exactly as in the dense path (fold_in per
    leaf), so the two agree to fp tolerance for the same key.
    `self_params` overrides the receiver's lambda=0 fallback view exactly
    as in `pme_average_pytree` (delay hits only communication).
    """
    from repro.core.mixing import gather_terms

    leaves, treedef = jax.tree_util.tree_flatten(params)
    self_leaves = (
        leaves if self_params is None
        else jax.tree_util.tree_flatten(self_params)[0]
    )
    m, d = nbrs.shape
    sel_f = sel.astype(jnp.float32)
    per_leaf = isinstance(p, (tuple, list))
    out = []
    for idx, leaf in enumerate(leaves):
        lkey = jax.random.fold_in(key, idx)
        own = self_leaves[idx]
        shape = leaf.shape
        p_i = p[idx] if per_leaf else p
        if mode == "exact":
            flat = leaf.reshape(m, -1)
            n = flat.shape[1]
            s = max(1, int(round(p_i * n)))
            masks = sample_coordinate_masks(lkey, m, n, s, mode="exact")
            payload = jnp.where(masks, flat, 0.0)
            mask_f = masks.astype(jnp.float32)
        else:
            masks = jax.random.bernoulli(lkey, p_i, shape)
            flat = leaf
            payload = flat * masks.astype(flat.dtype)
            mask_f = masks.astype(jnp.float32)
        agg, cnt = gather_terms(
            nbrs,
            [(sel_f, payload.astype(jnp.float32)), (sel_f, mask_f)],
            pad=pad, impl=impl,
        )
        fallback = flat if self_params is None else own.reshape(flat.shape)
        avg = jnp.where(
            cnt > 0, (agg / jnp.maximum(cnt, 1.0)).astype(flat.dtype), fallback
        )
        out.append(avg.reshape(shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def message_bits(s: int, n: int, value_bits: int = 64) -> int:
    """Eq. (8): transmitting a sparse vector costs (value_bits-1)*s + n bits
    (s payload values + an n-bit occupancy pattern); 64-bit gives 63s + n.

    value_bits=8 is the int8 wire format of exchange="compressed_q8": full
    8-bit payload values (no sign-bit folding), the n-bit occupancy pattern,
    plus one f32 absmax scale per message for dequantisation.
    """
    if value_bits == 8:
        return 8 * s + n + 32
    return (value_bits - 1) * s + n


def leaf_rates(num_leaves: int, p: float, p_leaf=None) -> Tuple[float, ...]:
    """Resolve the per-leaf transmission rates of a tree-partitioned message.

    ``p_leaf=None`` broadcasts the global rate p to every leaf; otherwise
    ``p_leaf`` must list one rate in (0, 1] per pytree leaf, in
    ``tree_flatten`` leaf order.
    """
    if p_leaf is None:
        rates = (float(p),) * num_leaves
    else:
        rates = tuple(float(r) for r in p_leaf)
        if len(rates) != num_leaves:
            raise ValueError(
                f"p_leaf has {len(rates)} rates but the model pytree has "
                f"{num_leaves} leaves"
            )
    for r in rates:
        if not 0.0 < r <= 1.0:
            raise ValueError(f"per-leaf transmission rate {r} outside (0, 1]")
    return rates


def tree_message_bits(sizes, rates, value_bits: int = 64) -> int:
    """Eq. (8) cost of one tree-partitioned message.

    Each pytree leaf is its own message segment: leaf of n_leaf coordinates
    at rate r carries s_leaf = max(1, round(r·n_leaf)) payload values plus
    its own n_leaf-bit occupancy pattern, so the total is
    sum_leaf message_bits(s_leaf, n_leaf).  This is what actually moves on
    the wire for a multi-leaf model — the flat formula
    message_bits(round(p·n_total), n_total) prices a single occupancy
    pattern over the concatenated vector, which no leaf-wise sampler emits.
    """
    if isinstance(rates, float):
        rates = (rates,) * len(sizes)
    if len(rates) != len(sizes):
        raise ValueError(
            f"got {len(rates)} rates for {len(sizes)} leaf sizes"
        )
    return sum(
        message_bits(max(1, int(round(r * n))), int(n), value_bits)
        for r, n in zip(rates, sizes)
    )
