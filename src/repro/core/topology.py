"""Communication topologies for decentralized federated learning.

Builds the undirected communication graph G = ([m], E), the neighbor sets
N_i, and the doubly-stochastic communication matrix B of Assumption 1
(PaME paper, Sec. IV-A).  The paper defines B_ji = 1/m_i for j in N_i which
is doubly stochastic only for regular graphs; for general graphs we use the
standard Metropolis–Hastings weights (symmetric, doubly stochastic) and keep
the paper's definition for regular topologies where the two coincide.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

__all__ = [
    "Topology",
    "ring_graph",
    "grid_graph",
    "complete_graph",
    "star_graph",
    "erdos_renyi_graph",
    "regular_graph",
    "build_topology",
    "metropolis_matrix",
    "spectral_gap_zeta",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fixed communication graph plus derived quantities.

    Attributes:
      m: number of nodes.
      adjacency: [m, m] 0/1 symmetric numpy array, zero diagonal.
      neighbor_sets: tuple of tuples, N_i for each node i (excludes i).
      mixing: [m, m] doubly-stochastic matrix B (float64).
      zeta: max(|lambda_2|, |lambda_m|) of B — Assumption 1 spectral gap.
    """

    m: int
    adjacency: np.ndarray
    neighbor_sets: Tuple[Tuple[int, ...], ...]
    mixing: np.ndarray
    zeta: float

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    def neighbor_matrix_padded(self) -> Tuple[np.ndarray, np.ndarray]:
        """Pad neighbor lists to [m, max_degree] for device-side sampling.

        Returns (nbrs, valid) where nbrs[i, :] lists N_i padded with i's own
        index and valid[i, :] marks real entries.
        """
        d = self.max_degree
        nbrs = np.tile(np.arange(self.m)[:, None], (1, d))
        valid = np.zeros((self.m, d), dtype=bool)
        for i, ns in enumerate(self.neighbor_sets):
            nbrs[i, : len(ns)] = ns
            valid[i, : len(ns)] = True
        return nbrs, valid

    def mixing_padded(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The mixing matrix B in padded neighbor-exchange form.

        Returns (nbrs, w, is_self), each [m, max_degree + 1]: row i lists
        N_i ∪ {i} in ascending sender order with the receive weight
        w[i, slot] = B[nbrs[i, slot], i]; padding slots repeat i with weight
        exactly 0.0 so they are no-ops under IEEE summation.  This is the
        O(m·deg·n) gather form consumed by `repro.core.mixing.mix_padded`,
        replacing the dense O(m²·n) einsum on sparse graphs.
        """
        k = self.max_degree + 1
        nbrs = np.tile(np.arange(self.m)[:, None], (1, k)).astype(np.int32)
        w = np.zeros((self.m, k), dtype=np.float32)
        is_self = np.zeros((self.m, k), dtype=bool)
        for i, ns in enumerate(self.neighbor_sets):
            ids = sorted(list(ns) + [i])
            nbrs[i, : len(ids)] = ids
            w[i, : len(ids)] = self.mixing[ids, i]
            is_self[i, : len(ids)] = np.asarray(ids) == i
        return nbrs, w, is_self


def _adjacency_from_edges(m: int, edges: List[Tuple[int, int]]) -> np.ndarray:
    a = np.zeros((m, m), dtype=np.int64)
    for i, j in edges:
        if i == j:
            continue
        a[i, j] = 1
        a[j, i] = 1
    return a


def ring_graph(m: int) -> np.ndarray:
    if m < 2:
        raise ValueError("ring needs m >= 2")
    return _adjacency_from_edges(m, [(i, (i + 1) % m) for i in range(m)])


def grid_graph(m: int) -> np.ndarray:
    """2-D torus grid; m must have an integer-ish factorization r*c."""
    r = int(np.floor(np.sqrt(m)))
    while m % r != 0:
        r -= 1
    c = m // r
    edges = []
    for i in range(r):
        for j in range(c):
            u = i * c + j
            edges.append((u, i * c + (j + 1) % c))
            edges.append((u, ((i + 1) % r) * c + j))
    return _adjacency_from_edges(m, edges)


def complete_graph(m: int) -> np.ndarray:
    a = np.ones((m, m), dtype=np.int64) - np.eye(m, dtype=np.int64)
    return a


def star_graph(m: int) -> np.ndarray:
    """CFL as a special case of DFL (paper Sec. I)."""
    return _adjacency_from_edges(m, [(0, i) for i in range(1, m)])


def erdos_renyi_graph(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Random G(m, p) conditioned on connectivity (re-draw until connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        upper = rng.random((m, m)) < p
        a = np.triu(upper, k=1).astype(np.int64)
        a = a + a.T
        if _is_connected(a):
            return a
    raise RuntimeError("failed to sample a connected G(m,p); raise p")


def regular_graph(m: int, degree: int, seed: int = 0) -> np.ndarray:
    """Random d-regular graph via repeated configuration-model draws."""
    import networkx as nx

    g = nx.random_regular_graph(degree, m, seed=seed)
    a = np.zeros((m, m), dtype=np.int64)
    for u, v in g.edges:
        a[u, v] = 1
        a[v, u] = 1
    if not _is_connected(a):
        return regular_graph(m, degree, seed=seed + 1)
    return a


def _is_connected(a: np.ndarray) -> bool:
    m = a.shape[0]
    seen = np.zeros(m, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        for v in np.nonzero(a[u])[0]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return bool(seen.all())


def metropolis_matrix(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings doubly-stochastic mixing matrix.

    B_ij = 1/(1+max(d_i, d_j)) for (i,j) in E, diagonal absorbs the rest.
    Symmetric => doubly stochastic; for d-regular graphs equals the paper's
    1/m_i row rule up to the self-weight.
    """
    a = adjacency
    m = a.shape[0]
    deg = a.sum(axis=1)
    b = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in np.nonzero(a[i])[0]:
            b[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(b, 1.0 - b.sum(axis=1))
    return b


def spectral_gap_zeta(mixing: np.ndarray) -> float:
    """zeta = max(|lambda_2(B)|, |lambda_m(B)|) — Assumption 1, Eq. (10)."""
    eig = np.sort(np.linalg.eigvalsh(mixing))[::-1]
    return float(max(abs(eig[1]), abs(eig[-1])))


_BUILDERS = {
    "ring": lambda m, **kw: ring_graph(m),
    "grid": lambda m, **kw: grid_graph(m),
    "complete": lambda m, **kw: complete_graph(m),
    "star": lambda m, **kw: star_graph(m),
    "erdos_renyi": lambda m, **kw: erdos_renyi_graph(
        m, kw.get("p", 0.4), kw.get("seed", 0)
    ),
    "regular": lambda m, **kw: regular_graph(
        m, kw.get("degree", 4), kw.get("seed", 0)
    ),
}


def build_topology(kind: str, m: int, **kwargs) -> Topology:
    if kind not in _BUILDERS:
        raise ValueError(f"unknown topology {kind!r}; pick from {sorted(_BUILDERS)}")
    a = _BUILDERS[kind](m, **kwargs)
    if not _is_connected(a):
        raise ValueError(f"{kind} graph on m={m} is not connected")
    nsets = tuple(tuple(int(j) for j in np.nonzero(a[i])[0]) for i in range(m))
    b = metropolis_matrix(a)
    return Topology(
        m=m,
        adjacency=a,
        neighbor_sets=nsets,
        mixing=b,
        zeta=spectral_gap_zeta(b),
    )
