from repro.data.synthetic import (  # noqa: F401
    SyntheticClassification,
    SyntheticTokens,
    make_linear_regression,
    make_logistic_regression,
)
from repro.data.partition import (  # noqa: F401
    dirichlet_partition,
    label_skew_partition,
    iid_partition,
)
from repro.data.pipeline import NodeBatcher  # noqa: F401
