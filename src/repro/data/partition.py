"""Non-IID partitioners — the paper's heterogeneity mechanisms.

  * label_skew_partition — each node sees samples from exactly C classes
    (paper Fig. 11, C in {1, 7, 10}); lower C = more heterogeneous.
  * dirichlet_partition  — class mix per node ~ Dir(beta) (paper Fig. 12,
    beta in {0.3, 0.6}); lower beta = more heterogeneous.
  * iid_partition        — uniform shuffle baseline.
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["iid_partition", "label_skew_partition", "dirichlet_partition"]


def iid_partition(labels: np.ndarray, m: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(part) for part in np.array_split(idx, m)]


def label_skew_partition(
    labels: np.ndarray, m: int, classes_per_node: int, seed: int = 0
) -> List[np.ndarray]:
    """Each node is assigned `classes_per_node` classes and receives an
    equal share of every assigned class's samples.

    Raises ValueError when `classes_per_node` falls outside
    ``[1, n_classes]`` (beyond n_classes the round-robin would silently
    assign the same class to a node twice) and when any node would end up
    with an empty shard (downstream batchers cannot sample from it).
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    if not 1 <= classes_per_node <= n_classes:
        raise ValueError(
            f"classes_per_node={classes_per_node} outside [1, {n_classes}]: "
            f"the dataset has {n_classes} classes, so larger values would "
            "double-assign a class to the same node"
        )
    by_class = [np.nonzero(labels == c)[0] for c in range(n_classes)]
    for c, idx in enumerate(by_class):
        if len(idx) == 0:
            raise ValueError(
                f"class {c} has no samples; every class in [0, labels.max()] "
                "must be populated to cover its assigned nodes"
            )
        rng.shuffle(idx)
    # round-robin class assignment so every class is covered
    assign = [
        [(i * classes_per_node + j) % n_classes for j in range(classes_per_node)]
        for i in range(m)
    ]
    # per class, how many nodes want it -> split its indices that many ways
    takers: List[List[int]] = [[] for _ in range(n_classes)]
    for i, cls_list in enumerate(assign):
        for c in cls_list:
            takers[c].append(i)
    shares = [np.array_split(by_class[c], max(1, len(takers[c]))) for c in range(n_classes)]
    parts: List[List[np.ndarray]] = [[] for _ in range(m)]
    for c in range(n_classes):
        for k, node in enumerate(takers[c]):
            parts[node].append(shares[c][k])
    out = []
    for i, p in enumerate(parts):
        shard = np.sort(np.concatenate(p)) if p else np.array([], np.int64)
        if len(shard) == 0:
            starved = assign[i]
            raise ValueError(
                f"node {i} received an empty shard (assigned classes "
                f"{starved} have too few samples for "
                f"{[len(takers[c]) for c in starved]} takers); use more "
                "data or fewer nodes"
            )
        out.append(shard)
    return out


def dirichlet_partition(
    labels: np.ndarray, m: int, beta: float, seed: int = 0, min_size: int = 2
) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(100):
        parts: List[List[int]] = [[] for _ in range(m)]
        for c in range(n_classes):
            idx = np.nonzero(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(m, beta))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for node, chunk in enumerate(np.split(idx, cuts)):
                parts[node].extend(chunk.tolist())
        if min(len(p) for p in parts) >= min_size:
            return [np.sort(np.array(p, np.int64)) for p in parts]
    raise RuntimeError("dirichlet partition failed min_size; raise beta")
