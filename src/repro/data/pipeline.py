"""Per-node sub-batch sampling B_i^k (Alg. 1 line 12) as a data pipeline.

NodeBatcher owns per-node index pools and serves node-stacked batches
[m, batch, ...] each round, with independent per-node shuffling — the
device-side counterpart feeds straight into `pame_step`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["NodeBatcher"]


class NodeBatcher:
    def __init__(
        self,
        arrays: Dict[str, np.ndarray],  # each [N, ...] global arrays
        parts: Sequence[np.ndarray],    # per-node index lists into N
        batch_size: int,
        seed: int = 0,
    ):
        self.arrays = arrays
        self.parts = [np.asarray(p) for p in parts]
        self.m = len(parts)
        self.batch = batch_size
        self._rngs = [np.random.default_rng(seed + 7919 * i) for i in range(self.m)]
        self._cursors = [len(p) for p in self.parts]  # force shuffle on first use
        self._orders: List[Optional[np.ndarray]] = [None] * self.m

    def _next_indices(self, i: int) -> np.ndarray:
        part = self.parts[i]
        if len(part) == 0:
            raise ValueError(f"node {i} has an empty shard")
        out = np.empty(self.batch, np.int64)
        filled = 0
        while filled < self.batch:
            if self._cursors[i] >= len(part):
                self._orders[i] = self._rngs[i].permutation(len(part))
                self._cursors[i] = 0
            take = min(self.batch - filled, len(part) - self._cursors[i])
            sel = self._orders[i][self._cursors[i] : self._cursors[i] + take]
            out[filled : filled + take] = part[sel]
            filled += take
            self._cursors[i] += take
        return out

    def next(self, step: int = 0) -> Dict[str, np.ndarray]:
        del step
        idx = np.stack([self._next_indices(i) for i in range(self.m)])  # [m, b]
        return {k: v[idx] for k, v in self.arrays.items()}
