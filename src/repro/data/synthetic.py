"""Synthetic datasets reproducing the paper's Examples 1–4 inputs.

The container is offline, so Fashion-MNIST / CIFAR-10 are replaced by
synthetic classification data with matched shapes (28x28x1 / 32x32x3, 10
classes) drawn from class-conditional Gaussians — the heterogeneity
*mechanisms* (label-skew, Dirichlet) operate on labels and are therefore
reproduced exactly; absolute accuracies are not comparable to the paper's
raw-image numbers and are labelled as synthetic in the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "make_linear_regression",
    "make_logistic_regression",
    "SyntheticClassification",
    "SyntheticTokens",
]


def make_linear_regression(
    m: int, samples_per_node: int, n: int, seed: int = 0, noise: float = 0.5,
    nonzero_frac: float = 0.01,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Paper Example 1: b = <a, w*> + 0.5 e, w* has 1% nonzeros in
    [0.5,2] U [-2,-0.5].  Returns (A [m,S,n], b [m,S], w_star [n])."""
    rng = np.random.default_rng(seed)
    w_star = np.zeros(n)
    nnz = max(1, int(round(nonzero_frac * n)))
    idx = rng.choice(n, nnz, replace=False)
    w_star[idx] = rng.uniform(0.5, 2.0, nnz) * rng.choice([-1.0, 1.0], nnz)
    a = rng.standard_normal((m, samples_per_node, n))
    b = a @ w_star + noise * rng.standard_normal((m, samples_per_node))
    return a.astype(np.float32), b.astype(np.float32), w_star.astype(np.float32)


def make_logistic_regression(
    m: int, samples_per_node: int, n: int, seed: int = 0, nonzero_frac: float = 0.5,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Paper Example 2: labels from sigmoid(<a, w*>), w* 50% nonzero."""
    rng = np.random.default_rng(seed)
    w_star = np.zeros(n)
    nnz = max(1, int(round(nonzero_frac * n)))
    idx = rng.choice(n, nnz, replace=False)
    w_star[idx] = rng.uniform(0.5, 2.0, nnz) * rng.choice([-1.0, 1.0], nnz)
    a = rng.standard_normal((m, samples_per_node, n))
    p = 1.0 / (1.0 + np.exp(-(a @ w_star)))
    b = (rng.random((m, samples_per_node)) < p).astype(np.float32)
    return a.astype(np.float32), b, w_star.astype(np.float32)


@dataclasses.dataclass
class SyntheticClassification:
    """Class-conditional Gaussian images; stand-in for FMNIST / CIFAR-10."""

    images: np.ndarray  # [N, H, W, C] float32
    labels: np.ndarray  # [N] int32
    n_classes: int

    @staticmethod
    def make(
        n_samples: int = 4096,
        shape: Tuple[int, int, int] = (28, 28, 1),
        n_classes: int = 10,
        seed: int = 0,
        sep: float = 2.0,
    ) -> "SyntheticClassification":
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, n_classes, n_samples).astype(np.int32)
        # one Gaussian mean-image per class; output standardized to unit
        # variance (as real image pipelines do) so loss scales are sane
        means = rng.standard_normal((n_classes,) + shape).astype(np.float32) * sep
        images = means[labels] + rng.standard_normal(
            (n_samples,) + shape
        ).astype(np.float32)
        images /= np.sqrt(sep**2 + 1.0)
        return SyntheticClassification(images, labels, n_classes)


@dataclasses.dataclass
class SyntheticTokens:
    """Deterministic synthetic token corpus for LM training.

    Per-node Markov-ish streams: node i's unigram distribution is a
    Dirichlet draw, giving *feature-distribution* heterogeneity for the
    language-model DFL experiments (the LM analogue of label skew).
    """

    tokens: np.ndarray  # [m, N] int32

    @staticmethod
    def make(
        m: int, per_node: int, vocab: int, seed: int = 0, alpha: float = 0.3
    ) -> "SyntheticTokens":
        rng = np.random.default_rng(seed)
        toks = np.empty((m, per_node), np.int32)
        for i in range(m):
            probs = rng.dirichlet(np.full(min(vocab, 512), alpha))
            support = rng.choice(vocab, min(vocab, 512), replace=False)
            toks[i] = support[rng.choice(len(probs), per_node, p=probs)]
        return SyntheticTokens(toks)
