"""Partition-spec rules for every parameter / cache / batch leaf.

Rules are keyed on leaf path names and give the *trailing* dims' axes;
extra leading dims (layer-scan axis, DFL node axis) are padded with None
and the node axis (training) gets "node".  Every proposed axis is dropped
if it does not divide the corresponding dim — so the same rules serve all
10 archs (e.g. kv=8 heads cannot shard over model=16 and fall back to the
head_dim).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_spec",
    "params_shardings",
    "batch_shardings",
    "cache_shardings",
    "state_shardings",
    "fit_spec",
]

# trailing-dims rules: substring of the leaf path -> tuple of axis names
# (a tuple entry may itself list fallbacks tried in order)
_RULES: Tuple[Tuple[str, Tuple[object, ...]], ...] = (
    ("embed", ("model", "fsdp")),
    ("lm_head", ("fsdp", "model")),
    ("vision_proj", (None, "fsdp")),
    # attention
    ("attn/wq", ("fsdp", "model")),
    ("attn/wk", ("fsdp", "model")),
    ("attn/wv", ("fsdp", "model")),
    ("attn/wo", ("model", "fsdp")),
    ("attn/w_dq", ("fsdp", None)),
    ("attn/w_uq", ("fsdp", "model")),
    ("attn/w_dkv", ("fsdp", None)),
    ("attn/w_uk", (None, "model")),
    ("attn/w_uv", (None, "model")),
    # dense mlp & shared experts
    ("mlp/w_gate", ("fsdp", "model")),
    ("mlp/w_up", ("fsdp", "model")),
    ("mlp/w_down", ("model", "fsdp")),
    ("shared/w_gate", ("fsdp", "model")),
    ("shared/w_up", ("fsdp", "model")),
    ("shared/w_down", ("model", "fsdp")),
    # routed experts: expert-parallel over `model`
    ("moe/router", ("fsdp", None)),
    ("moe/w_gate", ("model", "fsdp", None)),
    ("moe/w_up", ("model", "fsdp", None)),
    ("moe/w_down", ("model", None, "fsdp")),
    # mamba (fused in_proj baseline; split-proj leaves shard head-aligned)
    ("mamba/in_proj", ("fsdp", "model")),
    ("mamba/in_z", ("fsdp", "model")),
    ("mamba/in_x", ("fsdp", "model")),
    ("mamba/in_B", ("fsdp", None)),
    ("mamba/in_C", ("fsdp", None)),
    ("mamba/in_dt", ("fsdp", "model")),
    ("mamba/out_proj", ("model", "fsdp")),
    ("mamba/conv_x_w", (None, "model")),
    ("mamba/conv_x_b", ("model",)),
    ("mamba/conv_B_w", (None, None)),
    ("mamba/conv_C_w", (None, None)),
    ("mamba/conv_w", (None, "model")),
    ("mamba/conv_b", ("model",)),
)


def fit_spec(axes: Tuple[object, ...], shape: Tuple[int, ...], mesh: Mesh):
    """Drop axes that don't divide their dim; pad/truncate to rank."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    rank = len(shape)
    padded = (None,) * (rank - len(axes)) + tuple(axes)
    for dim, ax in zip(shape, padded[:rank]):
        if ax is None:
            out.append(None)
            continue
        candidates = ax if isinstance(ax, (list, tuple)) else (ax,)
        chosen = None
        for c in candidates:
            if c in sizes and dim % sizes[c] == 0 and sizes[c] > 1:
                chosen = c
                break
        out.append(chosen)
    # an axis may appear only once in a spec
    seen = set()
    for i, ax in enumerate(out):
        if ax is None:
            continue
        if ax in seen:
            out[i] = None
        else:
            seen.add(ax)
    return P(*out)


# experiment hook: {"pattern": axes} entries that take precedence over
# _RULES (set by the dry-run --variant machinery; empty in production)
RULE_OVERRIDES: dict = {}


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh, node_stacked: bool):
    rule: Tuple[object, ...] = ()
    for pattern, axes in RULE_OVERRIDES.items():
        if pattern in path:
            rule = axes
            break
    else:
        for pattern, axes in _RULES:
            if pattern in path:
                rule = axes
                break
    spec = list(fit_spec(rule, shape, mesh))
    if node_stacked and len(spec) >= 1:
        if "node" in mesh.axis_names and shape[0] % dict(
            zip(mesh.axis_names, mesh.devices.shape)
        )["node"] == 0:
            spec[0] = "node"
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "idx", None)
        if key is None:
            key = str(p)
        parts.append(str(key))
    return "/".join(parts)


def params_shardings(params_shapes, mesh: Mesh, node_stacked: bool):
    """ShapeDtypeStruct tree -> NamedSharding tree."""

    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, node_stacked)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_shardings(batch_shapes, mesh: Mesh, node_stacked: bool):
    """tokens [m,b,s] -> (node, fsdp, None); serving [b, s] -> ((node,fsdp), ...)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        shape = leaf.shape
        if node_stacked:
            axes: list = [None] * len(shape)
            if shape and shape[0] % sizes["node"] == 0:
                axes[0] = "node"
            if len(shape) > 1 and shape[1] % sizes["fsdp"] == 0 and sizes["fsdp"] > 1:
                axes[1] = "fsdp"
            return NamedSharding(mesh, P(*axes))
        # serving: batch over (node, fsdp) jointly if divisible
        axes = [None] * len(shape)
        if shape:
            nf = sizes["node"] * sizes["fsdp"]
            if shape[0] % nf == 0:
                axes[0] = ("node", "fsdp") if sizes["fsdp"] > 1 else "node"
            elif shape[0] % sizes["node"] == 0:
                axes[0] = "node"
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    """KV/MLA/SSM cache trees: batch over (node, fsdp); heads over model."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nf = sizes["node"] * sizes["fsdp"]

    def batch_axis(b: int):
        if b % nf == 0:
            return ("node", "fsdp") if sizes["fsdp"] > 1 else "node"
        if b % sizes["node"] == 0:
            return "node"
        return None

    def one(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        # leading dim of every cache leaf (after the layer-stack axis) is batch;
        # stacked caches have [L, B, ...]
        axes: list = [None] * len(shape)
        name = p.rsplit("/", 1)[-1]
        if name == "positions":
            return NamedSharding(mesh, P(*axes))
        # find batch position: stacked caches are [L, B, ...]
        bpos = 1 if len(shape) >= 2 else 0
        axes[bpos] = batch_axis(shape[bpos])
        if name in ("k", "v") and len(shape) >= 4:
            # [L, B, C, KV, hd]
            kv, hd = shape[-2], shape[-1]
            if kv % sizes["model"] == 0:
                axes[-2] = "model"
            elif hd % sizes["model"] == 0:
                axes[-1] = "model"
        if name == "state" and len(shape) >= 4:
            # [L, B, H, P, N]
            if shape[2] % sizes["model"] == 0:
                axes[2] = "model"
        if name == "conv" and len(shape) >= 3:
            if shape[-1] % sizes["model"] == 0:
                axes[-1] = "model"
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def state_shardings(state_shapes, mesh: Mesh):
    """PaMEState: params node-stacked; sigma [m] over node; step/key replicated."""
    params_sh = params_shardings(state_shapes.params, mesh, node_stacked=True)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    sigma_spec = (
        P("node") if state_shapes.sigma.shape[0] % sizes["node"] == 0 else P(None)
    )
    return type(state_shapes)(
        params=params_sh,
        sigma=NamedSharding(mesh, sigma_spec),
        step=NamedSharding(mesh, P()),
        key=NamedSharding(mesh, P()),
    )
