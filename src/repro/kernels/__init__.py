"""Pallas TPU kernels for the compute hot spots.

  pme_average     — the paper's PME count-weighted masked average, fused
                    (mask-mul + two MXU matmuls + divide + self-fill);
  flash_attention — blockwise causal GQA attention (opt. sliding window);
  ssd_scan        — Mamba2 SSD intra-chunk contraction.

Each subpackage: `kernel.py` (pl.pallas_call + BlockSpec VMEM tiling),
`ops.py` (jit'd public wrapper; interpret=True on CPU), `ref.py` (pure-jnp
oracle used by the allclose test sweeps).
"""
