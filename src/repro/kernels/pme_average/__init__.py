from repro.kernels.pme_average.ops import pme_average  # noqa: F401
