"""Pure-jnp oracle for the PME average kernel (same math as core.pme)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pme_average_ref(w: jax.Array, masks: jax.Array, a: jax.Array) -> jax.Array:
    maskf = masks.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    agg = jnp.einsum("jn,ji->in", wf * maskf, a.astype(jnp.float32))
    cnt = jnp.einsum("jn,ji->in", maskf, a.astype(jnp.float32))
    out = jnp.where(cnt > 0, agg / jnp.maximum(cnt, 1.0), wf)
    return out.astype(w.dtype)
