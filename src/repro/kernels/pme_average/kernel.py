"""Fused PME count-weighted average — Pallas TPU kernel.

For a (receiver, coordinate) tile of shape [BM, BN]:
    agg[i, l] = sum_j A[j, i] * M[j, l] * W[j, l]     (MXU matmul)
    cnt[i, l] = sum_j A[j, i] * M[j, l]               (MXU matmul)
    out[i, l] = cnt > 0 ? agg / cnt : W[i, l]         (VPU select)

The grid covers both the coordinate axis (tiles of BN) and the receiver
node axis (tiles of BM), so neither m nor n has to fit a single tile: W/M
tiles stream HBM->VMEM along the coordinate axis with the full sender axis
resident for the contraction, while each grid row only holds its [BM, m]
slice of the selection matrix A^T and the matching [BM, BN] self-fallback
tile of W.  The fusion avoids materialising the masked copy of W and the
count tensor in HBM — on a v5e this takes the op from 4 HBM round trips of
the [m, n] operand down to 1 read + 1 write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_M = 128


def _kernel(at_ref, w_ref, m_ref, wself_ref, out_ref):
    # f32 compute: exact counts, and the CPU interpreter lacks bf16 dots;
    # on TPU the converts fuse into the MXU matmul.
    a_t = at_ref[...].astype(jnp.float32)       # [BM, m]  A^T rows, receiver-major
    w = w_ref[...]                              # [m, BN]  full sender axis
    mask = m_ref[...].astype(jnp.float32)       # [m, BN] (0/1)
    w_self = wself_ref[...].astype(jnp.float32)  # [BM, BN] receivers' own coords
    wm = w.astype(jnp.float32) * mask
    agg = jnp.dot(a_t, wm, preferred_element_type=jnp.float32)
    cnt = jnp.dot(a_t, mask, preferred_element_type=jnp.float32)
    out = jnp.where(cnt > 0, agg / jnp.maximum(cnt, 1.0), w_self)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def pme_average_pallas(
    w: jax.Array,      # [m, n]
    masks: jax.Array,  # [m, n] same dtype as w (0/1)
    a: jax.Array,      # [m, m] selection, A[j, i] = j in N_i^k
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
) -> jax.Array:
    m, n = w.shape
    bn = min(block_n, n)
    bm = min(block_m, m)
    pad_n = (-n) % bn
    pad_m = (-m) % bm
    if pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_n)))
        masks = jnp.pad(masks, ((0, 0), (0, pad_n)))
    a_t = a.T.astype(w.dtype)  # [receiver, sender]
    w_self = w
    if pad_m:
        # pad receiver rows only; the sender (contraction) axis stays m, so
        # padded rows see cnt == 0 and fall back to their (zero) w_self.
        a_t = jnp.pad(a_t, ((0, pad_m), (0, 0)))
        w_self = jnp.pad(w_self, ((0, pad_m), (0, 0)))
    grid = ((m + pad_m) // bm, (n + pad_n) // bn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, m), lambda i, j: (i, 0)),   # A^T receiver rows
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),   # W sender tile
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),   # mask sender tile
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # W self-fallback
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pad_m, n + pad_n), w.dtype),
        interpret=interpret,
    )(a_t, w, masks, w_self)
    return out[:m, :n]
