"""Fused PME count-weighted average — Pallas TPU kernel.

For a coordinate tile of width BN:
    agg[i, l] = sum_j A[j, i] * M[j, l] * W[j, l]     (MXU matmul)
    cnt[i, l] = sum_j A[j, i] * M[j, l]               (MXU matmul)
    out[i, l] = cnt > 0 ? agg / cnt : W[i, l]         (VPU select)

W/M tiles stream HBM->VMEM along the coordinate axis; the selection matrix
A^T (m x m, m = #nodes <= a few hundred) stays resident in VMEM across the
whole grid.  The fusion avoids materialising the masked copy of W and the
count tensor in HBM — on a v5e this takes the op from 4 HBM round trips of
the [m, n] operand down to 1 read + 1 write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _kernel(at_ref, w_ref, m_ref, out_ref):
    # f32 compute: exact counts, and the CPU interpreter lacks bf16 dots;
    # on TPU the converts fuse into the MXU matmul.
    a_t = at_ref[...].astype(jnp.float32)   # [m, m]  A^T, receiver-major
    w = w_ref[...]                          # [m, BN]
    mask = m_ref[...].astype(jnp.float32)   # [m, BN] (0/1)
    wf = w.astype(jnp.float32)
    wm = wf * mask
    agg = jnp.dot(a_t, wm, preferred_element_type=jnp.float32)
    cnt = jnp.dot(a_t, mask, preferred_element_type=jnp.float32)
    out = jnp.where(cnt > 0, agg / jnp.maximum(cnt, 1.0), wf)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pme_average_pallas(
    w: jax.Array,      # [m, n]
    masks: jax.Array,  # [m, n] same dtype as w (0/1)
    a: jax.Array,      # [m, m] selection, A[j, i] = j in N_i^k
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    m, n = w.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        masks = jnp.pad(masks, ((0, 0), (0, pad)))
    grid = ((n + pad) // bn,)
    a_t = a.T.astype(w.dtype)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda j: (0, 0)),    # A^T resident
            pl.BlockSpec((m, bn), lambda j: (0, j)),   # W tile
            pl.BlockSpec((m, bn), lambda j: (0, j)),   # mask tile
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n + pad), w.dtype),
        interpret=interpret,
    )(a_t, w, masks)
    return out[:, :n] if pad else out
