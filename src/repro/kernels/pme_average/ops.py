"""Public wrapper: picks interpret mode on CPU, kernel on TPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pme_average.kernel import (
    DEFAULT_BLOCK_M,
    DEFAULT_BLOCK_N,
    pme_average_pallas,
)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pme_average(
    w: jax.Array,
    masks: jax.Array,
    a: jax.Array,
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
) -> jax.Array:
    """Count-weighted PME average; masks may be bool or numeric."""
    masks = masks.astype(w.dtype)
    return pme_average_pallas(
        w, masks, a, block_n=block_n, block_m=block_m, interpret=_on_cpu()
    )
