"""`gather_terms`-shaped entry point for the fused gossip kernel.

Adapts the exact `repro.core.mixing.gather_terms` contract — a padded
[m, k] neighbor table plus ([m, k] weight, [m, ...] operand) terms — to
`kernel.gossip_gather_pallas`:

  * dead-slot masking: structural padding slots (`pad`) get weight
    exactly 0.0 before the kernel runs, so poisoned padding weights
    (NaN/garbage) can never leak into a receiver row — same contract the
    segsum impl honors by routing padding to a dead segment;
  * weight-table deduplication: terms passing the *same* weight array
    (PME's payload + coordinate-count walk share one selection table)
    are detected by object identity and share one in-kernel scatter
    build;
  * leaf reshaping: [m, ...] operands are flattened to [m, n] and terms
    are bucketed by trailing size — one `pallas_call` per distinct n
    (every current caller uses a single bucket);
  * interpret mode defaults on for CPU so the same program runs under
    the Pallas interpreter in tier-1 tests.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gossip.kernel import gossip_gather_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def gather_terms_pallas(
    nbrs: jax.Array,                                  # [m, k] padded table
    terms: Sequence[Tuple[jax.Array, jax.Array]],     # ([m, k] w, [m, ...] x)
    *,
    pad: Optional[jax.Array] = None,                  # [m, k] padding slots
    block_n: Optional[int] = None,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, ...]:
    """Fused-kernel impl of `gather_terms`: out_t[i] = Σ_slot
    w_t[i, slot] · x_t[nbrs[i, slot]], matching slots/segsum to fp
    tolerance (the MXU contraction reduces in a different order)."""
    if interpret is None:
        interpret = _on_cpu()
    kw = {}
    if block_n is not None:
        kw["block_n"] = block_n
    if block_m is not None:
        kw["block_m"] = block_m
    terms = [(w, jnp.asarray(x)) for w, x in terms]
    m = nbrs.shape[0]

    # Mask padding weights once per distinct table (object identity —
    # tracers of one array are one object under jit).
    masked: dict = {}

    def mask_w(w: jax.Array) -> jax.Array:
        if id(w) not in masked:
            wf = jnp.asarray(w).astype(jnp.float32)
            masked[id(w)] = jnp.where(pad, 0.0, wf) if pad is not None else wf
        return masked[id(w)]

    # Bucket terms by flattened trailing size; dedupe weights per bucket.
    buckets: dict = {}  # n_flat -> (ws, w_index_by_id, entries)
    for t, (w, x) in enumerate(terms):
        n_flat = math.prod(x.shape[1:]) if x.ndim > 1 else 1
        ws, by_id, entries = buckets.setdefault(n_flat, ([], {}, []))
        if id(w) not in by_id:
            by_id[id(w)] = len(ws)
            ws.append(mask_w(w))
        entries.append((t, by_id[id(w)], x))

    outs: list = [None] * len(terms)
    for n_flat, (ws, _, entries) in buckets.items():
        xs = [x.reshape(m, n_flat) for _, _, x in entries]
        groups = tuple(g for _, g, _ in entries)
        res = gossip_gather_pallas(
            nbrs, tuple(ws), tuple(xs), groups, interpret=interpret, **kw
        )
        for (t, _, x), out in zip(entries, res):
            outs[t] = out.reshape(x.shape)
    return tuple(outs)
