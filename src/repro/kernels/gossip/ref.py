"""Dense scatter-matrix reference for the fused gossip kernel.

Materializes exactly what the kernel builds on-chip — the dense
receiver-by-sender matrix S[i, j] = Σ_{slot: nbrs[i,slot]=j} w[i, slot]
— then contracts it with one matmul per term.  O(m²) memory, so it is a
test oracle, not a production path; it shares the kernel's reduction
order (matmul over senders), making it the tight-tolerance comparison
point for the Pallas output in the conformance suite.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def gather_terms_ref(
    nbrs: jax.Array,                                  # [m, k] padded table
    terms: Sequence[Tuple[jax.Array, jax.Array]],     # ([m, k] w, [m, ...] x)
    *,
    pad: Optional[jax.Array] = None,
) -> Tuple[jax.Array, ...]:
    m, _ = nbrs.shape
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    outs = []
    for w, x in terms:
        wf = jnp.asarray(w).astype(jnp.float32)
        if pad is not None:
            wf = jnp.where(pad, 0.0, wf)
        s = jnp.zeros((m, m), jnp.float32).at[rows, nbrs].add(wf)
        x2 = jnp.asarray(x).reshape(m, -1).astype(jnp.float32)
        outs.append(jnp.dot(s, x2).reshape(x.shape).astype(x.dtype))
    return tuple(outs)
