"""Fused gossip neighbor contraction — Pallas TPU kernel.

Executes the padded-table neighbor contraction shared by every gossip
path in the repo (`repro.core.mixing.gather_terms`) in ONE kernel:

    out_t[i, l] = sum_slot w_t[i, slot] · x_t[nbrs[i, slot], l]

for T terms riding the same [m, k] neighbor table.  Per (receiver,
coordinate) tile of shape [BM, BN] the kernel

  1. scatters each distinct weight table into a dense receiver-row
     slice on-chip:  S[i, j] = Σ_{slot: nbrs[i,slot]=j} w[i, slot]
     (k one-hot compare + multiply-add passes on the VPU — the
     "scatter" of gather→contract→scatter, materialized only in VMEM),
  2. contracts it against the resident sender tile with one MXU matmul
     per term:  out[i, :] = S[i, :] @ x[:, tile].

The grid covers the receiver node axis (tiles of BM, like the
`pme_average` kernel) and the coordinate axis (tiles of BN): W/nbrs
stream along the receiver axis, x tiles stream along the coordinate
axis with the full sender axis resident for the contraction.  Terms
that share a weight table (PME's payload + coordinate-count walk) share
one S build — the neighbor table is traversed once however many
aggregates ride it.

Compared with the "slots" chain (k serialized gather+fma passes over
the [m, n] operand) and the "segsum" edge list (two gathers plus a
scatter-add of an [m·k, n] intermediate through HBM), the fused form
reads x once and writes out once per tile — O((k·m·BM + m·n) · T) VMEM
traffic, 1 HBM read + 1 HBM write of the [m, n] operands — and keeps
the contraction on the MXU.

Dead-slot masking happens in the wrapper (`repro.kernels.gossip.ops`):
structural padding slots get weight exactly 0.0 before entering the
kernel, so poisoned padding weights can never leak into a receiver row.
Lane batching (`bind_batched`) rides `jax.vmap`'s pallas batching rule,
which prepends a lane grid dimension to the same program.

Interpret mode (`interpret=True`, the CPU default via the ops wrapper)
runs the identical program through the Pallas interpreter so the kernel
is exercised bitwise-deterministically in tier-1 CPU tests; there the
one-hot build + matmul lower to plain XLA ops, which also makes it the
fastest CPU form at high degree (the slot chain is O(k) serialized
passes, this is one gemm).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_M = 128

# Sender axis padded to a multiple of this for the MXU contraction.
_SENDER_ALIGN = 8


def _kernel(*refs, k: int, term_groups: Tuple[int, ...], n_groups: int):
    """refs = nbrs, w_0..w_{G-1}, x_0..x_{T-1}, out_0..out_{T-1}."""
    n_terms = len(term_groups)
    nbrs_ref = refs[0]
    w_refs = refs[1:1 + n_groups]
    x_refs = refs[1 + n_groups:1 + n_groups + n_terms]
    out_refs = refs[1 + n_groups + n_terms:]

    nbrs = nbrs_ref[...]                       # [BM, k] sender ids
    bm = nbrs.shape[0]
    m = x_refs[0].shape[0]                     # full (padded) sender axis
    # receiver-major one-hot scatter: S[i, j] = sum of this row's slot
    # weights landing on sender j.  f32 compute throughout — counts and
    # Metropolis weights are exact, and the MXU converts fuse.
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, m), 1)
    smats = []
    for g in range(n_groups):
        w = w_refs[g][...].astype(jnp.float32)  # [BM, k]
        s = jnp.zeros((bm, m), jnp.float32)
        for slot in range(k):
            hit = (nbrs[:, slot][:, None] == iota).astype(jnp.float32)
            s = s + w[:, slot][:, None] * hit
        smats.append(s)
    for t, g in enumerate(term_groups):
        x = x_refs[t][...].astype(jnp.float32)  # [m, BN]
        out = jnp.dot(smats[g], x, preferred_element_type=jnp.float32)
        out_refs[t][...] = out.astype(out_refs[t].dtype)


@functools.partial(
    jax.jit,
    static_argnames=("term_groups", "block_n", "block_m", "interpret"),
)
def gossip_gather_pallas(
    nbrs: jax.Array,            # [m, k] int32 padded neighbor table
    ws: Sequence[jax.Array],    # G distinct weight tables, each [m, k]
    xs: Sequence[jax.Array],    # T sender stacks, each [m, n]
    term_groups: Tuple[int, ...],  # term t contracts ws[term_groups[t]]
    block_n: int = DEFAULT_BLOCK_N,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """One fused gather→contract→scatter over the padded neighbor table.

    Returns one [m, n] aggregate per term.  All xs must share [m, n]
    (the ops wrapper groups calls by trailing size); weight tables are
    deduplicated by the caller so shared-weight terms build S once.
    """
    m, k = nbrs.shape
    n = xs[0].shape[1]
    bn = min(block_n, n)
    bm = min(block_m, m)
    pad_n = (-n) % bn
    pad_m = (-m) % bm                       # receiver-axis padding
    pad_s = (-m) % _SENDER_ALIGN            # sender-axis (MXU) padding
    nbrs = nbrs.astype(jnp.int32)
    ws = [w.astype(jnp.float32) for w in ws]
    if pad_m:
        # padded receiver rows: slot ids 0 with weight exactly 0.0 — the
        # rows compute harmless zeros and are sliced away below.
        nbrs = jnp.pad(nbrs, ((0, pad_m), (0, 0)))
        ws = [jnp.pad(w, ((0, pad_m), (0, 0))) for w in ws]
    if pad_s or pad_n:
        # padded sender rows are never referenced (nbrs < m keeps their
        # one-hot columns all-zero); padded coordinates are sliced away.
        xs = [jnp.pad(x, ((0, pad_s), (0, pad_n))) for x in xs]
    grid = ((m + pad_m) // bm, (n + pad_n) // bn)
    row_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    outs = pl.pallas_call(
        functools.partial(
            _kernel, k=k, term_groups=term_groups, n_groups=len(ws)
        ),
        grid=grid,
        in_specs=(
            [row_spec]                                              # nbrs
            + [row_spec] * len(ws)                                  # weights
            + [pl.BlockSpec((m + pad_s, bn), lambda i, j: (0, j))]  # senders
            * len(xs)
        ),
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)) for _ in xs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m + pad_m, n + pad_n), x.dtype) for x in xs
        ],
        interpret=interpret,
    )(nbrs, *ws, *xs)
    return tuple(out[:m, :n] for out in outs)
