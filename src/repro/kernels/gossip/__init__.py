"""Fused Pallas gossip kernel: gather→weighted-contract→scatter of the
padded neighbor table in one kernel, registered as impl="pallas" in
`repro.core.mixing.gather_terms`."""
from repro.kernels.gossip.kernel import gossip_gather_pallas
from repro.kernels.gossip.ops import gather_terms_pallas
from repro.kernels.gossip.ref import gather_terms_ref

__all__ = ["gossip_gather_pallas", "gather_terms_pallas", "gather_terms_ref"]
