"""Pure-jnp oracle for the SSD intra-chunk contraction (and a fully naive
sequential recurrence used to cross-check both implementations)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, rep):
    """Same contraction as the kernel, in plain einsums.

    xc [B,Nc,L,H,P], dtc/cum [B,Nc,L,H], bc/cc [B,Nc,L,G,N] ->
    (y [B,Nc,L,H,P], state [B,Nc,H,P,N]).
    """
    l = xc.shape[2]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,Nc,L,L,H]
    causal = jnp.tril(jnp.ones((l, l), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    bh = jnp.repeat(bc, rep, axis=3)
    ch = jnp.repeat(cc, rep, axis=3)
    scores = jnp.einsum("bnlhs,bnmhs->bnlmh", ch, bh)
    w = scores * lmat * dtc[:, :, None, :, :]
    y = jnp.einsum("bnlmh,bnmhp->bnlhp", w.astype(xc.dtype), xc)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    wstate = (decay_to_end * dtc)[..., None] * bh
    state = jnp.einsum("bnlhs,bnlhp->bnhps", wstate.astype(xc.dtype), xc)
    return y, state.astype(jnp.float32)


def ssd_sequential_ref(x, dt, a, b_, c_, rep):
    """Token-by-token recurrence (ground truth for the whole SSD layer).

    x [B,S,H,P], dt [B,S,H], a [H], b_/c_ [B,S,G,N] -> y [B,S,H,P]."""
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    bh = jnp.repeat(b_, rep, axis=2)
    ch = jnp.repeat(c_, rep, axis=2)

    def step(state, t):
        da = jnp.exp(dt[:, t] * a[None])  # [B,H]
        contrib = (dt[:, t][..., None, None] * x[:, t][..., None]) * bh[:, t][:, :, None, :]
        state = state * da[..., None, None] + contrib
        y = jnp.einsum("bhpn,bhn->bhp", state, ch[:, t])
        return state, y

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, state0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3)  # [B,S,H,P]
