"""Public wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_pallas


def ssd_intra_chunk(xc, dtc, cum, bc, cc, rep: int):
    interpret = jax.default_backend() == "cpu"
    return ssd_intra_chunk_pallas(xc, dtc, cum, bc, cc, rep, interpret=interpret)
