"""Mamba2 SSD intra-chunk contraction — Pallas TPU kernel.

Per (batch, chunk, head) the kernel computes, for a chunk of length L:

    scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j   (i >= j)
    y[i]        = sum_j scores[i,j] * x_j                   [L, P]
    state       = sum_j exp(cum_L - cum_j) * dt_j * (x_j (x) B_j)  [P, N]

i.e. two MXU matmuls ([L,N]x[N,L] and [L,L]x[L,P]) plus one for the chunk
state, all on VMEM-resident tiles — L = 128, P = 64, N = 64/128 keeps the
working set ~0.5 MB.  The inter-chunk recurrence (associative scan over
chunks) stays in XLA where the compiler already pipelines it.

Head grid axis maps to the group axis of B/C via h // (H // G).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)     # [L, P]
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)   # [L]
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32) # [L]
    bmat = b_ref[0, 0, :, 0].astype(jnp.float32)  # [L, N]
    cmat = c_ref[0, 0, :, 0].astype(jnp.float32)  # [L, N]
    l = x.shape[0]

    seg = cum[:, None] - cum[None, :]             # [L(i), L(j)]
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    causal = cols <= rows
    lmat = jnp.where(causal, jnp.exp(seg), 0.0)

    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    w = scores * lmat * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)  # [L, P]

    decay_to_end = jnp.exp(cum[-1] - cum) * dt             # [L]
    state = jnp.dot(
        (x * decay_to_end[:, None]).T, bmat, preferred_element_type=jnp.float32
    )                                                       # [P, N]

    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = state.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rep", "interpret"))
def ssd_intra_chunk_pallas(
    xc: jax.Array,    # [B, Nc, L, H, P]
    dtc: jax.Array,   # [B, Nc, L, H]
    cum: jax.Array,   # [B, Nc, L, H]  (within-chunk cumsum of dt*A)
    bc: jax.Array,    # [B, Nc, L, G, N]
    cc: jax.Array,    # [B, Nc, L, G, N]
    rep: int,         # heads per group, H = G * rep
    interpret: bool = False,
):
    b, nc, l, h, p = xc.shape
    n = bc.shape[-1]
    grid = (b, nc, h)
    y, state = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, l, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, l, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec(
                (1, 1, l, 1, n), lambda bi, ci, hi, r=rep: (bi, ci, 0, hi // r, 0)
            ),
            pl.BlockSpec(
                (1, 1, l, 1, n), lambda bi, ci, hi, r=rep: (bi, ci, 0, hi // r, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, l, h, p), xc.dtype),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, cum, bc, cc)
    return y, state
