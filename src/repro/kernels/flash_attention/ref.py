"""Naive attention oracle (materialised scores, f32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int | None = None
) -> jax.Array:
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * d**-0.5
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if window is not None:
        mask &= (i - j) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)
