"""Public flash-attention wrapper (interpret on CPU, Mosaic on TPU)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    interpret = jax.default_backend() == "cpu"
    bq = min(block_q, q.shape[1])
    bk = min(block_k, q.shape[1])
    return flash_attention_pallas(
        q, k, v, window=window, block_q=bq, block_k=bk, interpret=interpret
    )
