"""Blockwise causal GQA flash attention (opt. sliding window) — Pallas TPU.

Grid (batch, q_head, q_block, kv_block); the kv axis is the innermost
"arbitrary" dimension so the online-softmax state (running max m, running
denominator l, output accumulator) lives in VMEM scratch across kv steps.
Per q block the working set is q[bq,d] + k/v[bk,d] + acc[bq,d] — sized so
bq = bk = 128 with d <= 256 fits comfortably in the ~16 MB v5e VMEM.

GQA is handled in the index map: q head h reads kv head h // (H // KV).
Causal and sliding-window masks are applied with global-position iota; kv
blocks entirely outside the (window, causal) band are skipped via pl.when
on block bounds, so the compute volume matches the mask's true area.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bq, bk,
            seq_len, window):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    k_lo = ki * bk
    # block-level causal/window culling
    causal_ok = k_lo <= q_lo + bq - 1
    window_ok = True
    if window is not None:
        window_ok = (k_lo + bk - 1) >= (q_lo - window + 1)

    @pl.when(causal_ok & window_ok if window is not None else causal_ok)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, S, KV, D]
    v: jax.Array,  # [B, S, KV, D]
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} must be divisible by blocks ({bq},{bk})")
    grid = (b, h, s // bq, s // bk)
    # operands laid out [B, heads, S, D] for clean blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(
        _kernel, scale=d**-0.5, bq=bq, bk=bk, seq_len=s, window=window
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ) if not interpret else None,
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
