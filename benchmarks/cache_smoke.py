"""Cold-vs-warm persistent compilation cache smoke — the CI gate.

    PYTHONPATH=src python -m benchmarks.cache_smoke

Runs a small `bind_batched` grid dispatch in a child process twice
against the same fresh `engine.setup_compilation_cache` directory (set
through the `REPRO_COMPILE_CACHE` env var, so the env path is exercised
too).  The check is deterministic, not a timing assertion: a warm run
that actually skips compilation reads every executable from the cache
and writes NO new entries, so any new `jit_*` file in the cache dir
after the second run means a program was recompiled — that fails the
smoke.  Wall-clock for both runs is printed for the log but not
asserted (CI machines are too noisy for a ratio gate; the ≥30% saving
claim lives in `bench_sweep`'s compile-cache race, measured on a quiet
host).
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


def _workload() -> None:
    """One bind_batched grid dispatch — trace + compile + run."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import linreg_problem
    from repro.core import algorithms as ALG
    from repro.core import build_topology
    from repro.core.engine import setup_compilation_cache

    cache = setup_compilation_cache()  # from REPRO_COMPILE_CACHE
    assert cache, "REPRO_COMPILE_CACHE must be set for the smoke child"
    m, n = 16, 60
    topo = build_topology("ring", m)
    batch, grad_fn, objective = linreg_problem(m, n, spn=16, seed=0)
    ba = ALG.get_algorithm("dpsgd").bind_batched(
        grad_fn, topo,
        [ALG.DPSGDHp(lr=0.1), ALG.DPSGDHp(lr=0.05)], seeds=[0, 1],
    )
    _, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 16,
        objective_fn=objective, tol_std=0.0, chunk_size=8,
    )
    jax.block_until_ready(hist["objective"])


def _entries(cache_dir: str) -> list:
    """Cache executables only — `-atime` stamps are touched on reads."""
    return sorted(
        f for f in os.listdir(cache_dir) if not f.endswith("-atime")
    )


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "child":
        _workload()
        return
    cache_dir = tempfile.mkdtemp(prefix="repro-cache-smoke-")
    env = dict(os.environ)
    env["REPRO_COMPILE_CACHE"] = cache_dir
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )

    def run_child() -> float:
        t0 = time.perf_counter()
        subprocess.run(
            [sys.executable, "-m", "benchmarks.cache_smoke", "child"],
            env=env, cwd=REPO, check=True,
        )
        return time.perf_counter() - t0

    cold_s = run_child()
    cold = _entries(cache_dir)
    warm_s = run_child()
    warm = _entries(cache_dir)
    if not cold:
        sys.exit(
            "cache smoke FAIL: cold run wrote no cache entries — "
            "persistent cache not active"
        )
    new = sorted(set(warm) - set(cold))
    if new:
        sys.exit(
            f"cache smoke FAIL: warm run recompiled {len(new)} program(s) "
            f"(new cache entries: {new[:5]})"
        )
    print(
        f"cache smoke OK: {len(cold)} cached programs; "
        f"cold {cold_s:.2f}s, warm {warm_s:.2f}s "
        f"({(1.0 - warm_s / cold_s) * 100.0:.0f}% saved)"
    )


if __name__ == "__main__":
    main()
