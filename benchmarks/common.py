"""Shared benchmark plumbing: problems, drivers, bit accounting."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core import baselines as B
from repro.core.pme import message_bits
from repro.data.synthetic import make_linear_regression, make_logistic_regression


def linreg_problem(m: int, n: int, spn: int = 128, seed: int = 0):
    """Paper Example 1."""
    a, b, w_star = make_linear_regression(m, spn, n, seed=seed)
    a_j, b_j = jnp.asarray(a), jnp.asarray(b)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - b_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    return (a_j, b_j), grad_fn, objective


def logreg_problem(m: int, n: int, spn: int = 128, seed: int = 0, lam: float = 1e-3):
    """Paper Example 2 (with test split for accuracy)."""
    a, b, w_star = make_logistic_regression(m, spn + 32, n, seed=seed)
    a_tr, b_tr = jnp.asarray(a[:, :spn]), jnp.asarray(b[:, :spn])
    a_te, b_te = jnp.asarray(a[:, spn:]), jnp.asarray(b[:, spn:])

    def grad_fn(w, batch, key):
        aa, yy = batch
        z = aa @ w
        loss = jnp.mean(jnp.logaddexp(0.0, z) - yy * z) + 0.5 * lam * jnp.sum(w**2)
        p = jax.nn.sigmoid(z)
        g = aa.T @ (p - yy) / aa.shape[0] + lam * w
        return loss, g

    def objective(w):
        z = jnp.einsum("mbn,n->mb", a_tr, w)
        return jnp.sum(
            jnp.mean(jnp.logaddexp(0.0, z) - b_tr * z, axis=1)
        ) + 0.5 * lam * m * jnp.sum(w**2)

    def accuracy(w):
        z = jnp.einsum("mbn,n->mb", a_te, w)
        return float(jnp.mean(((z > 0).astype(jnp.float32) == b_te)))

    return (a_tr, b_tr), grad_fn, objective, accuracy


def pame_bits_per_round(
    m: int, mean_t: float, s: int, n: int, value_bits: int = 64
) -> float:
    """Transmitted bits per *communication* round across the network:
    every receiver gets t_i sparse messages of (value_bits-1)s + n bits."""
    return m * mean_t * message_bits(s, n, value_bits)


def chunk_for(steps: int) -> int:
    """A scan-chunk length dividing `steps`, so a timed run reuses the single
    warmed-up executable (no tail-chunk compile in the measured region)."""
    for c in (50, 40, 32, 25, 20):
        if steps % c == 0:
            return c
    return min(32, steps)


def benchmark(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> dict:
    """Wall-clock a callable with explicit warmup/measure phases.

    ``warmup`` calls run first — the first one pays tracing + compilation
    and is timed on its own (``first_call_s``) — then ``iters`` timed
    repetitions, each synchronized with ``jax.block_until_ready``.
    Returns microseconds per call as ``us_min`` (the steady-state figure
    — least scheduler noise), ``us_median`` and ``us_mean``, plus the raw
    phases: ``warmup_s`` (whole warmup phase), ``first_call_s``, and
    ``compile_s`` = first call minus one steady-state call — the
    trace+compile (or persistent-cache read) cost in isolation, the
    column the compilation-cache races compare.
    """
    t0 = time.perf_counter()
    out = None
    first_call_s = 0.0
    for i in range(max(warmup, 0)):
        out = fn(*args)
        if i == 0:
            jax.block_until_ready(out)
            first_call_s = time.perf_counter() - t0
    if out is not None:
        jax.block_until_ready(out)
    warmup_s = time.perf_counter() - t0
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times_us = np.asarray(times) * 1e6
    return {
        "us_min": float(times_us.min()),
        "us_median": float(np.median(times_us)),
        "us_mean": float(times_us.mean()),
        "warmup_s": warmup_s,
        "first_call_s": first_call_s,
        "compile_s": max(first_call_s - float(np.median(times_us)) / 1e6, 0.0),
        "iters": int(len(times_us)),
    }


def mean_std(values) -> Tuple[float, float]:
    """(mean, std) of a per-lane/per-seed metric as plain floats."""
    arr = np.asarray(values, dtype=np.float64)
    return float(arr.mean()), float(arr.std())


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
