"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Output: `name,us_per_call,derived` CSV rows (us_per_call = jitted step
wall time on this CPU host; derived = the figure's headline metric).
Full curves land in benchmarks/artifacts/bench_results.json for
EXPERIMENTS.md.

Figure map:
  bench_transmission_rate  Fig 2a & 3   (s/n sweep, Example 1)
  bench_participation      Fig 2b & 4   (nu sweep, Example 1)
  bench_comm_period        Fig 2c/d,5,6 (kappa homo/hetero, Example 1)
  bench_connectivity       Fig 7        (degree x s/n heatmap)
  bench_vs_baselines       Figs 8-10    (Example 2 vs D-PSGD/DFedSAM/BEER/ANQ-NIDS)
  bench_heterogeneity      Figs 11-12   (label-skew CNN / Dirichlet ResNet-20)
  bench_comm_volume        Eq. (8)      (bit accounting, 64/16/8-bit wires)
  bench_kernels            —            (Pallas kernels, interpret-mode checks)
  bench_engine             —            (host-loop vs scan-driver us_per_call)
  bench_roofline           —            (§Roofline table from the dry-run)
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core import baselines as B
from repro.core import engine
from repro.core.pame import make_pame_runner
from repro.core.compression import qsgd, rand_k
from repro.core.pme import message_bits

from benchmarks.common import (
    chunk_for,
    csv_row,
    linreg_problem,
    logreg_problem,
    pame_bits_per_round,
    timed,
)

HERE = os.path.dirname(os.path.abspath(__file__))
ART = os.path.join(HERE, "artifacts")
os.makedirs(ART, exist_ok=True)

RESULTS: Dict[str, object] = {}


def _pame_run(m, n, cfg, steps, seed=0, problem="linreg", topo_kind="erdos_renyi",
              topo_kwargs=None, spn=128):
    topo = build_topology(topo_kind, m, **(topo_kwargs or dict(p=0.4, seed=seed)))
    if problem == "linreg":
        batch, grad_fn, objective = linreg_problem(m, n, spn=spn, seed=seed)
        acc = None
    else:
        batch, grad_fn, objective, acc = logreg_problem(m, n, spn=spn, seed=seed)
    chunk = chunk_for(steps)
    runner = make_pame_runner(
        grad_fn, topo, cfg, objective_fn=objective, tol_std=1e-3,
        chunk_size=chunk, seed=seed,
    )
    key = jax.random.PRNGKey(seed)
    # warm-up: one chunk compiles the scan executable; the timed run below
    # then measures steady-state algorithm throughput, not tracing.
    runner(key, jnp.zeros(n), m, lambda k: batch, chunk)
    t0 = time.perf_counter()
    state, hist = runner(key, jnp.zeros(n), m, lambda k: batch, steps)
    wall = time.perf_counter() - t0
    mean_w = jax.tree_util.tree_map(lambda x: x.mean(axis=0), state.params)
    out = {
        "objective": hist["objective"],
        "steps_run": hist["steps_run"],
        "final": hist["objective"][-1],
        # per-step wall over the steps actually executed on device (the
        # engine runs to the chunk boundary past an early termination)
        "us_per_call": wall / max(hist["steps_dispatched"], 1) * 1e6,
        "mean_t": float(np.mean(np.maximum(1, np.floor(cfg.nu * topo.degrees)))),
    }
    if acc is not None:
        out["accuracy"] = acc(mean_w)
    return out


# ---------------------------------------------------------------------------
def bench_transmission_rate(quick=False):
    """Fig 2a/3: final objective & convergence vs s/n for m in {16,32,64}."""
    n = 300
    rates = [0.1, 0.2, 0.4, 0.6, 1.0]
    ms = [16, 32] if quick else [16, 32, 64]
    table = {}
    for m in ms:
        for p in rates:
            cfg = PaMEConfig(nu=0.2, p=p, gamma=1.01, sigma0=8.0)
            r = _pame_run(m, n, cfg, steps=300, problem="linreg")
            table[f"m{m}_p{p}"] = r
            csv_row(
                f"transmission_rate/m={m}/s_over_n={p}", r["us_per_call"],
                f"final_obj={r['final']:.4f};rounds={r['steps_run']}",
            )
    # paper claim C4: gains are marginal once s/n exceeds ~0.2
    for m in ms:
        p01 = table[f"m{m}_p0.1"]["final"]
        p02 = table[f"m{m}_p0.2"]["final"]
        hi = table[f"m{m}_p1.0"]["final"]
        csv_row(
            f"transmission_rate/claimC4/m={m}", 0.0,
            f"final_p0.1={p01:.4f};final_p0.2={p02:.4f};final_p1.0={hi:.4f};"
            f"ratio_p0.2={p02/max(hi,1e-9):.3f}",
        )
    RESULTS["transmission_rate"] = table


def bench_participation(quick=False):
    """Fig 2b/4: nu sweep."""
    n = 300
    nus = [0.1, 0.2, 0.4, 0.6]
    ms = [16, 32] if quick else [16, 32, 64]
    table = {}
    for m in ms:
        for nu in nus:
            cfg = PaMEConfig(nu=nu, p=0.2, gamma=1.01, sigma0=8.0)
            r = _pame_run(m, n, cfg, steps=300, problem="linreg")
            table[f"m{m}_nu{nu}"] = r
            csv_row(
                f"participation/m={m}/nu={nu}", r["us_per_call"],
                f"final_obj={r['final']:.4f};rounds={r['steps_run']}",
            )
    RESULTS["participation"] = table


def bench_comm_period(quick=False):
    """Fig 2c/d + 5/6: homogeneous vs heterogeneous kappa."""
    n, m = 300, 32
    table = {}
    for k0 in [1, 2, 4, 8, 16]:
        cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0, homogeneous_kappa=k0)
        r = _pame_run(m, n, cfg, steps=400)
        table[f"homo_k{k0}"] = r
        csv_row(
            f"comm_period/homogeneous/k0={k0}", r["us_per_call"],
            f"final_obj={r['final']:.4f};rounds={r['steps_run']}",
        )
    for lo, hi in [(1, 3), (3, 7), (5, 10), (8, 16)]:
        cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0, kappa_lo=lo, kappa_hi=hi)
        r = _pame_run(m, n, cfg, steps=400)
        table[f"hetero_k{lo}_{hi}"] = r
        csv_row(
            f"comm_period/heterogeneous/k=[{lo},{hi}]", r["us_per_call"],
            f"final_obj={r['final']:.4f};rounds={r['steps_run']}",
        )
    RESULTS["comm_period"] = table


def bench_connectivity(quick=False):
    """Fig 7 heatmap: degree x transmission rate -> (final obj, iters)."""
    n, m = 300, 32
    degrees = [2, 6, 14] if quick else [2, 4, 8, 14, 20]
    rates = [0.1, 0.3, 0.6]
    table = {}
    for d in degrees:
        for p in rates:
            cfg = PaMEConfig(nu=0.4, p=p, gamma=1.01, sigma0=8.0)
            r = _pame_run(
                m, n, cfg, steps=300, topo_kind="regular",
                topo_kwargs=dict(degree=d, seed=0),
            )
            table[f"deg{d}_p{p}"] = r
            csv_row(
                f"connectivity/degree={d}/s_over_n={p}", r["us_per_call"],
                f"final_obj={r['final']:.4f};rounds={r['steps_run']}",
            )
    RESULTS["connectivity"] = table


def bench_vs_baselines(quick=False):
    """Figs 8-10: Example 2 (logistic regression) — objective/accuracy vs
    rounds and total transmitted volume, PaME vs the four baselines."""
    m, n = 32, 1000
    steps = 150 if quick else 300
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    bmat = jnp.asarray(topo.mixing)
    batch, grad_fn, objective, accuracy = logreg_problem(m, n, spn=128, seed=0)
    w0 = B.stack_params(jnp.zeros(n), m)
    key = jax.random.PRNGKey(0)
    mean_deg = float(topo.degrees.mean())
    table = {}

    # --- PaME ---
    cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.002, sigma0=1.0, kappa_lo=3, kappa_hi=7)
    r = _pame_run(m, n, cfg, steps=steps, problem="logreg")
    s = int(round(0.2 * n))
    comm_rounds = r["steps_run"] / 5.0  # mean kappa = 5
    bits = comm_rounds * pame_bits_per_round(m, r["mean_t"], s, n)
    table["pame"] = {**r, "bits": bits, "comm_rounds": comm_rounds}
    csv_row(
        "vs_baselines/pame", r["us_per_call"],
        f"acc={r['accuracy']:.4f};final_obj={r['final']:.4f}"
        f";comm_rounds={comm_rounds:.0f};gbits={bits/1e9:.3f}",
    )

    def run_baseline(init_state, step_closure, bits_per_round, params_of=lambda s_: s_.params):
        # same methodology as _pame_run: warm the scan executable on a
        # throwaway chunk (the engine copies init_state before donating, so
        # the real run below starts from the same state), then time
        # steady-state throughput.
        chunk = chunk_for(steps)
        runner = engine.make_scan_runner(
            step_closure, objective_fn=objective, params_of=params_of,
            tol_std=1e-3, chunk_size=chunk,
        )
        runner(init_state, lambda k: batch, chunk)
        t0 = time.perf_counter()
        st_, metrics, info = runner(init_state, lambda k: batch, steps)
        wall = time.perf_counter() - t0
        n_run = info["steps_run"]
        mean_w = jax.tree_util.tree_map(lambda x: x.mean(axis=0), params_of(st_))
        return {
            "steps_run": n_run,
            "final": float(metrics["objective"][-1]),
            "accuracy": accuracy(mean_w),
            "us_per_call": wall / max(info["steps_dispatched"], 1) * 1e6,
            "bits": n_run * bits_per_round,
        }

    full_bits = m * mean_deg * message_bits(n, n)  # dense vectors to all nbrs
    table["dpsgd"] = run_baseline(
        B.dpsgd_init(key, w0),
        lambda s_, b_: B.dpsgd_step(s_, b_, grad_fn, bmat, 0.1), full_bits)
    table["dfedsam"] = run_baseline(
        B.dfedsam_init(key, w0),
        lambda s_, b_: B.dfedsam_step(s_, b_, grad_fn, bmat, 0.1, rho=0.01), full_bits)
    comp = rand_k(0.2, rescale=False)
    table["beer"] = run_baseline(
        B.beer_init(key, w0, batch, grad_fn),
        lambda s_, b_: B.beer_step(s_, b_, grad_fn, bmat, 0.05, comp, 0.4),
        m * mean_deg * 2 * comp.bits(n))
    q = qsgd(16)
    table["anq_nids"] = run_baseline(
        B.nids_init(key, w0, batch, grad_fn, 0.1),
        lambda s_, b_: B.nids_step(s_, b_, grad_fn, bmat, 0.1, q),
        m * mean_deg * q.bits(n))

    for name in ("dpsgd", "dfedsam", "beer", "anq_nids"):
        rr = table[name]
        csv_row(
            f"vs_baselines/{name}", rr["us_per_call"],
            f"acc={rr['accuracy']:.4f};final_obj={rr['final']:.4f}"
            f";rounds={rr['steps_run']};gbits={rr['bits']/1e9:.3f}",
        )
    red = 1.0 - table["pame"]["bits"] / table["dpsgd"]["bits"]
    csv_row("vs_baselines/claimC7_volume_reduction_vs_dpsgd", 0.0, f"reduction={red:.2%}")
    RESULTS["vs_baselines"] = table


def bench_heterogeneity(quick=False):
    """Fig 11 (label skew, CNN) + Fig 12 (Dirichlet, ResNet-20), synthetic
    stand-in images (offline container; heterogeneity mechanism exact)."""
    from repro.data import (
        NodeBatcher,
        SyntheticClassification,
        dirichlet_partition,
        iid_partition,
        label_skew_partition,
    )
    from repro.models.cnn import ce_loss, cnn_apply, cnn_init, resnet20_apply, resnet20_init

    table = {}
    m = 4
    steps = 40 if quick else 100

    def run_fl(ds, parts, init_fn, apply_fn, steps, sigma0=10.0):
        nb = NodeBatcher({"x": ds.images, "y": ds.labels}, parts, batch_size=32, seed=0)
        topo = build_topology("complete", m)
        cfg = PaMEConfig(nu=0.7, p=0.3, gamma=1.002, sigma0=sigma0, kappa_lo=2, kappa_hi=4)

        def grad_fn(params, batch, key):
            return jax.value_and_grad(
                lambda p: ce_loss(apply_fn(p, batch["x"]), batch["y"])
            )(params)

        def batch_fn(k):
            b = nb.next()
            return {"x": jnp.asarray(b["x"], jnp.float32), "y": jnp.asarray(b["y"], jnp.int32)}

        t0 = time.perf_counter()
        state, hist = run_pame(
            jax.random.PRNGKey(0), init_fn(jax.random.PRNGKey(1)), m,
            grad_fn, batch_fn, topo, cfg, num_steps=steps, tol_std=0.0,
        )
        wall = time.perf_counter() - t0
        mean_params = jax.tree_util.tree_map(lambda x: x.mean(axis=0), state.params)
        logits = apply_fn(mean_params, jnp.asarray(ds.images[:512], jnp.float32))
        acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ds.labels[:512])))
        return {
            "loss": hist["loss"],
            "final_loss": hist["loss"][-1],
            "accuracy": acc,
            "us_per_call": wall / steps * 1e6,
        }

    # Fig 11: label skew C in {1, 7, 10} on the CNN
    ds = SyntheticClassification.make(1024, (28, 28, 1), 10, seed=0, sep=3.0)
    for c in (1, 7, 10):
        parts = label_skew_partition(ds.labels, m, c, seed=0)
        r = run_fl(ds, parts, lambda k: cnn_init(k), cnn_apply, steps)
        table[f"cnn_labelskew_C{c}"] = r
        csv_row(
            f"heterogeneity/cnn/C={c}", r["us_per_call"],
            f"acc={r['accuracy']:.3f};final_loss={r['final_loss']:.3f}",
        )

    # Fig 12: Dirichlet beta in {0.3, 0.6} + iid on ResNet-20 (short run)
    ds2 = SyntheticClassification.make(512, (32, 32, 3), 10, seed=1, sep=2.0)
    rn_steps = 10 if quick else 40
    for beta in (0.3, 0.6, None):
        if beta is None:
            parts = iid_partition(ds2.labels, m, seed=0)
            tag = "iid"
        else:
            parts = dirichlet_partition(ds2.labels, m, beta, seed=0)
            tag = f"beta{beta}"
        r = run_fl(
            ds2, parts, lambda k: resnet20_init(k), resnet20_apply, rn_steps, sigma0=10.0
        )
        table[f"resnet20_{tag}"] = r
        csv_row(
            f"heterogeneity/resnet20/{tag}", r["us_per_call"],
            f"acc={r['accuracy']:.3f};final_loss={r['final_loss']:.3f}",
        )
    RESULTS["heterogeneity"] = table


def bench_engine(quick=False):
    """Host-loop vs scan-driver step cost on the Fig 2a workload (m=32,
    n=300 linreg).  Three rows: the pre-engine host loop (one dispatch +
    three float() syncs per step), a cold scan run (compile included), and
    the warmed scan runner (steady state — what the other benches report)."""
    m, n = 32, 300
    steps = 100 if quick else 200
    cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0)
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective = linreg_problem(m, n, spn=128, seed=0)
    key = jax.random.PRNGKey(0)
    table = {}

    t0 = time.perf_counter()
    _, hist = run_pame(
        key, jnp.zeros(n), m, grad_fn, lambda k: batch, topo, cfg,
        num_steps=steps, objective_fn=objective, tol_std=0.0, driver="host",
    )
    table["host_loop"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    t0 = time.perf_counter()
    _, hist = run_pame(
        key, jnp.zeros(n), m, grad_fn, lambda k: batch, topo, cfg,
        num_steps=steps, objective_fn=objective, tol_std=0.0, driver="scan",
        chunk_size=chunk_for(steps),
    )
    table["scan_cold"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    chunk = chunk_for(steps)
    runner = make_pame_runner(
        grad_fn, topo, cfg, objective_fn=objective, tol_std=0.0,
        chunk_size=chunk, seed=0,
    )
    runner(key, jnp.zeros(n), m, lambda k: batch, chunk)  # compile
    t0 = time.perf_counter()
    _, hist = runner(key, jnp.zeros(n), m, lambda k: batch, steps)
    table["scan_steady"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    for name, us in table.items():
        csv_row(f"engine/{name}", us, f"steps={steps}")
    csv_row(
        "engine/speedup", 0.0,
        f"host_over_steady={table['host_loop']/max(table['scan_steady'],1e-9):.1f}x;"
        f"host_over_cold={table['host_loop']/max(table['scan_cold'],1e-9):.1f}x",
    )
    RESULTS["engine"] = table


def bench_comm_volume(quick=False):
    """Eq. (8): bits per message, sparse vs dense; 64-/16-bit float payloads
    plus the int8 wire of exchange="compressed_q8"."""
    table = {}
    for n in (10_000, 100_000, 1_000_000):
        for frac in (0.01, 0.1, 0.2):
            s = int(frac * n)
            for vb in (64, 16, 8):
                sparse = message_bits(s, n, vb)
                dense = vb * n
                table[f"n{n}_s{s}_b{vb}"] = {"sparse": sparse, "dense": dense}
                csv_row(
                    f"comm_volume/n={n}/s={s}/bits={vb}", 0.0,
                    f"sparse_bits={sparse};dense_bits={dense};saving={1-sparse/dense:.2%}",
                )
    RESULTS["comm_volume"] = table


def bench_kernels(quick=False):
    """Pallas kernels in interpret mode (correctness-path timing only —
    real-TPU wall times are not measurable on this CPU host)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.pme_average.ops import pme_average
    from repro.kernels.pme_average.ref import pme_average_ref
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk
    from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref

    rng = np.random.default_rng(0)
    table = {}

    m, n = 16, 4096
    w = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    masks = jnp.asarray(rng.random((m, n)) < 0.2)
    a = jnp.asarray(((rng.random((m, m)) < 0.4) & ~np.eye(m, dtype=bool)), jnp.float32)
    us_k = timed(lambda: pme_average(w, masks, a))
    us_r = timed(jax.jit(lambda: pme_average_ref(w, masks.astype(w.dtype), a)))
    err = float(jnp.max(jnp.abs(pme_average(w, masks, a) - pme_average_ref(w, masks.astype(w.dtype), a))))
    table["pme_average"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/pme_average", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")

    b, s, h, kv, d = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    us_k = timed(lambda: flash_attention(q, k, v, block_q=64, block_k=64), repeats=1)
    us_r = timed(jax.jit(lambda: attention_ref(q, k, v)))
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, block_q=64, block_k=64) - attention_ref(q, k, v))))
    table["flash_attention"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/flash_attention", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")

    B_, Nc, L, H, P, G, N = 1, 4, 32, 4, 16, 2, 16
    xc = jnp.asarray(rng.standard_normal((B_, Nc, L, H, P)), jnp.float32)
    dtc = jnp.asarray(rng.random((B_, Nc, L, H)) * 0.2 + 0.01, jnp.float32)
    av = jnp.asarray(-np.exp(rng.standard_normal(H) * 0.2), jnp.float32)
    cum = jnp.cumsum(dtc * av[None, None, None], axis=2)
    bc = jnp.asarray(rng.standard_normal((B_, Nc, L, G, N)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((B_, Nc, L, G, N)), jnp.float32)
    us_k = timed(lambda: ssd_intra_chunk(xc, dtc, cum, bc, cc, H // G), repeats=1)
    us_r = timed(jax.jit(lambda: ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, H // G)))
    yk, _ = ssd_intra_chunk(xc, dtc, cum, bc, cc, H // G)
    yr, _ = ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, H // G)
    err = float(jnp.max(jnp.abs(yk - yr)))
    table["ssd_scan"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/ssd_scan", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")
    RESULTS["kernels"] = table


def bench_roofline(quick=False):
    """§Roofline table (single-pod baselines for all 40 pairs)."""
    from benchmarks import roofline

    try:
        rows = roofline.build_table()
    except FileNotFoundError:
        csv_row("roofline", 0.0, "SKIPPED=no dryrun.json; run repro.launch.dryrun first")
        return
    print(roofline.format_table(rows))
    for r in rows:
        csv_row(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"compute_s={r['t_compute_s']:.4g};memory_s={r['t_memory_s']:.4g};"
            f"collective_s={r['t_collective_s']:.4g};dominant={r['dominant']};"
            f"useful={r['useful_ratio']:.2f}",
        )
    RESULTS["roofline"] = rows


BENCHES = {
    "transmission_rate": bench_transmission_rate,
    "participation": bench_participation,
    "comm_period": bench_comm_period,
    "connectivity": bench_connectivity,
    "vs_baselines": bench_vs_baselines,
    "heterogeneity": bench_heterogeneity,
    "comm_volume": bench_comm_volume,
    "kernels": bench_kernels,
    "engine": bench_engine,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--quick", action="store_true")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    names = [args.only] if args.only else list(BENCHES)
    for name in names:
        t0 = time.perf_counter()
        BENCHES[name](quick=args.quick)
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", flush=True)
    with open(os.path.join(ART, "bench_results.json"), "w") as f:
        json.dump(RESULTS, f, indent=1, default=float)
    print(f"# wrote {os.path.join(ART, 'bench_results.json')}")


if __name__ == "__main__":
    main()
