"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Output: `name,us_per_call,derived` CSV rows (us_per_call = jitted step
wall time on this CPU host; derived = the figure's headline metric).
Full curves land in benchmarks/artifacts/bench_results.json for
EXPERIMENTS.md.

Figure map:
  bench_transmission_rate  Fig 2a & 3   (s/n sweep, Example 1; seeds batched)
  bench_participation      Fig 2b & 4   (nu sweep, Example 1; one batched
                                         nu x seed grid per m)
  bench_comm_period        Fig 2c/d,5,6 (kappa homo/hetero, Example 1; one
                                         batched kappa x seed grid each)
  bench_connectivity       Fig 7        (degree x s/n heatmap)
  bench_vs_baselines       Figs 8-10    (Example 2, registry race: PaME vs
                                         D-PSGD/DFedSAM/CHOCO/BEER/ANQ-NIDS,
                                         mean ± std over batched seed lanes)
  bench_faults             —            (graceful degradation: accuracy &
                                         realized gbits vs message-loss rate,
                                         replicated surrogates + repair traffic)
  bench_mixing             —            (dense einsum vs sparse neighbor gossip)
  bench_sweep              —            (batched lane engine vs per-cell loop;
                                         slots vs segment-sum gossip core;
                                         cold-vs-warm persistent compile cache;
                                         emits BENCH_sweep.json)
  bench_gossip             —            (slots vs segsum vs fused Pallas kernel
                                         across m × degree × n with bytes-moved
                                         roofline terms; emits BENCH_gossip.json)
  bench_scenarios          —            (dynamic networks: churn x topology race
                                         with realized per-step wire bits)
  bench_chaos              —            (network split + heal: post-heal
                                         consensus recovery, PaME vs surrogate-
                                         memory baselines; emits BENCH_chaos.json)
  bench_heterogeneity      Figs 11-12   (label-skew CNN / Dirichlet ResNet-20)
  bench_comm_volume        Eq. (8)      (bit accounting, 64/16/8-bit wires)
  bench_kernels            —            (Pallas kernels, interpret-mode checks)
  bench_engine             —            (host-loop vs scan-driver us_per_call)
  bench_roofline           —            (§Roofline table from the dry-run)
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core.algorithms import lane_finals
from repro.core.pame import make_pame_runner
from repro.core.pme import message_bits

from benchmarks.common import (
    benchmark,
    chunk_for,
    csv_row,
    linreg_problem,
    logreg_problem,
    mean_std,
)

HERE = os.path.dirname(os.path.abspath(__file__))
ART = os.path.join(HERE, "artifacts")
os.makedirs(ART, exist_ok=True)

RESULTS: Dict[str, object] = {}


SWEEP_SEEDS = 5  # >= 5 seeds behind every mean ± std table entry


def _pame_grid(m, n, cfgs, steps, seeds=None, topo_kind="erdos_renyi",
               topo_kwargs=None, spn=128, tol_std=1e-3):
    """Run a C-config × S-seed PaME grid as ONE batched scan (one compile).

    Configs may differ in any field `bind_batched` can thread (nu, gamma,
    sigma0, kappa_* — not p, which fixes the payload shape).  The problem
    instance and topology are fixed; lanes vary the algorithm's PRNG
    stream.  Returns per-config rows with mean ± std over the seed lanes.
    """
    from repro.core import algorithms as ALG

    seeds = list(range(SWEEP_SEEDS)) if seeds is None else list(seeds)
    topo = build_topology(topo_kind, m, **(topo_kwargs or dict(p=0.4, seed=0)))
    batch, grad_fn, objective = linreg_problem(m, n, spn=spn, seed=0)
    chunk = chunk_for(steps)
    ba = ALG.get_algorithm("pame").bind_batched(
        grad_fn, topo, cfgs, seeds=seeds
    )
    runner = ba.make_runner(
        objective_fn=objective, tol_std=tol_std, chunk_size=chunk
    )
    # warm-up: ONE compile covers the whole grid
    runner(jnp.zeros(n), m, lambda k: batch, chunk)
    t0 = time.perf_counter()
    state, hist = runner(jnp.zeros(n), m, lambda k: batch, steps)
    wall = time.perf_counter() - t0
    finals = lane_finals(hist)
    lane_steps = wall / max(int(hist["steps_dispatched"]) * ba.lanes, 1)
    rows = []
    for c, cfg in enumerate(cfgs):
        mask = hist["lane_config"] == c
        fm, fs = mean_std(finals[mask])
        rm, _ = mean_std(hist["steps_run"][mask])
        rows.append({
            "final_mean": fm, "final_std": fs, "rounds_mean": rm,
            "seeds": len(seeds), "us_per_lane_step": lane_steps * 1e6,
            "mean_t": float(np.mean(np.maximum(1, np.floor(cfg.nu * topo.degrees)))),
        })
    return rows


# ---------------------------------------------------------------------------
def bench_transmission_rate(quick=False):
    """Fig 2a/3: final objective & convergence vs s/n for m in {16,32,64}.

    p fixes the message payload shape (trace-static), so each (m, p) cell
    compiles once and its SWEEP_SEEDS seed replicas run as lanes of that
    one program."""
    n = 300
    rates = [0.1, 0.2, 0.4, 0.6, 1.0]
    ms = [16, 32] if quick else [16, 32, 64]
    table = {}
    for m in ms:
        for p in rates:
            cfg = PaMEConfig(nu=0.2, p=p, gamma=1.01, sigma0=8.0)
            (r,) = _pame_grid(m, n, [cfg], steps=300)
            table[f"m{m}_p{p}"] = r
            csv_row(
                f"transmission_rate/m={m}/s_over_n={p}", r["us_per_lane_step"],
                f"final_obj={r['final_mean']:.4f}±{r['final_std']:.4f}"
                f";rounds={r['rounds_mean']:.0f};seeds={r['seeds']}",
            )
    # paper claim C4: gains are marginal once s/n exceeds ~0.2
    for m in ms:
        p01 = table[f"m{m}_p0.1"]["final_mean"]
        p02 = table[f"m{m}_p0.2"]["final_mean"]
        hi = table[f"m{m}_p1.0"]["final_mean"]
        csv_row(
            f"transmission_rate/claimC4/m={m}", 0.0,
            f"final_p0.1={p01:.4f};final_p0.2={p02:.4f};final_p1.0={hi:.4f};"
            f"ratio_p0.2={p02/max(hi,1e-9):.3f}",
        )
    RESULTS["transmission_rate"] = table


def bench_participation(quick=False):
    """Fig 2b/4: nu sweep — per m, the whole nu × seed grid is ONE batched
    scan (nu reaches the trace through the stacked TopologyArrays, so the
    4 configs share a single compiled program)."""
    n = 300
    nus = [0.1, 0.2, 0.4, 0.6]
    ms = [16, 32] if quick else [16, 32, 64]
    table = {}
    for m in ms:
        cfgs = [PaMEConfig(nu=nu, p=0.2, gamma=1.01, sigma0=8.0) for nu in nus]
        rows = _pame_grid(m, n, cfgs, steps=300)
        for nu, r in zip(nus, rows):
            table[f"m{m}_nu{nu}"] = r
            csv_row(
                f"participation/m={m}/nu={nu}", r["us_per_lane_step"],
                f"final_obj={r['final_mean']:.4f}±{r['final_std']:.4f}"
                f";rounds={r['rounds_mean']:.0f};seeds={r['seeds']}",
            )
    RESULTS["participation"] = table


def bench_comm_period(quick=False):
    """Fig 2c/d + 5/6: homogeneous vs heterogeneous kappa.  Each family's
    kappa × seed grid is ONE batched scan — the per-node periods live in
    the stacked TopologyArrays, not the traced program."""
    n, m = 300, 32
    table = {}
    homo_ks = [1, 2, 4, 8, 16]
    cfgs = [
        PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0, homogeneous_kappa=k0)
        for k0 in homo_ks
    ]
    for k0, r in zip(homo_ks, _pame_grid(m, n, cfgs, steps=400)):
        table[f"homo_k{k0}"] = r
        csv_row(
            f"comm_period/homogeneous/k0={k0}", r["us_per_lane_step"],
            f"final_obj={r['final_mean']:.4f}±{r['final_std']:.4f}"
            f";rounds={r['rounds_mean']:.0f};seeds={r['seeds']}",
        )
    hetero = [(1, 3), (3, 7), (5, 10), (8, 16)]
    cfgs = [
        PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0, kappa_lo=lo, kappa_hi=hi)
        for lo, hi in hetero
    ]
    for (lo, hi), r in zip(hetero, _pame_grid(m, n, cfgs, steps=400)):
        table[f"hetero_k{lo}_{hi}"] = r
        csv_row(
            f"comm_period/heterogeneous/k=[{lo},{hi}]", r["us_per_lane_step"],
            f"final_obj={r['final_mean']:.4f}±{r['final_std']:.4f}"
            f";rounds={r['rounds_mean']:.0f};seeds={r['seeds']}",
        )
    RESULTS["comm_period"] = table


def bench_connectivity(quick=False):
    """Fig 7 heatmap: degree x transmission rate -> (final obj, iters).

    Each (degree, rate) cell's SWEEP_SEEDS seed replicas run as lanes of
    ONE batched scan (`_pame_grid` -> `bind_batched`) — the seed axis left
    the per-cell Python loop, so every table entry is a mean ± std."""
    n, m = 300, 32
    degrees = [2, 6, 14] if quick else [2, 4, 8, 14, 20]
    rates = [0.1, 0.3, 0.6]
    table = {}
    for d in degrees:
        for p in rates:
            cfg = PaMEConfig(nu=0.4, p=p, gamma=1.01, sigma0=8.0)
            (r,) = _pame_grid(
                m, n, [cfg], steps=300, topo_kind="regular",
                topo_kwargs=dict(degree=d, seed=0),
            )
            table[f"deg{d}_p{p}"] = r
            csv_row(
                f"connectivity/degree={d}/s_over_n={p}",
                r["us_per_lane_step"],
                f"final_obj={r['final_mean']:.4f}±{r['final_std']:.4f}"
                f";rounds={r['rounds_mean']:.0f};seeds={r['seeds']}",
            )
    RESULTS["connectivity"] = table


def bench_vs_baselines(quick=False):
    """Figs 8-10: Example 2 (logistic regression) — objective/accuracy vs
    rounds and total transmitted volume, PaME vs all five baselines, as a
    data-driven loop over the unified algorithm registry.  Each algorithm's
    SWEEP_SEEDS seed replicas run as lanes of one batched scan (one compile
    per algorithm, mean ± std columns), emitted into EXPERIMENTS.md."""
    from repro.core import algorithms as ALG

    m, n = 32, 1000
    steps = 150 if quick else 300
    seeds = list(range(SWEEP_SEEDS))
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective, accuracy = logreg_problem(m, n, spn=128, seed=0)
    chunk = chunk_for(steps)
    race_hps = {
        "pame": PaMEConfig(nu=0.2, p=0.2, gamma=1.002, sigma0=1.0,
                           kappa_lo=3, kappa_hi=7),
        "dpsgd": ALG.DPSGDHp(lr=0.1),
        "dfedsam": ALG.DFedSAMHp(lr=0.1, rho=0.01),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        "beer": ALG.BeerHp(lr=0.05, gossip_gamma=0.4, comp_frac=0.2),
        "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=16),
    }
    table = {}
    md_rows = []
    for name in ALG.list_algorithms():
        # algorithms registered beyond the built-in six race on their
        # default hyperparameters
        ba = ALG.get_algorithm(name).bind_batched(
            grad_fn, topo, [race_hps.get(name)], seeds=seeds, mixing="sparse"
        )
        runner = ba.make_runner(
            objective_fn=objective, tol_std=1e-3, chunk_size=chunk
        )
        # warm-up: one chunk compiles the scan executable for ALL lanes
        runner(jnp.zeros(n), m, lambda k: batch, chunk)
        t0 = time.perf_counter()
        state, hist = runner(jnp.zeros(n), m, lambda k: batch, steps)
        wall = time.perf_counter() - t0
        # per-lane accuracy of the node-mean parameters
        mean_w = np.asarray(
            jax.tree_util.tree_map(
                lambda x: x.mean(axis=1), ba.params_of(state)
            )
        )
        accs = [accuracy(jnp.asarray(mean_w[l])) for l in range(ba.lanes)]
        fm, fs = mean_std(lane_finals(hist))
        am, a_s = mean_std(accs)
        bm, bs = mean_std(hist["wire_bits_total"])
        rm, _ = mean_std(hist["steps_run"])
        table[name] = {
            "steps_run": rm,
            "final": fm, "final_std": fs,
            "accuracy": am, "accuracy_std": a_s,
            "us_per_call": wall / max(
                int(hist["steps_dispatched"]) * ba.lanes, 1) * 1e6,
            "bits": bm, "bits_std": bs, "seeds": len(seeds),
        }
        rr = table[name]
        csv_row(
            f"vs_baselines/{name}", rr["us_per_call"],
            f"acc={rr['accuracy']:.4f}±{rr['accuracy_std']:.4f}"
            f";final_obj={rr['final']:.4f}±{rr['final_std']:.4f}"
            f";rounds={rr['steps_run']:.0f};gbits={rr['bits']/1e9:.3f}"
            f";seeds={rr['seeds']}",
        )
        md_rows.append((
            name, f"{rr['final']:.4f} ± {rr['final_std']:.4f}",
            f"{rr['accuracy']:.4f} ± {rr['accuracy_std']:.4f}",
            f"{rr['steps_run']:.0f}", f"{rr['bits']/1e9:.3f}",
            f"{rr['us_per_call']:.0f}",
        ))
    # claim C7: PaME's transmitted-volume reduction vs every dense/compressed
    # competitor (CHOCO included now that it races too)
    for name, rr in table.items():
        if name == "pame":
            continue
        red = 1.0 - table["pame"]["bits"] / rr["bits"]
        csv_row(
            f"vs_baselines/claimC7_volume_reduction_vs_{name}", 0.0,
            f"reduction={red:.2%}",
        )
    _update_experiments_md(
        "vs-baselines",
        "## PaME vs baselines: mean ± std over batched seed lanes\n\n"
        f"Example 2 logistic regression (m={m}, n={n}), erdos_renyi(p=0.4), "
        f"{steps} steps, tol_std=1e-3.  Each algorithm's {len(seeds)} seed "
        "replicas run as lanes of ONE jitted scan "
        "(`Algorithm.bind_batched`); mean gbits count the full run's "
        "transmitted volume.\n\n"
        + _fmt_md_table(
            ("algo", "final objective", "accuracy", "rounds", "gbits",
             "us/lane-step"),
            md_rows,
        ),
    )
    RESULTS["vs_baselines"] = table


def bench_faults(quick=False):
    """Graceful-degradation race: final accuracy and realized transmitted
    volume vs message-loss rate, PaME vs all five baselines under the
    message-level fault layer (`repro.core.faults`): asymmetric
    per-direction drops + transient crashes.  Surrogate-memory baselines
    (CHOCO/BEER/ANQ-NIDS) run their per-receiver replica variants with
    wire-charged repair; PaME consumes the delivery masks natively and
    its realized matrices stay row-stochastic by construction.  Each
    (algorithm, loss-rate) cell runs SWEEP_SEEDS seed lanes as one
    batched scan; the degradation curve is emitted into EXPERIMENTS.md."""
    from repro.core import algorithms as ALG
    from repro.core.faults import FaultModel

    m, n = 16, 300
    steps = 80 if quick else 200
    loss_grid = [0.0, 0.1, 0.2] if quick else [0.0, 0.05, 0.1, 0.2, 0.3]
    seeds = list(range(SWEEP_SEEDS))
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective, accuracy = logreg_problem(m, n, spn=64, seed=0)
    chunk = chunk_for(steps)
    race_hps = {
        "pame": PaMEConfig(nu=0.2, p=0.2, gamma=1.002, sigma0=1.0,
                           kappa_lo=3, kappa_hi=7),
        "dpsgd": ALG.DPSGDHp(lr=0.1),
        "dfedsam": ALG.DFedSAMHp(lr=0.1, rho=0.01),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        "beer": ALG.BeerHp(lr=0.05, gossip_gamma=0.4, comp_frac=0.2),
        "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=16),
    }
    table = {}
    md_rows = []
    for name in ALG.list_algorithms():
        for loss in loss_grid:
            # loss=0.0 is a static FaultModel: bind_batched falls back to
            # the plain fault-free program — the curve's anchor point
            fm_model = FaultModel(loss=loss, crash=0.01, rejoin=0.3, seed=0)
            ba = ALG.get_algorithm(name).bind_batched(
                grad_fn, topo, [race_hps.get(name)], seeds=seeds,
                mixing="sparse", faults=fm_model,
            )
            runner = ba.make_runner(
                objective_fn=objective, tol_std=0.0, chunk_size=chunk
            )
            t0 = time.perf_counter()
            state, hist = runner(jnp.zeros(n), m, lambda k: batch, steps)
            wall = time.perf_counter() - t0
            mean_w = np.asarray(
                jax.tree_util.tree_map(
                    lambda x: x.mean(axis=1), ba.params_of(state)
                )
            )
            accs = [accuracy(jnp.asarray(mean_w[l])) for l in range(ba.lanes)]
            om, os_ = mean_std(lane_finals(hist))
            am, a_s = mean_std(accs)
            bm, _ = mean_std(hist["wire_bits_total"])
            rep = 0.0
            if "repair_bits" in hist:
                per = np.asarray(hist["repair_bits"])
                steps_run = np.asarray(hist["steps_run"])
                rep = float(np.mean([
                    per[: steps_run[l], l].sum() for l in range(ba.lanes)
                ]))
            table[f"{name}@{loss}"] = {
                "loss_rate": loss, "final": om, "final_std": os_,
                "accuracy": am, "accuracy_std": a_s,
                "bits": bm, "repair_bits": rep, "seeds": len(seeds),
            }
            csv_row(
                f"faults/{name}/loss={loss}",
                wall / max(int(hist["steps_dispatched"]) * ba.lanes, 1) * 1e6,
                f"acc={am:.4f}±{a_s:.4f};final_obj={om:.4f}±{os_:.4f}"
                f";gbits={bm/1e9:.3f};repair_gbits={rep/1e9:.4f}",
            )
            md_rows.append((
                name, f"{loss:.2f}", f"{am:.4f} ± {a_s:.4f}",
                f"{om:.4f} ± {os_:.4f}", f"{bm/1e9:.3f}",
                f"{rep/1e9:.4f}",
            ))
    # headline: PaME's accuracy drop from 0% to the worst raced loss rate
    worst = max(loss_grid)
    for name in ALG.list_algorithms():
        drop = (table[f"{name}@0.0"]["accuracy"]
                - table[f"{name}@{worst}"]["accuracy"])
        csv_row(f"faults/degradation_{name}", 0.0,
                f"acc_drop@{worst:.0%}={drop:.4f}")
    _update_experiments_md(
        "faults",
        "## Graceful degradation under message-level faults\n\n"
        f"Example 2 logistic regression (m={m}, n={n}), erdos_renyi(p=0.4), "
        f"{steps} steps, crash=0.01/rejoin=0.3 throughout, asymmetric "
        "per-direction message loss at the listed rate.  "
        f"Mean ± std over {len(seeds)} batched seed lanes "
        "(`bind_batched(faults=...)`).  CHOCO/BEER/ANQ-NIDS run "
        "per-receiver surrogate replicas with wire-charged full-surrogate "
        "repair (the repair gbits column); PaME's count-normalized "
        "averaging needs no repair traffic.\n\n"
        + _fmt_md_table(
            ("algo", "loss rate", "accuracy", "final objective", "gbits",
             "repair gbits"),
            md_rows,
        ),
    )
    RESULTS["faults"] = table


def bench_mixing(quick=False):
    """Sparse neighbor-exchange gossip vs the dense [m, m] einsum: mixing
    cost scales with the edge set, not m².  Sweeps m x topology on a
    model-layer-sized pytree and reports us_per_call for both paths, plus
    the dense/sparse bit-identity check on a short D-PSGD run."""
    from repro.core import algorithms as ALG
    from repro.core.mixing import make_mixer

    rng = np.random.default_rng(0)
    ms = [32, 128] if quick else [32, 128, 512]
    table = {}
    for m in ms:
        tree = {
            "w": jnp.asarray(rng.standard_normal((m, 64, 64)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((m, 256)), jnp.float32),
        }
        for kind, kwargs in (
            ("ring", {}),
            ("regular", dict(degree=4, seed=0)),
            ("erdos_renyi", dict(p=max(8.0 / m, float(np.log(m) + 1) / m), seed=0)),
        ):
            topo = build_topology(kind, m, **kwargs)
            mx_mat = make_mixer(topo, "matrix")   # legacy dense einsum
            mx_sp = make_mixer(topo, "sparse")    # padded neighbor gather
            dense_fn = jax.jit(mx_mat.mix)
            sparse_fn = jax.jit(mx_sp.mix)
            us_dense = benchmark(dense_fn, tree, iters=10)["us_median"]
            us_sparse = benchmark(sparse_fn, tree, iters=10)["us_median"]
            err = max(
                float(jnp.max(jnp.abs(a - b_)))
                for a, b_ in zip(
                    jax.tree_util.tree_leaves(dense_fn(tree)),
                    jax.tree_util.tree_leaves(sparse_fn(tree)),
                )
            )
            table[f"m{m}_{kind}"] = {
                "us_dense": us_dense, "us_sparse": us_sparse,
                "max_degree": topo.max_degree, "max_err": err,
            }
            csv_row(
                f"mixing/m={m}/{kind}", us_sparse,
                f"dense_us={us_dense:.1f};speedup={us_dense/max(us_sparse,1e-9):.2f}x"
                f";max_degree={topo.max_degree};max_err={err:.2e}",
            )
    # mixing="dense" (full-connectivity padded) vs "sparse": same-seed
    # D-PSGD curves must be bit-identical.  On a complete graph the two
    # modes lower to the *same* XLA program over the same arrays, so the
    # identity is compiler-proof; on sparse graphs it additionally holds
    # whenever LLVM contracts mul+add uniformly (reported, not asserted —
    # eager mode is always bit-identical, see tests/test_mixing.py).
    m, n = 16, 300
    batch, grad_fn, objective = linreg_problem(m, n, spn=64, seed=0)
    for kind in ("complete", "ring"):
        topo = build_topology(kind, m)
        curves = {}
        for mode in ("dense", "sparse"):
            bound = ALG.get_algorithm("dpsgd").bind(
                grad_fn, topo, ALG.DPSGDHp(lr=0.1), mixing=mode
            )
            _, hist = bound.run(
                jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 32,
                tol_std=0.0, chunk_size=16,
            )
            curves[mode] = hist["loss"]
        identical = curves["dense"] == curves["sparse"]
        table[f"dpsgd_bit_identity_{kind}"] = bool(identical)
        csv_row(f"mixing/dpsgd_bit_identity/{kind}", 0.0, f"identical={identical}")
    RESULTS["mixing"] = table


def _fmt_md_table(header, rows):
    lines = ["| " + " | ".join(header) + " |",
             "|" + "|".join("---" for _ in header) + "|"]
    lines += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
    return "\n".join(lines)


def _merge_artifact(fname, key, value):
    """Read-modify-write one top-level key of a JSON artifact, so several
    benches can contribute sections to the same trajectory file."""
    path = os.path.join(ART, fname)
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data[key] = value
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float, sort_keys=True)
    print(f"# wrote {path} [{key}]")


def _update_experiments_md(tag, body):
    """Replace the marked section of EXPERIMENTS.md (idempotent emission —
    repeat benchmark runs rewrite their own block only)."""
    path = os.path.join(HERE, "..", "EXPERIMENTS.md")
    begin, end = f"<!-- BEGIN {tag} -->", f"<!-- END {tag} -->"
    block = f"{begin}\n{body}\n{end}"
    if os.path.exists(path):
        with open(path) as f:
            text = f.read()
    else:
        text = "# EXPERIMENTS\n\nGenerated tables from `benchmarks.run`.\n"
    if begin in text and end in text:
        head, rest = text.split(begin, 1)
        _, tail = rest.split(end, 1)
        text = head + block + tail
    else:
        text = text.rstrip() + "\n\n" + block + "\n"
    with open(path, "w") as f:
        f.write(text)


def bench_scenarios(quick=False):
    """Dynamic-network race: churn rate × topology for PaME + two baselines
    through the scan engine.  Every dynamic step realizes a fresh
    doubly-stochastic matrix on device (links fail, nodes drop, state of
    dropped nodes frozen) and only realized edges are charged, so the
    gbits column is the *surviving-traffic* volume.  churn=0.0 rows run
    the static fixed-Topology path — the baseline the dynamic rows are
    read against.  Then three temporal-dynamics sections: the edge_drop ×
    straggler sweep with its wall-clock-per-realized-gbit frontier
    (emitted into EXPERIMENTS.md), the i.i.d.-vs-Markov-vs-stale regime
    race at matched stationary rates, and the headline staleness-
    sensitivity row (PaME vs the gradient-tracking baselines as the
    bounded-staleness window D grows).  Closes with the sparse-vs-dense
    scenario-mixing check (same realizations, same realized wire bits,
    fp-tolerance params)."""
    from repro.core import algorithms as ALG
    from repro.core.scenarios import Scenario
    from repro.core.temporal import TemporalScenario

    m, n = 16, 300
    steps = 60 if quick else 120
    algos = ("pame", "dpsgd", "choco")
    churns = (0.0, 0.2) if quick else (0.0, 0.1, 0.3)
    topos = (("ring", {}), ("erdos_renyi", dict(p=0.4, seed=0)))
    batch, grad_fn, objective = linreg_problem(m, n, spn=64, seed=0)
    key = jax.random.PRNGKey(0)
    chunk = chunk_for(steps)
    hps = {
        "pame": PaMEConfig(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0),
        "dpsgd": ALG.DPSGDHp(lr=0.1),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
    }
    table = {}
    for kind, kwargs in topos:
        topo = build_topology(kind, m, **kwargs)
        for churn in churns:
            scen = Scenario(
                name=f"churn{churn}", churn=churn,
                edge_drop=0.1 if churn > 0 else 0.0, seed=1,
            )
            for name in algos:
                bound = ALG.get_algorithm(name).bind(
                    grad_fn, topo, hps[name], mixing="sparse", scenario=scen
                )
                runner = bound.make_runner(
                    objective_fn=objective, tol_std=1e-3, chunk_size=chunk
                )
                runner(key, jnp.zeros(n), m, lambda k: batch, chunk)  # warm-up
                t0 = time.perf_counter()
                _, hist = runner(key, jnp.zeros(n), m, lambda k: batch, steps)
                wall = time.perf_counter() - t0
                row = {
                    "final": hist["objective"][-1],
                    "steps_run": hist["steps_run"],
                    "gbits": hist["wire_bits_total"] / 1e9,
                    "us_per_call": wall / max(hist["steps_dispatched"], 1) * 1e6,
                }
                if "alive_nodes" in hist:
                    row["mean_alive"] = float(np.mean(hist["alive_nodes"]))
                table[f"{kind}_churn{churn}_{name}"] = row
                csv_row(
                    f"scenarios/{kind}/churn={churn}/{name}", row["us_per_call"],
                    f"final_obj={row['final']:.4f};rounds={row['steps_run']}"
                    f";gbits={row['gbits']:.4f}"
                    f";mean_alive={row.get('mean_alive', float(m)):.1f}",
                )
    def _race(name, scen, steps_, hp=None, topo_=None):
        """One warmed scan run; returns (final obj, realized gbits, wall s,
        us/call, steps dispatched)."""
        bound = ALG.get_algorithm(name).bind(
            grad_fn, topo_ if topo_ is not None else topo, hp or hps.get(name),
            mixing="sparse", scenario=scen,
        )
        runner = bound.make_runner(
            objective_fn=objective, tol_std=1e-3, chunk_size=chunk
        )
        runner(key, jnp.zeros(n), m, lambda k: batch, chunk)  # warm-up
        t0 = time.perf_counter()
        _, hist = runner(key, jnp.zeros(n), m, lambda k: batch, steps_)
        wall = time.perf_counter() - t0
        return {
            "final": hist["objective"][-1],
            "gbits": hist["wire_bits_total"] / 1e9,
            "wall_s": wall,
            "us_per_call": wall / max(hist["steps_dispatched"], 1) * 1e6,
            "steps_run": hist["steps_run"],
            "staleness_hist": hist.get("staleness_hist"),
        }

    # edge_drop × straggler sweep: the wall-clock-per-realized-gbit
    # frontier (how much wall time each surviving gigabit costs as links
    # fail and nodes straggle) — emitted as a table into EXPERIMENTS.md.
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    hps["beer"] = ALG.BeerHp(lr=0.05, gossip_gamma=0.4, comp_frac=0.2)
    hps["anq_nids"] = ALG.AnqNidsHp(lr=0.1, qsgd_levels=16)
    edge_drops = (0.0, 0.3) if quick else (0.0, 0.2, 0.4)
    stragglers = (0.0, 0.3) if quick else (0.0, 0.2, 0.4)
    frontier_rows = []
    for ed in edge_drops:
        for sg in stragglers:
            scen = Scenario(name=f"ed{ed}_sg{sg}", edge_drop=ed,
                            straggler=sg, seed=2)
            for name in ("pame", "dpsgd"):
                r = _race(name, scen, steps)
                tag = f"edge_drop{ed}_strag{sg}_{name}"
                s_per_gbit = r["wall_s"] / max(r["gbits"], 1e-12)
                table[tag] = {**r, "s_per_realized_gbit": s_per_gbit}
                frontier_rows.append(
                    (name, ed, sg, f"{r['final']:.4f}", f"{r['gbits']:.4f}",
                     f"{r['us_per_call']:.0f}", f"{s_per_gbit:.2f}")
                )
                csv_row(
                    f"scenarios/sweep/edge_drop={ed}/straggler={sg}/{name}",
                    r["us_per_call"],
                    f"final_obj={r['final']:.4f};gbits={r['gbits']:.4f}"
                    f";s_per_gbit={s_per_gbit:.2f}",
                )
    _update_experiments_md(
        "scenario-frontier",
        "## Dynamic-network frontier: wall-clock per realized gbit\n\n"
        f"edge_drop × straggler sweep on erdos_renyi(m={m}, p=0.4), "
        f"linreg n={n}, {steps} steps (scan engine, warmed).  gbits counts "
        "*surviving* traffic only, so the s/gbit column is the cost of the "
        "bits that actually moved.\n\n"
        + _fmt_md_table(
            ("algo", "edge_drop", "straggler", "final_obj", "realized_gbits",
             "us/step", "s_per_realized_gbit"),
            frontier_rows,
        ),
    )

    # i.i.d. vs Markov vs stale: same stationary link-failure rate (20%)
    # and straggler rate; the Markov rows replace the i.i.d. draw with a
    # bursty Gilbert–Elliott chain (mean bad burst 5 steps), and the
    # stale rows let stragglers keep participating at <= 3 steps delay.
    # Staleness delays the gradients too (the step runs on the delayed
    # stack), so the baseline stepsize must respect the delay bound —
    # lr = 0.05 here (lr = 0.1 diverges at D = 3, the classic
    # delayed-gradient stability shrinkage).
    regimes = {
        "iid": Scenario(name="iid", edge_drop=0.2, straggler=0.4, seed=3),
        "markov": TemporalScenario(
            name="markov", burst_down=0.05, burst_up=0.2, straggler=0.4,
            staleness=0, seed=3),
        "stale": TemporalScenario(
            name="stale", burst_down=0.05, burst_up=0.2, straggler=0.4,
            staleness=3, seed=3),
    }
    for regime, scen in regimes.items():
        for name in ("pame", "dpsgd"):
            r = _race(name, scen, steps,
                      hp=ALG.DPSGDHp(lr=0.05) if name == "dpsgd" else None)
            table[f"regime_{regime}_{name}"] = r
            csv_row(
                f"scenarios/regime/{regime}/{name}", r["us_per_call"],
                f"final_obj={r['final']:.4f};gbits={r['gbits']:.4f}",
            )

    # headline: staleness sensitivity, PaME vs the gradient-tracking
    # baselines — how much does each method pay as 40% of nodes run
    # late, when their t-delayed messages still count (D > 0) vs are
    # dropped (D = 0)?  Baselines race at the delay-stable lr = 0.02.
    stale_hps = {
        "dpsgd": ALG.DPSGDHp(lr=0.02),
        "beer": ALG.BeerHp(lr=0.02, gossip_gamma=0.4, comp_frac=0.2),
        "anq_nids": ALG.AnqNidsHp(lr=0.02, qsgd_levels=16),
    }
    stale_rows = []
    ds = (0, 1, 3) if quick else (0, 1, 2, 3)
    for name in ("pame", "dpsgd", "beer", "anq_nids"):
        finals = {}
        for d in ds:
            scen = TemporalScenario(
                name=f"stale{d}", straggler=0.4, staleness=d, seed=4
            )
            r = _race(name, scen, steps, hp=stale_hps.get(name))
            finals[d] = r["final"]
            table[f"staleness{d}_{name}"] = r
        degr = finals[max(ds)] / max(finals[0], 1e-12)
        stale_rows.append(
            (name,) + tuple(f"{finals[d]:.4f}" for d in ds)
            + (f"{degr:.3f}",)
        )
        csv_row(
            f"scenarios/staleness_sensitivity/{name}", 0.0,
            ";".join(f"final_D{d}={finals[d]:.4f}" for d in ds)
            + f";ratio_Dmax_over_D0={degr:.3f}",
        )
    _update_experiments_md(
        "staleness-sensitivity",
        "## Staleness sensitivity: PaME vs gradient tracking\n\n"
        "40% stragglers; D = 0 drops their round (self-loop, the old\n"
        "semantics), D > 0 mixes their <= D-step-old parameters from the\n"
        "scan-carried snapshot ring (gradients too are evaluated on the\n"
        "delayed stack — computation + communication staleness).  Final\n"
        f"objective after {steps} steps; last column is\n"
        "final(D=max)/final(D=0) — below 1 means delayed messages helped.\n"
        "PaME's decaying penalty stepsize absorbs the delay (ratio < 1),\n"
        "while the gradient-tracking baselines' correction memory\n"
        "amplifies it — the sensitivity gap the paper's robustness story\n"
        "predicts.\n\n"
        + _fmt_md_table(
            ("algo",) + tuple(f"final D={d}" for d in ds) + ("Dmax/D0",),
            stale_rows,
        ),
    )

    # sparse vs dense scenario mixing: identical realizations (same seed)
    # => identical realized wire bits; params agree to fp tolerance (the
    # two modes sum the node axis in different slot orders).
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    scen = Scenario(name="mix_eq", churn=0.2, edge_drop=0.2, seed=1)
    outs = {}
    for mode in ("sparse", "dense"):
        bound = ALG.get_algorithm("dpsgd").bind(
            grad_fn, topo, hps["dpsgd"], mixing=mode, scenario=scen
        )
        state, hist = bound.run(
            key, jnp.zeros(n), m, lambda k: batch, 32,
            tol_std=0.0, chunk_size=16,
        )
        outs[mode] = (np.asarray(state.params), hist["wire_bits"])
    delta = float(np.max(np.abs(outs["sparse"][0] - outs["dense"][0])))
    wire_equal = outs["sparse"][1] == outs["dense"][1]
    table["sparse_vs_dense"] = {"max_param_delta": delta, "wire_equal": wire_equal}
    csv_row(
        "scenarios/sparse_vs_dense", 0.0,
        f"max_param_delta={delta:.2e};wire_equal={wire_equal}",
    )
    RESULTS["scenarios"] = table


def bench_sweep(quick=False):
    """The batched-sweep headline: an S-seed × C-config grid through the
    vmap-over-lanes engine vs the per-cell Python loop (compile included),
    plus the slots-vs-segment-sum gossip core race across degrees.
    Everything lands in benchmarks/artifacts/BENCH_sweep.json so the perf
    trajectory is machine-readable, and in an EXPERIMENTS.md block."""
    from repro.core import algorithms as ALG

    m, n = 32, 300
    steps = 50 if quick else 100
    n_seeds = 4 if quick else 8
    seeds = list(range(n_seeds))
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective = linreg_problem(m, n, spn=64, seed=0)
    chunk = chunk_for(steps)
    grids = {
        "dpsgd": [ALG.DPSGDHp(lr=0.1), ALG.DPSGDHp(lr=0.05)],
        "pame": [
            PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0),
            PaMEConfig(nu=0.4, p=0.2, gamma=1.02, sigma0=4.0),
        ],
    }
    sweep_table = {}
    for name, cfgs in grids.items():
        cells = len(cfgs) * len(seeds)
        # per-cell loop: fresh bind + runner per (config, seed) — every
        # cell re-traces and re-compiles its own scan executable
        t0 = time.perf_counter()
        loop_finals = []
        for cfg in cfgs:
            for s in seeds:
                bound = ALG.get_algorithm(name).bind(grad_fn, topo, cfg)
                _, hist = bound.run(
                    jax.random.PRNGKey(s), jnp.zeros(n), m, lambda k: batch,
                    steps, objective_fn=objective, tol_std=0.0,
                    chunk_size=chunk,
                )
                loop_finals.append(hist["objective"][-1])
        wall_loop = time.perf_counter() - t0
        # batched: the whole grid is ONE jitted scan (compile included)
        t0 = time.perf_counter()
        ba = ALG.get_algorithm(name).bind_batched(
            grad_fn, topo, cfgs, seeds=seeds
        )
        _, hist = ba.run(
            jnp.zeros(n), m, lambda k: batch, steps,
            objective_fn=objective, tol_std=0.0, chunk_size=chunk,
        )
        wall_batched = time.perf_counter() - t0
        finals = lane_finals(hist)
        max_dev = float(np.max(np.abs(finals - np.asarray(loop_finals))))
        speedup = wall_loop / max(wall_batched, 1e-9)
        sweep_table[name] = {
            "cells": cells, "steps": steps,
            "wall_loop_s": wall_loop, "wall_batched_s": wall_batched,
            "speedup": speedup,
            "us_per_cell_step_loop": wall_loop / (cells * steps) * 1e6,
            "us_per_cell_step_batched": wall_batched / (cells * steps) * 1e6,
            "max_final_dev": max_dev,
        }
        csv_row(
            f"sweep/batched_vs_loop/{name}",
            sweep_table[name]["us_per_cell_step_batched"],
            f"speedup={speedup:.1f}x;cells={cells};loop_s={wall_loop:.1f}"
            f";batched_s={wall_batched:.1f};max_final_dev={max_dev:.2e}",
        )

    # gossip core race: fused slot chain vs edge-list segment-sum, across
    # degrees, on a model-layer-sized pytree.  Compile (warmup) time and
    # steady state recorded separately — the segment-sum program is O(1)
    # traced ops at any degree, the slot chain O(d).
    from repro.core.mixing import default_impl, make_mixer

    rng = np.random.default_rng(0)
    gossip_table = {}
    degs = [(32, 4), (64, 8)] if quick else [(32, 4), (64, 8), (128, 32), (256, 64)]
    for m_, d_ in degs:
        topo_ = build_topology("regular", m_, degree=d_, seed=0)
        tree = {
            "w": jnp.asarray(rng.standard_normal((m_, 64, 64)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((m_, 256)), jnp.float32),
        }
        row = {}
        for impl in ("slots", "segsum"):
            fn = jax.jit(make_mixer(topo_, "sparse", impl=impl).mix)
            r = benchmark(fn, tree, warmup=1, iters=5)
            row[impl] = {
                "us_steady": r["us_min"], "us_median": r["us_median"],
                "compile_s": r["warmup_s"],
            }
        gossip_table[f"m{m_}_d{d_}"] = row
        csv_row(
            f"sweep/gossip/m={m_}/d={d_}", row["slots"]["us_steady"],
            f"slots_us={row['slots']['us_steady']:.0f}"
            f";segsum_us={row['segsum']['us_steady']:.0f}"
            f";slots_compile_s={row['slots']['compile_s']:.2f}"
            f";segsum_compile_s={row['segsum']['compile_s']:.2f}",
        )

    # persistent-compile-cache race: the SAME dpsgd grid dispatched twice
    # through fresh bind_batched closures against a fresh cache directory.
    # A fresh closure always re-traces AND re-compiles (that is the
    # per-dispatch fixed cost the cache attacks); with the cache on, the
    # warm dispatch re-traces but swaps the XLA compile for a disk read.
    import shutil

    from repro.core.engine import setup_compilation_cache

    def _grid_dispatch_s():
        t0 = time.perf_counter()
        ba_ = ALG.get_algorithm("dpsgd").bind_batched(
            grad_fn, topo, grids["dpsgd"], seeds=seeds
        )
        _, h = ba_.run(
            jnp.zeros(n), m, lambda k: batch, steps,
            objective_fn=objective, tol_std=0.0, chunk_size=chunk,
        )
        jax.block_until_ready(h["objective"])
        return time.perf_counter() - t0

    cache_dir = os.path.join(ART, ".jax_cache_race")
    shutil.rmtree(cache_dir, ignore_errors=True)
    prior_dir = jax.config.jax_compilation_cache_dir
    setup_compilation_cache(cache_dir)
    cold_s = _grid_dispatch_s()
    warm_s = _grid_dispatch_s()
    if prior_dir:
        setup_compilation_cache(prior_dir)
    else:
        jax.config.update("jax_compilation_cache_dir", None)
        from repro.core.engine import _reset_cache_object

        _reset_cache_object()
    cache_saving = 1.0 - warm_s / max(cold_s, 1e-9)
    cache_table = {
        "cold_s": cold_s, "warm_s": warm_s, "saving": cache_saving,
        "cache_dir_entries": len(os.listdir(cache_dir)),
    }
    csv_row(
        "sweep/compile_cache/dpsgd_grid", warm_s * 1e6,
        f"cold_s={cold_s:.2f};warm_s={warm_s:.2f}"
        f";saving={cache_saving*100:.0f}%",
    )

    artifact = {
        "backend": jax.default_backend(),
        "default_gossip_impl": default_impl(),
        "batched_vs_loop": sweep_table,
        "gossip_core": gossip_table,
        "compile_cache": cache_table,
    }
    with open(os.path.join(ART, "BENCH_sweep.json"), "w") as f:
        json.dump(artifact, f, indent=1, default=float, sort_keys=True)
    print(f"# wrote {os.path.join(ART, 'BENCH_sweep.json')}")
    _merge_artifact(
        "BENCH_gossip.json", "compile_cache",
        {"backend": jax.default_backend(), **cache_table},
    )

    md_rows = [
        (name, r["cells"],
         f"{r['wall_loop_s']:.1f}", f"{r['wall_batched_s']:.1f}",
         f"{r['speedup']:.1f}x", f"{r['max_final_dev']:.1e}")
        for name, r in sweep_table.items()
    ]
    gossip_rows = [
        (key, f"{row['slots']['us_steady']:.0f}",
         f"{row['segsum']['us_steady']:.0f}",
         f"{row['slots']['compile_s']:.2f}",
         f"{row['segsum']['compile_s']:.2f}")
        for key, row in gossip_table.items()
    ]
    _update_experiments_md(
        "batched-sweep",
        "## Batched sweep engine: one compile for the whole grid\n\n"
        f"{n_seeds} seeds × 2 configs per algorithm on linreg "
        f"(m={m}, n={n}), {steps} steps, compile time included in both "
        "columns.  The per-cell loop re-traces and re-compiles every "
        "(config, seed) cell; the batched engine runs the grid as lanes "
        "of one jitted scan (`engine.run_batched`).  max_dev is the "
        "largest |batched − looped| final objective across cells.\n\n"
        + _fmt_md_table(
            ("algo", "cells", "loop_s", "batched_s", "speedup", "max_dev"),
            md_rows,
        )
        + "\n\n### Gossip core: fused slot chain vs edge-list segment-sum\n\n"
        f"`Mixer.mix` on a 64×64+256 pytree, backend={jax.default_backend()}"
        ", steady state = min over 5 reps; compile_s is the first-call "
        "(trace + compile) wall time.  The segment-sum program is O(1) "
        "traced ops at any degree — on CPU, XLA's serialized scatter "
        "keeps the fused slot chain ahead at runtime (hence the "
        "backend-gated default, `repro.core.mixing.default_impl`).\n\n"
        + _fmt_md_table(
            ("graph", "slots us/call", "segsum us/call",
             "slots compile s", "segsum compile s"),
            gossip_rows,
        )
        + "\n\n### Persistent compilation cache: cold vs warm grid dispatch\n\n"
        "The same dpsgd seed×config grid dispatched twice through *fresh* "
        "`bind_batched` closures (each dispatch re-traces and, without a "
        "cache, re-compiles) against a fresh "
        "`engine.setup_compilation_cache` directory.\n\n"
        + _fmt_md_table(
            ("cold s", "warm s", "saving"),
            [(f"{cold_s:.2f}", f"{warm_s:.2f}", f"{cache_saving*100:.0f}%")],
        ),
    )
    RESULTS["sweep"] = {
        **sweep_table, "gossip": gossip_table, "compile_cache": cache_table,
    }


def bench_gossip(quick=False):
    """The gossip-impl roofline race: slots vs segsum vs the fused Pallas
    kernel (`kernels/gossip`) across (m, degree, n) regimes, with
    bytes-moved roofline terms per impl (`roofline.gossip_roofline`).
    On CPU the kernel runs in interpret mode — the one-hot scatter build
    + single gemm lower to plain XLA, which beats the O(degree)
    serialized slot chain once the degree is high; on accelerators it is
    the fused-MXU form.  `default_impl` stays backend-gated, so a regime
    where pallas loses costs nothing — this bench is the evidence for
    flipping the gate per backend.  Emits the race into
    BENCH_gossip.json (shared with bench_sweep's compile-cache section)
    and an EXPERIMENTS.md block."""
    from benchmarks.roofline import gossip_roofline
    from repro.core.mixing import default_impl, make_mixer

    rng = np.random.default_rng(0)
    regimes = [
        (32, 4, 4096),      # low degree — slot chain territory
        (64, 32, 2048),     # mid: degree = m/2, close race
        (128, 64, 1024),    # high degree, one receiver tile
        (256, 120, 4096),   # high degree at the unroll ceiling, large n
    ]
    if quick:
        regimes = [(32, 4, 1024), (128, 64, 1024)]
    impls = ("slots", "segsum", "pallas")
    table = {}
    pallas_wins = []
    for m_, d_, n_ in regimes:
        topo_ = build_topology("regular", m_, degree=d_, seed=0)
        k_ = topo_.max_degree + 1
        x = jnp.asarray(rng.standard_normal((m_, n_)), jnp.float32)
        row = {}
        for impl in impls:
            fn = jax.jit(make_mixer(topo_, "sparse", impl=impl).mix)
            r = benchmark(fn, x, warmup=2, iters=7)
            row[impl] = {
                "us_steady": r["us_min"],
                "us_median": r["us_median"],
                "compile_s": r["compile_s"],
                "roofline": gossip_roofline(
                    m_, k_, n_, impl, measured_us=r["us_min"]
                ),
            }
        winner = min(impls, key=lambda i: row[i]["us_steady"])
        if winner == "pallas":
            pallas_wins.append(f"m{m_}_d{d_}_n{n_}")
        table[f"m{m_}_d{d_}_n{n_}"] = {**row, "winner": winner}
        csv_row(
            f"gossip/m={m_}/d={d_}/n={n_}", row["pallas"]["us_steady"],
            f"slots_us={row['slots']['us_steady']:.0f}"
            f";segsum_us={row['segsum']['us_steady']:.0f}"
            f";pallas_us={row['pallas']['us_steady']:.0f}"
            f";winner={winner}",
        )

    backend = jax.default_backend()
    race = {
        "backend": backend,
        "default_gossip_impl": default_impl(),
        "pallas_interpret": backend == "cpu",
        "regimes": table,
        "pallas_wins": pallas_wins,
    }
    _merge_artifact("BENCH_gossip.json", f"race_{backend}", race)

    md_rows = [
        (key,
         f"{row['slots']['us_steady']:.0f}",
         f"{row['segsum']['us_steady']:.0f}",
         f"{row['pallas']['us_steady']:.0f}",
         row["winner"],
         f"{row['pallas']['roofline']['intensity_flop_per_byte']:.1f}")
        for key, row in table.items()
    ]
    _update_experiments_md(
        "gossip-kernel",
        "## Gossip kernel race: slots vs segsum vs fused Pallas\n\n"
        f"`Mixer.mix` on an [m, n] stack, backend={backend} "
        f"(pallas {'interpret mode' if backend == 'cpu' else 'compiled'}), "
        "steady state = min over 7 reps.  The fused kernel builds the "
        "dense scatter matrix on-chip and contracts with one matmul per "
        "term — it trades O(degree) serialized gather passes for "
        "matrix-unit FLOPs, so it wins where the degree is high and "
        "loses to the fused slot chain at low degree (the backend-gated "
        "`default_impl` keeps slots/segsum the defaults; "
        "`REPRO_GOSSIP_IMPL=pallas` opts in).  `intensity` is the pallas "
        "roofline arithmetic intensity (flop/HBM-byte) from "
        "`roofline.gossip_roofline`.\n\n"
        + _fmt_md_table(
            ("regime", "slots us", "segsum us", "pallas us", "winner",
             "pallas intensity"),
            md_rows,
        ),
    )
    RESULTS["gossip"] = race


def bench_heterogeneity(quick=False):
    """Fig 11 (label skew, CNN) + Fig 12 (Dirichlet, ResNet-20), synthetic
    stand-in images (offline container; heterogeneity mechanism exact).

    Every cell's SWEEP_SEEDS seed replicas run as lanes of ONE batched scan
    (the seed axis moved from a per-cell Python loop onto `bind_batched`),
    so accuracies and losses report mean ± std.  The headline block races
    the flat vs tree-partitioned exchange on a >=1M-parameter wide CNN
    under label skew and emits the table into EXPERIMENTS.md."""
    from repro.core import algorithms as ALG
    from repro.data import (
        NodeBatcher,
        SyntheticClassification,
        dirichlet_partition,
        iid_partition,
        label_skew_partition,
    )
    from repro.models.cnn import ce_loss, cnn_apply, cnn_init, resnet20_apply, resnet20_init

    table = {}
    m = 4
    # quick trims: chunk-aligned step counts (one scan length = one
    # compile), 3 seed lanes on the figure cells; the EXPERIMENTS.md
    # headline always runs the full SWEEP_SEEDS lanes
    steps = 32 if quick else 100
    fig_seeds = list(range(3 if quick else SWEEP_SEEDS))
    hl_seeds = list(range(SWEEP_SEEDS))

    def run_fl(ds, parts, init_fn, apply_fn, steps, sigma0=10.0, cfg=None,
               seeds=None, batch_size=32):
        seeds = fig_seeds if seeds is None else seeds
        nb = NodeBatcher({"x": ds.images, "y": ds.labels}, parts,
                         batch_size=batch_size, seed=0)
        topo = build_topology("complete", m)
        if cfg is None:
            cfg = PaMEConfig(nu=0.7, p=0.3, gamma=1.002, sigma0=sigma0,
                             kappa_lo=2, kappa_hi=4)

        def grad_fn(params, batch, key):
            return jax.value_and_grad(
                lambda p: ce_loss(apply_fn(p, batch["x"]), batch["y"])
            )(params)

        def batch_fn(k):
            b = nb.next()
            return {"x": jnp.asarray(b["x"], jnp.float32), "y": jnp.asarray(b["y"], jnp.int32)}

        ba = ALG.get_algorithm("pame").bind_batched(
            grad_fn, topo, [cfg], seeds=seeds
        )
        t0 = time.perf_counter()
        state, hist = ba.run(
            init_fn(jax.random.PRNGKey(1)), m, batch_fn, steps, tol_std=0.0
        )
        wall = time.perf_counter() - t0
        # per-lane accuracy of the node-mean parameters (state leaves [L, m, ...])
        xs = jnp.asarray(ds.images[:512], jnp.float32)
        ys = jnp.asarray(ds.labels[:512])
        accs = []
        for l in range(ba.lanes):
            mean_params = jax.tree_util.tree_map(
                lambda x: x[l].mean(axis=0), state.params
            )
            logits = apply_fn(mean_params, xs)
            accs.append(float(jnp.mean(jnp.argmax(logits, -1) == ys)))
        am, astd = mean_std(accs)
        lm, lstd = mean_std(lane_finals(hist, "loss"))
        bm, _ = mean_std(hist["wire_bits_total"])
        return {
            "final_loss": lm, "final_loss_std": lstd,
            "accuracy": am, "accuracy_std": astd,
            "gbits": bm / 1e9, "seeds": len(seeds),
            "us_per_call": wall / max(
                int(hist["steps_dispatched"]) * ba.lanes, 1) * 1e6,
        }

    # Fig 11: label skew C in {1, 7, 10} on the CNN (quick: the extremes —
    # every cell pays a fresh lane-vmapped compile, so quick trims cells,
    # not steps)
    ds = SyntheticClassification.make(1024, (28, 28, 1), 10, seed=0, sep=3.0)
    for c in ((1, 10) if quick else (1, 7, 10)):
        parts = label_skew_partition(ds.labels, m, c, seed=0)
        r = run_fl(ds, parts, lambda k: cnn_init(k), cnn_apply, steps)
        table[f"cnn_labelskew_C{c}"] = r
        csv_row(
            f"heterogeneity/cnn/C={c}", r["us_per_call"],
            f"acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f}"
            f";final_loss={r['final_loss']:.3f};seeds={r['seeds']}",
        )

    # Fig 12: Dirichlet beta in {0.3, 0.6} + iid on ResNet-20 (short run)
    ds2 = SyntheticClassification.make(512, (32, 32, 3), 10, seed=1, sep=2.0)
    rn_steps = 10 if quick else 40
    for beta in ((0.3,) if quick else (0.3, 0.6, None)):
        if beta is None:
            parts = iid_partition(ds2.labels, m, seed=0)
            tag = "iid"
        else:
            parts = dirichlet_partition(ds2.labels, m, beta, seed=0)
            tag = f"beta{beta}"
        r = run_fl(
            ds2, parts, lambda k: resnet20_init(k), resnet20_apply, rn_steps, sigma0=10.0
        )
        table[f"resnet20_{tag}"] = r
        csv_row(
            f"heterogeneity/resnet20/{tag}", r["us_per_call"],
            f"acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f}"
            f";final_loss={r['final_loss']:.3f};seeds={r['seeds']}",
        )

    # Headline: flat vs tree-partitioned exchange on a >=1M-parameter wide
    # CNN (cnn_init width=2) under label skew.  The tree partition prices
    # each leaf as its own Eq.-(8) segment, and p_leaf throttles the
    # dominant fc1 matrix (~95% of the parameters) while the small conv /
    # head leaves keep exchanging densely.
    width = 2
    params0 = cnn_init(jax.random.PRNGKey(1), width=width)
    sizes = [int(np.prod(x.shape))
             for x in jax.tree_util.tree_leaves(params0)]
    n_wide = sum(sizes)
    hl_steps = 16 if quick else 60
    hl_bs = 16 if quick else 32
    hl_C = 3
    parts = label_skew_partition(ds.labels, m, hl_C, seed=0)
    base = dict(nu=0.7, gamma=1.002, sigma0=10.0, kappa_lo=2, kappa_hi=4,
                mask_mode="bernoulli")
    # leaf order (tree_flatten, sorted keys): b1 b2 c1 c2 fc1 fc2
    hl_cfgs = [
        ("flat p=0.3", PaMEConfig(p=0.3, **base)),
        ("tree p=0.3", PaMEConfig(p=0.3, partition="tree", **base)),
        ("tree p_leaf (fc1@0.15)", PaMEConfig(
            p=0.3, partition="tree",
            p_leaf=(1.0, 1.0, 0.8, 0.4, 0.15, 0.8), **base)),
    ]
    md_rows = []
    for label, cfg in hl_cfgs:
        r = run_fl(ds, parts, lambda k: cnn_init(k, width=width), cnn_apply,
                   hl_steps, cfg=cfg, seeds=hl_seeds, batch_size=hl_bs)
        table[f"wide_cnn_{label}"] = r
        csv_row(
            f"heterogeneity/wide_cnn/{label}", r["us_per_call"],
            f"acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f}"
            f";final_loss={r['final_loss']:.3f};gbits={r['gbits']:.3f}"
            f";seeds={r['seeds']}",
        )
        md_rows.append((
            label,
            f"{r['accuracy']:.3f} ± {r['accuracy_std']:.3f}",
            f"{r['final_loss']:.3f} ± {r['final_loss_std']:.3f}",
            f"{r['gbits']:.3f}",
            f"{r['us_per_call']:.0f}",
        ))
    _update_experiments_md(
        "heterogeneity-real",
        "## Partitioned partial exchange on a real model workload\n\n"
        f"Wide CNN ({n_wide/1e6:.2f}M params, `cnn_init(width=2)`), "
        f"label-skew heterogeneity (C={hl_C} classes/node), m={m} nodes "
        f"(complete graph), {hl_steps} steps, per-node batch {hl_bs}; each "
        f"row's {len(hl_seeds)} seed replicas run as lanes of ONE batched scan "
        "(`bind_batched`).  `tree` partitions the exchange over the model "
        "pytree: per-leaf coordinate masks and per-leaf Eq.-(8) wire "
        "accounting; `p_leaf` throttles the dominant fc1 leaf "
        f"({sizes[4]/n_wide:.0%} of all parameters) to 0.15 while small "
        "conv/head leaves exchange at 0.4–1.0.\n\n"
        + _fmt_md_table(
            ("exchange", "accuracy", "final loss", "gbits on the wire",
             "us/lane-step"),
            md_rows,
        ),
    )
    RESULTS["heterogeneity"] = table


def bench_engine(quick=False):
    """Host-loop vs scan-driver step cost on the Fig 2a workload (m=32,
    n=300 linreg).  Three rows: the pre-engine host loop (one dispatch +
    three float() syncs per step), a cold scan run (compile included), and
    the warmed scan runner (steady state — what the other benches report)."""
    m, n = 32, 300
    steps = 100 if quick else 200
    cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0)
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective = linreg_problem(m, n, spn=128, seed=0)
    key = jax.random.PRNGKey(0)
    table = {}

    t0 = time.perf_counter()
    _, hist = run_pame(
        key, jnp.zeros(n), m, grad_fn, lambda k: batch, topo, cfg,
        num_steps=steps, objective_fn=objective, tol_std=0.0, driver="host",
    )
    table["host_loop"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    t0 = time.perf_counter()
    _, hist = run_pame(
        key, jnp.zeros(n), m, grad_fn, lambda k: batch, topo, cfg,
        num_steps=steps, objective_fn=objective, tol_std=0.0, driver="scan",
        chunk_size=chunk_for(steps),
    )
    table["scan_cold"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    chunk = chunk_for(steps)
    runner = make_pame_runner(
        grad_fn, topo, cfg, objective_fn=objective, tol_std=0.0,
        chunk_size=chunk, seed=0,
    )
    runner(key, jnp.zeros(n), m, lambda k: batch, chunk)  # compile
    t0 = time.perf_counter()
    _, hist = runner(key, jnp.zeros(n), m, lambda k: batch, steps)
    table["scan_steady"] = (time.perf_counter() - t0) / hist["steps_run"] * 1e6

    for name, us in table.items():
        csv_row(f"engine/{name}", us, f"steps={steps}")
    csv_row(
        "engine/speedup", 0.0,
        f"host_over_steady={table['host_loop']/max(table['scan_steady'],1e-9):.1f}x;"
        f"host_over_cold={table['host_loop']/max(table['scan_cold'],1e-9):.1f}x",
    )
    RESULTS["engine"] = table


def bench_comm_volume(quick=False):
    """Eq. (8): bits per message, sparse vs dense; 64-/16-bit float payloads
    plus the int8 wire of exchange="compressed_q8"."""
    table = {}
    for n in (10_000, 100_000, 1_000_000):
        for frac in (0.01, 0.1, 0.2):
            s = int(frac * n)
            for vb in (64, 16, 8):
                sparse = message_bits(s, n, vb)
                dense = vb * n
                table[f"n{n}_s{s}_b{vb}"] = {"sparse": sparse, "dense": dense}
                csv_row(
                    f"comm_volume/n={n}/s={s}/bits={vb}", 0.0,
                    f"sparse_bits={sparse};dense_bits={dense};saving={1-sparse/dense:.2%}",
                )
    RESULTS["comm_volume"] = table


def bench_kernels(quick=False):
    """Pallas kernels in interpret mode (correctness-path timing only —
    real-TPU wall times are not measurable on this CPU host)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.pme_average.ops import pme_average
    from repro.kernels.pme_average.ref import pme_average_ref
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk
    from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref

    rng = np.random.default_rng(0)
    table = {}

    m, n = 16, 4096
    w = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    masks = jnp.asarray(rng.random((m, n)) < 0.2)
    a = jnp.asarray(((rng.random((m, m)) < 0.4) & ~np.eye(m, dtype=bool)), jnp.float32)
    us_k = benchmark(lambda: pme_average(w, masks, a), iters=3)["us_median"]
    us_r = benchmark(
        jax.jit(lambda: pme_average_ref(w, masks.astype(w.dtype), a)), iters=3
    )["us_median"]
    err = float(jnp.max(jnp.abs(pme_average(w, masks, a) - pme_average_ref(w, masks.astype(w.dtype), a))))
    table["pme_average"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/pme_average", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")

    b, s, h, kv, d = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    us_k = benchmark(
        lambda: flash_attention(q, k, v, block_q=64, block_k=64), iters=1
    )["us_median"]
    us_r = benchmark(jax.jit(lambda: attention_ref(q, k, v)), iters=3)["us_median"]
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, block_q=64, block_k=64) - attention_ref(q, k, v))))
    table["flash_attention"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/flash_attention", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")

    B_, Nc, L, H, P, G, N = 1, 4, 32, 4, 16, 2, 16
    xc = jnp.asarray(rng.standard_normal((B_, Nc, L, H, P)), jnp.float32)
    dtc = jnp.asarray(rng.random((B_, Nc, L, H)) * 0.2 + 0.01, jnp.float32)
    av = jnp.asarray(-np.exp(rng.standard_normal(H) * 0.2), jnp.float32)
    cum = jnp.cumsum(dtc * av[None, None, None], axis=2)
    bc = jnp.asarray(rng.standard_normal((B_, Nc, L, G, N)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((B_, Nc, L, G, N)), jnp.float32)
    us_k = benchmark(
        lambda: ssd_intra_chunk(xc, dtc, cum, bc, cc, H // G), iters=1
    )["us_median"]
    us_r = benchmark(
        jax.jit(lambda: ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, H // G)),
        iters=3,
    )["us_median"]
    yk, _ = ssd_intra_chunk(xc, dtc, cum, bc, cc, H // G)
    yr, _ = ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, H // G)
    err = float(jnp.max(jnp.abs(yk - yr)))
    table["ssd_scan"] = {"us_kernel": us_k, "us_ref": us_r, "max_err": err}
    csv_row("kernels/ssd_scan", us_k, f"ref_us={us_r:.1f};max_err={err:.2e}")
    RESULTS["kernels"] = table


def bench_roofline(quick=False):
    """§Roofline table (single-pod baselines for all 40 pairs)."""
    from benchmarks import roofline

    try:
        rows = roofline.build_table()
    except FileNotFoundError:
        csv_row("roofline", 0.0, "SKIPPED=no dryrun.json; run repro.launch.dryrun first")
        return
    print(roofline.format_table(rows))
    for r in rows:
        csv_row(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"compute_s={r['t_compute_s']:.4g};memory_s={r['t_memory_s']:.4g};"
            f"collective_s={r['t_collective_s']:.4g};dominant={r['dominant']};"
            f"useful={r['useful_ratio']:.2f}",
        )
    RESULTS["roofline"] = rows


def bench_serving(quick=False):
    """Serve-while-train frontier: final accuracy vs served QPS as the
    inference arrival process intensifies.  Each preset drives the event
    clock from `repro.serve.events` through `bind_batched(pacing=...)`:
    nodes whose request queue exceeds the defer threshold skip that
    round's exchange (a load-induced straggler — PaME's partial-exchange
    semantics absorb it natively) while still taking their local step.
    `off` is the anchor: a static pacing binds the plain program, so its
    row is the no-serving baseline.  Queueing latency is recovered from
    the histories by Little's law (mean queue depth / per-node service
    rate).  The frontier is emitted into EXPERIMENTS.md."""
    from repro.core import algorithms as ALG
    from repro.serve.events import ServePacing, get_arrival

    m, n = 16, 300
    steps = 80 if quick else 200
    seeds = list(range(SWEEP_SEEDS))
    presets = ("off", "quiet", "steady", "bursty", "rush")
    capacity, defer = 2, 4
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective, accuracy = logreg_problem(m, n, spn=64, seed=0)
    chunk = chunk_for(steps)
    hps = {
        "pame": PaMEConfig(nu=0.2, p=0.2, gamma=1.002, sigma0=1.0,
                           kappa_lo=3, kappa_hi=7),
        "dpsgd": ALG.DPSGDHp(lr=0.1),
    }
    table = {}
    md_rows = []
    for name in ("pame", "dpsgd"):
        for preset in presets:
            pac = ServePacing(get_arrival(preset), capacity=capacity,
                              defer_threshold=defer)
            ba = ALG.get_algorithm(name).bind_batched(
                grad_fn, topo, [hps[name]], seeds=seeds,
                mixing="sparse", pacing=pac,
            )
            runner = ba.make_runner(
                objective_fn=objective, tol_std=0.0, chunk_size=chunk
            )
            t0 = time.perf_counter()
            state, hist = runner(jnp.zeros(n), m, lambda k: batch, steps)
            wall = time.perf_counter() - t0
            mean_w = np.asarray(
                jax.tree_util.tree_map(
                    lambda x: x.mean(axis=1), ba.params_of(state)
                )
            )
            accs = [accuracy(jnp.asarray(mean_w[l])) for l in range(ba.lanes)]
            am, a_s = mean_std(accs)
            if "served_reqs" in hist:
                served = np.asarray(hist["served_reqs"])  # [steps, lanes]
                queue = np.asarray(hist["queue_depth"])
                deferred = np.asarray(hist["deferred_nodes"])
                qps = float(served.sum(axis=0).mean()) / steps
                per_node_rate = qps / m
                # Little's law: W = L / lambda (sojourn in rounds)
                latency = (float(queue.mean()) / per_node_rate
                           if per_node_rate > 0 else 0.0)
                defer_frac = float(deferred.mean()) / m
            else:
                # static pacing was dropped at bind: nothing served
                qps, latency, defer_frac = 0.0, 0.0, 0.0
            table[f"{name}@{preset}"] = {
                "preset": preset, "accuracy": am, "accuracy_std": a_s,
                "served_qps": qps, "latency_rounds": latency,
                "defer_frac": defer_frac, "seeds": len(seeds),
            }
            csv_row(
                f"serving/{name}/{preset}",
                wall / max(int(hist["steps_dispatched"]) * ba.lanes, 1) * 1e6,
                f"acc={am:.4f}±{a_s:.4f};qps={qps:.2f}"
                f";latency_rounds={latency:.2f};defer_frac={defer_frac:.3f}",
            )
            md_rows.append((
                name, preset, f"{am:.4f} ± {a_s:.4f}", f"{qps:.2f}",
                f"{latency:.2f}", f"{defer_frac*100:.1f}%",
            ))
    for name in ("pame", "dpsgd"):
        drop = (table[f"{name}@off"]["accuracy"]
                - table[f"{name}@rush"]["accuracy"])
        csv_row(f"serving/acc_cost_{name}", 0.0,
                f"acc_drop@rush={drop:.4f}")
    _update_experiments_md(
        "serving",
        "## Serve while you train: accuracy vs served QPS\n\n"
        f"Example 2 logistic regression (m={m}, n={n}), erdos_renyi(p=0.4), "
        f"{steps} steps, per-node serve capacity {capacity} req/round, "
        f"defer threshold {defer}.  Overloaded nodes defer that round's "
        "gossip (self-loop in the realized matrix) but keep their local "
        "gradient step — the paper's straggler semantics, triggered by "
        f"inference load.  Mean ± std over {len(seeds)} batched seed "
        "lanes (`bind_batched(pacing=...)`); latency is queueing sojourn "
        "via Little's law in units of training rounds.\n\n"
        + _fmt_md_table(
            ("algo", "arrival", "accuracy", "served QPS (net)",
             "latency (rounds)", "deferred node-rounds"),
            md_rows,
        ),
    )
    RESULTS["serving"] = table


def bench_chaos(quick=False):
    """Partition-tolerance race: a scheduled network split opens at
    steps//4 (the realization turns block-doubly-stochastic — zero
    cross-component mass, Assumption 1 intact within each side) and
    heals at steps//2; 5% message loss runs throughout so the
    surrogate-memory baselines (CHOCO/BEER/ANQ-NIDS) race their
    per-receiver replica variants.  During the split each side converges
    internally while the component means drift apart; at heal that drift
    becomes global disagreement and the race is who reconciles it.
    PaME's count-normalized averaging is memoryless — the merged rounds
    mix correctly immediately — while the surrogates re-enter with
    replicas desynced across the cut.

    Each algorithm runs TWICE with identical faults/seeds: once with the
    partition window and once without (the no-split reference).  The
    headline is the *residual damage ratio* — final-state disagreement
    split / no-split — which isolates the lasting scar the partition
    leaves after the algorithm's own convergence behaviour is divided
    out (PaME ≈ 1.0: memoryless, no scar).  The *merge spike* (peak
    disagreement in the 10 steps after heal over the pre-heal level)
    shows the transient a desynced surrogate memory injects at
    reconnection.  Emits BENCH_chaos.json and the EXPERIMENTS.md
    block."""
    from repro.core import algorithms as ALG
    from repro.core.faults import FaultModel
    from repro.core.scenarios import PartitionWindow, Scenario

    m, n = 16, 300
    steps = 80 if quick else 200
    start, heal = steps // 4, steps // 2
    seeds = list(range(SWEEP_SEEDS))
    topo = build_topology("erdos_renyi", m, p=0.4, seed=0)
    batch, grad_fn, objective, accuracy = logreg_problem(m, n, spn=64, seed=0)
    chunk = chunk_for(steps)
    scen = Scenario(
        name="split", seed=0,
        partitions=(PartitionWindow(start=start, heal=heal, n_parts=2,
                                    seed=1),),
    )
    fm_model = FaultModel(loss=0.05, seed=0)
    race_hps = {
        "pame": PaMEConfig(nu=0.2, p=0.2, gamma=1.002, sigma0=1.0,
                           kappa_lo=3, kappa_hi=7),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        "beer": ALG.BeerHp(lr=0.05, gossip_gamma=0.4, comp_frac=0.2),
        "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=16),
    }
    def final_disagreement(ba, state):
        # batched leaves are [lanes, m, ...]: per-lane mean over the m
        # nodes of the squared distance to the lane's node-mean params
        w = np.asarray(ba.params_of(state), np.float64)  # [L, m, n]
        dev = w - w.mean(axis=1, keepdims=True)
        return float(np.mean(np.mean(np.sum(dev * dev, axis=-1), axis=1)))

    table = {}
    curves = {}
    md_rows = []
    for name, hp in race_hps.items():
        run = {}
        for variant, variant_scen in (("split", scen), ("nosplit", None)):
            ba = ALG.get_algorithm(name).bind_batched(
                grad_fn, topo, [hp], seeds=seeds,
                mixing="sparse", scenario=variant_scen, faults=fm_model,
            )
            runner = ba.make_runner(
                objective_fn=objective, tol_std=0.0, chunk_size=chunk
            )
            t0 = time.perf_counter()
            state, hist = runner(jnp.zeros(n), m, lambda k: batch, steps)
            wall = time.perf_counter() - t0
            mean_w = np.asarray(
                jax.tree_util.tree_map(
                    lambda x: x.mean(axis=1), ba.params_of(state)
                )
            )
            accs = [
                accuracy(jnp.asarray(mean_w[l])) for l in range(ba.lanes)
            ]
            am, a_s = mean_std(accs)
            run[variant] = {
                "disagreement": final_disagreement(ba, state),
                "accuracy": am, "accuracy_std": a_s,
                "hist": hist, "wall": wall, "lanes": ba.lanes,
            }
        # [steps, lanes]: per-component consensus defect; outside the
        # window the single global component makes it plain disagreement
        hist = run["split"]["hist"]
        cc = np.asarray(hist["comp_consensus"]).mean(axis=1)
        gap = np.asarray(hist["comp_mean_gap"]).mean(axis=1)
        drift_at_heal = float(gap[heal - 1])     # cross-component drift
        pre_heal = float(cc[heal - 1])           # within-component level
        merge_spike = float(cc[heal:heal + 10].max()) / max(pre_heal, 1e-12)
        residual = run["split"]["disagreement"] / max(
            run["nosplit"]["disagreement"], 1e-12
        )
        acc_cost = run["nosplit"]["accuracy"] - run["split"]["accuracy"]
        table[name] = {
            "drift_at_heal": drift_at_heal,
            "pre_heal_disagreement": pre_heal,
            "merge_spike": merge_spike,
            "disagreement_split": run["split"]["disagreement"],
            "disagreement_nosplit": run["nosplit"]["disagreement"],
            "residual_damage": residual,
            "accuracy_split": run["split"]["accuracy"],
            "accuracy_nosplit": run["nosplit"]["accuracy"],
            "accuracy_cost": acc_cost,
            "seeds": len(seeds),
        }
        curves[name] = {"comp_consensus": cc.tolist(),
                        "comp_mean_gap": gap.tolist()}
        csv_row(
            f"chaos/{name}",
            run["split"]["wall"]
            / max(int(hist["steps_dispatched"]) * run["split"]["lanes"], 1)
            * 1e6,
            f"residual={residual:.4f};spike={merge_spike:.2f}x;"
            f"drift@heal={drift_at_heal:.4f};acc_cost={acc_cost:+.4f}",
        )
        md_rows.append((
            name, f"{drift_at_heal:.4f}", f"{merge_spike:.2f}×",
            f"{run['split']['disagreement']:.4f}",
            f"{run['nosplit']['disagreement']:.4f}",
            f"{residual:.3f}", f"{acc_cost:+.4f}",
        ))
    # headline: the partition's lasting scar, PaME vs each surrogate
    for name in race_hps:
        if name == "pame":
            continue
        margin = table[name]["residual_damage"] - table["pame"]["residual_damage"]
        csv_row(f"chaos/residual_damage_vs_{name}", 0.0,
                f"{name}_minus_pame={margin:+.4f};"
                f"spike_ratio={table[name]['merge_spike'] / max(table['pame']['merge_spike'], 1e-12):.1f}x")
    payload = {"config": {"m": m, "n": n, "steps": steps, "start": start,
                          "heal": heal, "loss": fm_model.loss,
                          "seeds": len(seeds)},
               "table": table, "curves": curves}
    with open(os.path.join(ART, "BENCH_chaos.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
    print(f"# wrote {os.path.join(ART, 'BENCH_chaos.json')}")
    _update_experiments_md(
        "chaos",
        "## Partition tolerance: post-heal consensus recovery\n\n"
        f"Example 2 logistic regression (m={m}, n={n}), erdos_renyi(p=0.4), "
        f"{steps} steps.  The graph splits into 2 components over steps "
        f"[{start}, {heal}) — the realized matrix is block-doubly-"
        "stochastic per component (zero cross mass) — then heals; 5% "
        "message loss runs throughout, so CHOCO/BEER/ANQ-NIDS race their "
        "per-receiver surrogate replicas.  Every algorithm also runs a "
        "*no-split* reference with identical faults and seeds; the "
        "**residual damage** column is final-state disagreement "
        "split/no-split (1.0 = the partition left no lasting scar), and "
        "**merge spike** is the peak disagreement in the 10 steps after "
        "heal over the pre-heal level (the transient a desynced "
        "surrogate memory injects at reconnection).  PaME's "
        "count-normalized averaging is memoryless, so both stay near "
        f"1.  Mean over {len(seeds)} batched seed lanes "
        "(`bind_batched(scenario=..., faults=...)`).\n\n"
        + _fmt_md_table(
            ("algo", "drift@heal", "merge spike", "final dis. (split)",
             "final dis. (no split)", "residual damage", "acc cost"),
            md_rows,
        ),
    )
    RESULTS["chaos"] = table


BENCHES = {
    "transmission_rate": bench_transmission_rate,
    "participation": bench_participation,
    "comm_period": bench_comm_period,
    "connectivity": bench_connectivity,
    "vs_baselines": bench_vs_baselines,
    "faults": bench_faults,
    "mixing": bench_mixing,
    "sweep": bench_sweep,
    "gossip": bench_gossip,
    "scenarios": bench_scenarios,
    "serving": bench_serving,
    "chaos": bench_chaos,
    "heterogeneity": bench_heterogeneity,
    "comm_volume": bench_comm_volume,
    "kernels": bench_kernels,
    "engine": bench_engine,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--compile-cache", default=os.path.join(ART, ".jax_cache"),
        metavar="DIR",
        help="persistent XLA compilation cache (on by default for "
             "benchmarks; repeat runs skip compilation for unchanged "
             "programs)",
    )
    ap.add_argument(
        "--no-compile-cache", dest="compile_cache",
        action="store_const", const=None,
    )
    args, _ = ap.parse_known_args()
    if args.compile_cache:
        from repro.core.engine import setup_compilation_cache

        print(f"# compile cache: {setup_compilation_cache(args.compile_cache)}")
    print("name,us_per_call,derived")
    names = [args.only] if args.only else list(BENCHES)
    for name in names:
        t0 = time.perf_counter()
        BENCHES[name](quick=args.quick)
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", flush=True)
    out_path = os.path.join(ART, "bench_results.json")
    results = {}
    if args.only and os.path.exists(out_path):
        # --only runs refresh their own section without clobbering the
        # rest of the artifact
        try:
            with open(out_path) as f:
                results = json.load(f)
        except (json.JSONDecodeError, OSError):
            results = {}
    results.update(RESULTS)
    # sort_keys gives byte-stable artifacts: section order no longer
    # depends on which benches ran (or in what order), so repeat runs
    # and --only refreshes diff cleanly
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, default=float, sort_keys=True)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
