"""§Roofline: three-term analysis from the dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, so the
full-depth numbers undercount the layer stack.  Two reduced-depth UNROLLED
probes per combo give the exact marginal per-layer cost; we extrapolate
linearly to the real depth:

    X(L) = X(a) + (L - a) * (X(b) - X(a)) / (b - a)

MODEL_FLOPS = 6 * N * D (dense; N_active for MoE) is reported alongside and
the ratio MODEL_FLOPS / HLO_FLOPS flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per link (ICI)

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN_PATH = os.path.join(HERE, "artifacts", "dryrun.json")


def gossip_roofline(
    m: int,
    k: int,
    n: int,
    impl: str,
    *,
    n_terms: int = 1,
    itemsize: int = 4,
    block_m: int = 128,
    measured_us: Optional[float] = None,
) -> dict:
    """Bytes-moved / FLOP roofline terms for one `gather_terms` call.

    One call contracts `n_terms` ([m, k] weight, [m, n] operand) pairs
    over the padded neighbor table.  Per impl the HBM traffic models are:

      * slots  — k fused gather+fma passes: the operand is gathered once
        per slot (k·m·n reads), the accumulator lives in registers and is
        written once (m·n), plus the table+weights (m·k ids and floats).
      * segsum — gather to an [m·k, n] edge-value intermediate (k·m·n
        read + k·m·n write), then segment-sum reads it back and writes
        m·n.
      * pallas — the fused kernel: the operand streams through VMEM once
        per receiver-row tile (ceil(m/block_m)·m·n reads — 1 when
        m ≤ block_m), output written once; the scatter matrix never
        touches HBM.

    FLOPs: 2·k·m·n multiply-adds per term for slots/segsum; the kernel
    trades them for a dense-matrix build + MXU contraction,
    2·k·m²·(n/bn tiles) + 2·m²·n — more raw FLOPs, but on the matrix
    unit with minimal HBM traffic, which is the bet the race measures.
    """
    table_bytes = m * k * (4 + 4 * n_terms)  # int32 ids + f32 weights
    op = m * n * itemsize
    if impl == "slots":
        hbm = n_terms * (k * op + op) + table_bytes
        flops = n_terms * 2.0 * k * m * n
    elif impl == "segsum":
        hbm = n_terms * (3 * k * op + op) + table_bytes
        flops = n_terms * 2.0 * k * m * n
    elif impl == "pallas":
        row_tiles = -(-m // min(block_m, m))
        hbm = n_terms * (row_tiles * op + op) + table_bytes
        flops = 2.0 * k * m * m * row_tiles + n_terms * 2.0 * m * m * n
    else:
        raise ValueError(f"unknown gossip impl {impl!r}")
    row = {
        "impl": impl,
        "m": m, "k": k, "n": n, "n_terms": n_terms,
        "hbm_bytes": float(hbm),
        "flops": float(flops),
        "t_memory_s": hbm / HBM_BW,
        "t_compute_s": flops / PEAK_FLOPS,
        "intensity_flop_per_byte": flops / hbm,
    }
    if measured_us is not None:
        row["us"] = measured_us
        row["achieved_gbps"] = hbm / (measured_us * 1e-6) / 1e9
    return row


def load_results(path: str = DRYRUN_PATH) -> Dict[str, dict]:
    with open(path) as f:
        return json.load(f)


def _extrapolate(full: dict, pa: Optional[dict], pb: Optional[dict]) -> dict:
    """Correct scan-undercounted metrics using the two unrolled probes."""
    l_target = full["n_layers"]
    out = dict(full)
    if not pa or not pb:
        out["extrapolated"] = False
        return out
    a, b = pa["n_layers"], pb["n_layers"]
    if b == a:
        out["extrapolated"] = False
        return out

    def lin(metric):
        xa, xb = pa[metric], pb[metric]
        return max(xa + (l_target - a) * (xb - xa) / (b - a), xa)

    out["flops_per_device"] = lin("flops_per_device")
    out["bytes_per_device"] = lin("bytes_per_device")
    ca = pa["collective_bytes_total"]
    cb = pb["collective_bytes_total"]
    out["collective_bytes_total"] = max(ca + (l_target - a) * (cb - ca) / (b - a), ca)
    out["extrapolated"] = True
    return out


def roofline_row(rec: dict) -> dict:
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_total"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    chips = rec["layout"]["node"] * rec["layout"]["fsdp"] * rec["layout"]["model"]
    # model flops for this step (per device): 6 N D tokens, x3 for bwd in train
    n_active = rec["active_param_count"]
    mult = 3.0 if rec["shape"].startswith("train") else 1.0
    model_flops_total = 2.0 * n_active * rec["tokens"] * mult
    model_flops_dev = model_flops_total / chips
    ratio = model_flops_dev / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "layout": rec["layout"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": model_flops_dev,
        "hlo_flops_per_device": rec["flops_per_device"],
        "useful_ratio": ratio,
        "extrapolated": rec.get("extrapolated", False),
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
    }


def build_table(results: Optional[Dict[str, dict]] = None, mesh: str = "single") -> List[dict]:
    res = results or load_results()
    rows = []
    for key, rec in sorted(res.items()):
        if rec.get("probe_layers") is not None or rec["mesh"] != mesh:
            continue
        if rec.get("variant", "baseline") != "baseline":
            continue  # §Perf variants are reported separately
        arch, shape = rec["arch"], rec["shape"]
        pa = res.get(f"{arch}|{shape}|{mesh}|L{_depths(rec)[0]}")
        pb = res.get(f"{arch}|{shape}|{mesh}|L{_depths(rec)[1]}")
        rows.append(roofline_row(_extrapolate(rec, pa, pb)))
    return rows


def _depths(rec: dict) -> tuple:
    from repro.configs import get_config
    from repro.launch.dryrun import probe_depths

    return probe_depths(get_config(rec["arch"]))


def format_table(rows: List[dict]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'n/f/m':9s} "
        f"{'compute(s)':>11s} {'memory(s)':>11s} {'collect(s)':>11s} "
        f"{'dominant':>10s} {'useful':>7s} {'temp GB':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lay = r["layout"]
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{lay['node']}/{lay['fsdp']}/{lay['model']:<5d} "
            f"{r['t_compute_s']:11.4g} {r['t_memory_s']:11.4g} "
            f"{r['t_collective_s']:11.4g} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} {r['temp_gb']:8.2f}"
        )
    return "\n".join(lines)


def main() -> None:
    rows = build_table()
    print(format_table(rows))
    out = os.path.join(HERE, "artifacts", "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
