"""§Perf: probe-extrapolated before/after comparison for the hillclimbed
(arch x shape) pairs.  Reads benchmarks/artifacts/dryrun.json."""
from __future__ import annotations

import json
import os

from benchmarks.roofline import (
    DRYRUN_PATH,
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    _extrapolate,
)

EXPERIMENTS = [
    ("stablelm-1.6b", "train_4k", ["compressed", "remat_dots"]),
    ("yi-34b", "train_4k",
     ["compressed", "remat_dots", "embed_vocab_only", "embed_vocab_only+compressed"]),
    ("deepseek-v2-236b", "prefill_32k", ["chunked2048", "chunked512"]),
    # E4 (extension): SSM projection sharding
    ("mamba2-1.3b", "prefill_32k", ["mamba_nosplit_shard", "mamba_split_proj"]),
    ("mamba2-1.3b", "train_4k", ["mamba_split_proj"]),
]


def _key(arch, shape, depth=None, variant=None):
    k = f"{arch}|{shape}|single"
    if depth:
        k += f"|L{depth}"
    if variant and variant != "baseline":
        k += f"|{variant}"
    return k


def extrapolated(res, arch, shape, variant=None):
    from repro.configs import get_config
    from repro.launch.dryrun import probe_depths

    full = res.get(_key(arch, shape, variant=variant))
    if full is None:
        return None
    a, b = probe_depths(get_config(arch))
    pa = res.get(_key(arch, shape, depth=a, variant=variant))
    pb = res.get(_key(arch, shape, depth=b, variant=variant))
    rec = _extrapolate(full, pa, pb)
    return {
        # raw = as-compiled (lax.scan bodies counted once) — always
        # comparable across variants; extrapolated = probe-corrected totals
        "raw_flops": full["flops_per_device"],
        "raw_bytes": full["bytes_per_device"],
        "raw_coll": full["collective_bytes_total"],
        "t_compute": rec["flops_per_device"] / PEAK_FLOPS,
        "t_memory": rec["bytes_per_device"] / HBM_BW,
        "t_collective": rec["collective_bytes_total"] / LINK_BW,
        "temp_gb": full["memory"]["temp_bytes"] / 1e9,
        "extrapolated": rec.get("extrapolated", False),
    }


def main() -> None:
    with open(DRYRUN_PATH) as f:
        res = json.load(f)
    report = {}
    for arch, shape, variants in EXPERIMENTS:
        base = extrapolated(res, arch, shape)
        rows = {"baseline": base}
        print(f"\n=== {arch} x {shape} (single-pod) ===")
        hdr = (
            f"{'variant':28s} | {'raw flops':>10s} {'raw bytes':>10s} {'raw coll':>10s}"
            f" {'temp GB':>8s} | {'ext cmp(s)':>10s} {'ext mem(s)':>10s} {'ext col(s)':>10s}"
        )
        print(hdr)

        def prow(name, r):
            if r is None:
                print(f"{name:28s} (missing)")
                return
            ext = (
                f"{r['t_compute']:10.4g} {r['t_memory']:10.4g} {r['t_collective']:10.4g}"
                if r["extrapolated"] else f"{'—':>10s} {'—':>10s} {'—':>10s}"
            )
            print(
                f"{name:28s} | {r['raw_flops']:10.3e} {r['raw_bytes']:10.3e}"
                f" {r['raw_coll']:10.3e} {r['temp_gb']:8.1f} | {ext}"
            )

        prow("baseline", base)
        for v in variants:
            r = extrapolated(res, arch, shape, v)
            rows[v] = r
            prow(v, r)
        report[f"{arch}|{shape}"] = rows
    out = os.path.join(os.path.dirname(DRYRUN_PATH), "perf_report.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
