"""Elastic-membership conformance: joins stay doubly stochastic and
mean-preserving, zero joins are bitwise no-ops, and checkpoint catch-up
equals live catch-up for a frozen donor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.algorithms import PaMEHp, get_algorithm
from repro.core.faults import FaultModel
from repro.core.pame import make_topology_arrays
from repro.core.scenarios import (
    Scenario,
    make_scenario_arrays,
    realization_matrix,
    realize,
)
from repro.core.topology import build_topology
from repro.serve import membership as mb

M_OLD = 8


def _grown(n_new=4, degree=2, seed=0):
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    return topo, mb.grown_topology(topo, n_new, degree=degree, seed=seed)


# ---------------------------------------------------------------------------
# Topology growth invariants
# ---------------------------------------------------------------------------
def test_grown_mixing_doubly_stochastic():
    _, g = _grown()
    assert g.m == M_OLD + 4
    np.testing.assert_allclose(g.mixing.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(g.mixing.sum(axis=0), 1.0, atol=1e-12)
    assert np.array_equal(g.mixing, g.mixing.T)


def test_grown_preserves_old_graph_and_mean():
    topo, g = _grown()
    assert np.array_equal(g.adjacency[:M_OLD, :M_OLD], topo.adjacency)
    x = np.random.default_rng(0).standard_normal((g.m, 7))
    np.testing.assert_allclose((g.mixing @ x).mean(axis=0), x.mean(axis=0),
                               atol=1e-12)


def test_realized_matrix_across_join_doubly_stochastic():
    """The in-scan realization over the GROWN node set keeps the paper's
    doubly-stochasticity / mean-preservation invariants — with dynamics."""
    _, g = _grown()
    scen = Scenario(name="harsh", edge_drop=0.2, straggler=0.3, seed=1)
    arrays = make_scenario_arrays(g, scen)
    for k in range(5):
        r = realize(scen, arrays, jnp.int32(k))
        w = np.asarray(realization_matrix(arrays, r))
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-5)
        np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-5)
        x = np.random.default_rng(k).standard_normal((g.m, 3))
        np.testing.assert_allclose((w @ x).mean(axis=0), x.mean(axis=0),
                                   atol=1e-5)


def test_new_nodes_attach_to_old_nodes_only():
    _, g = _grown(n_new=4, degree=3)
    for i in range(M_OLD, g.m):
        assert all(j < M_OLD for j in g.neighbor_sets[i])
        assert len(g.neighbor_sets[i]) == 3


def test_zero_join_topology_is_same_object():
    topo = build_topology("ring", M_OLD)
    assert mb.grown_topology(topo, 0) is topo


def test_kappa_stable_for_incumbent_nodes():
    """PaME's per-node kappa draws are sequential, so incumbents keep
    their communication periods across a join."""
    topo, g = _grown()
    cfg = PaMEHp(kappa_lo=3, kappa_hi=7)
    old = np.asarray(make_topology_arrays(topo, cfg, seed=5).kappa)
    new = np.asarray(make_topology_arrays(g, cfg, seed=5).kappa)
    np.testing.assert_array_equal(new[:M_OLD], old)


def test_join_spec_parsing():
    evs = mb.parse_join_spec("40:2,20:1:3", degree=2)
    assert evs == (mb.JoinEvent(20, 1, 3), mb.JoinEvent(40, 2, 2))
    assert mb.parse_join_spec(None) == ()
    assert mb.parse_join_spec("") == ()
    with pytest.raises(ValueError):
        mb.parse_join_spec("40")
    with pytest.raises(ValueError):
        mb.JoinEvent(step=1, n_new=1, degree=0)


def test_topology_from_adjacency_validates():
    a = np.zeros((3, 3), np.int64)
    a[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        mb.topology_from_adjacency(a)


# ---------------------------------------------------------------------------
# State expansion
# ---------------------------------------------------------------------------
def _trained_state(steps=6):
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M_OLD, 4, 5)).astype(np.float32)
    y = rng.standard_normal((M_OLD, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    bound = get_algorithm("pame").bind(grad_fn, topo,
                                       PaMEHp(nu=0.5, p=0.5))
    batch = (jnp.asarray(A), jnp.asarray(y))
    state, _ = bound.run(jax.random.PRNGKey(1), np.zeros(5, np.float32),
                         M_OLD, lambda k: batch, steps)
    return state


def test_expand_state_zero_joins_bitwise_noop():
    state = _trained_state()
    out = mb.expand_state(state, M_OLD, [])
    assert out is state  # not even a copy


def test_expand_state_clones_donors():
    state = _trained_state()
    donors = np.array([2, 0, 5])
    grown = mb.expand_state(state, M_OLD, donors)
    p_old = np.asarray(state.params)
    p_new = np.asarray(grown.params)
    assert p_new.shape[0] == M_OLD + 3
    np.testing.assert_array_equal(p_new[:M_OLD], p_old)
    np.testing.assert_array_equal(p_new[M_OLD:], p_old[donors])
    # per-node sigma rows clone too; scalar step counter passes through
    np.testing.assert_array_equal(np.asarray(grown.sigma)[M_OLD:],
                                  np.asarray(state.sigma)[donors])
    assert np.asarray(grown.step) == np.asarray(state.step)


def test_expand_state_validates_donors():
    state = _trained_state()
    with pytest.raises(ValueError):
        mb.expand_state(state, M_OLD, [M_OLD])


def test_checkpoint_catchup_equals_live_for_frozen_state(tmp_path):
    """A donor whose state has not moved since the save: catch-up from
    the checkpoint is bitwise identical to catch-up from live state."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    state = _trained_state()
    save_checkpoint(str(tmp_path), 6, {"state": state})
    restored = restore_checkpoint(str(tmp_path), {"state": state}, 6)["state"]
    donors = np.array([1, 4])
    via_live = mb.expand_state(state, M_OLD, donors)
    via_ckpt = mb.expand_state(state, M_OLD, donors, source_state=restored)
    for a, b in zip(jax.tree_util.tree_leaves(via_live),
                    jax.tree_util.tree_leaves(via_ckpt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grown_state_trains_under_grown_topology():
    """End-to-end: expand a trained state over the grown graph and keep
    training — losses stay finite, incumbents keep learning."""
    state = _trained_state()
    topo, g = _grown()
    donors = mb.default_donors(g, M_OLD)
    grown = mb.expand_state(state, M_OLD, donors)

    rng = np.random.default_rng(1)
    A = rng.standard_normal((g.m, 4, 5)).astype(np.float32)
    y = rng.standard_normal((g.m, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    bound = get_algorithm("pame").bind(grad_fn, g, PaMEHp(nu=0.5, p=0.5))
    batch = (jnp.asarray(A), jnp.asarray(y))
    new_state, hist = B.run_algorithm(
        bound.step, grown, lambda k: batch, 5,
        params_of=bound.params_of)
    assert np.all(np.isfinite(hist["loss"]))
    assert np.asarray(bound.params_of(new_state)).shape[0] == g.m


# ---------------------------------------------------------------------------
# Fault / membership separation
# ---------------------------------------------------------------------------
def test_crash_faults_refused_with_joins():
    with pytest.raises(ValueError, match="fixed-m"):
        mb.check_join_faults(FaultModel(name="c", crash=0.02, rejoin=0.2))


def test_non_crash_faults_allowed_with_joins():
    mb.check_join_faults(None)
    mb.check_join_faults(FaultModel(name="l", loss=0.2))


# ---------------------------------------------------------------------------
# Graceful departures
# ---------------------------------------------------------------------------
def test_shrunk_mixing_doubly_stochastic():
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    s = mb.shrunk_topology(topo, (6, 7))
    assert s.m == M_OLD - 2
    np.testing.assert_allclose(s.mixing.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(s.mixing.sum(axis=0), 1.0, atol=1e-12)
    assert np.array_equal(s.mixing, s.mixing.T)
    # survivors keep their sub-graph
    assert np.array_equal(s.adjacency, topo.adjacency[:6, :6])


def test_shrunk_topology_validates():
    topo = build_topology("ring", 4)
    assert mb.shrunk_topology(topo, ()) is topo  # zero leavers: same object
    with pytest.raises(ValueError):
        mb.shrunk_topology(topo, (4,))
    with pytest.raises(ValueError, match="at least one must remain"):
        mb.shrunk_topology(topo, (0, 1, 2, 3))


def test_retire_state_mean_preserving():
    """The β-weighted deviation handoff keeps the survivor mean equal to
    the pre-departure global mean (the paper's Assumption-1 analogue for
    departures), for every floating node-stacked leaf."""
    state = _trained_state()
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    pre_p = np.asarray(state.params, np.float64).mean(axis=0)
    pre_s = np.asarray(state.sigma, np.float64).mean(axis=0)
    out = mb.retire_state(state, topo, (6, 7))
    assert np.asarray(out.params).shape[0] == M_OLD - 2
    np.testing.assert_allclose(
        np.asarray(out.params, np.float64).mean(axis=0), pre_p, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out.sigma, np.float64).mean(axis=0), pre_s, atol=1e-5)
    # scalar step counter passes through untouched
    assert np.asarray(out.step) == np.asarray(state.step)


def test_retire_state_zero_leavers_bitwise_noop():
    state = _trained_state()
    topo = build_topology("ring", M_OLD)
    assert mb.retire_state(state, topo, ()) is state


def test_retire_consensus_state_costs_nothing():
    """Near consensus the deviation handoff vanishes: retiring from a
    row-identical state leaves the survivors' rows (numerically) alone —
    a graceful leave is free, unlike a crash's frozen row."""
    state = _trained_state()
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    consensus = jax.tree_util.tree_map(
        lambda x: (jnp.broadcast_to(x[:1], x.shape)
                   if getattr(x, "ndim", 0) >= 1 and x.shape[0] == M_OLD
                   else x),
        state,
    )
    out = mb.retire_state(consensus, topo, (7,))
    np.testing.assert_allclose(np.asarray(out.params),
                               np.asarray(consensus.params)[:7],
                               atol=1e-6, rtol=1e-6)


def test_retire_then_train_stays_finite():
    """End-to-end: retire two nodes from a trained state and keep
    training over the shrunk graph."""
    state = _trained_state()
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    shrunk = mb.retire_state(state, topo, (6, 7))
    s_topo = mb.shrunk_topology(topo, (6, 7))

    rng = np.random.default_rng(2)
    A = rng.standard_normal((s_topo.m, 4, 5)).astype(np.float32)
    y = rng.standard_normal((s_topo.m, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    bound = get_algorithm("pame").bind(grad_fn, s_topo, PaMEHp(nu=0.5, p=0.5))
    batch = (jnp.asarray(A), jnp.asarray(y))
    new_state, hist = B.run_algorithm(
        bound.step, shrunk, lambda k: batch, 5, params_of=bound.params_of)
    assert np.all(np.isfinite(hist["loss"]))
    assert np.asarray(bound.params_of(new_state)).shape[0] == s_topo.m


# ---------------------------------------------------------------------------
# Grow -> shrink round trip (property)
# ---------------------------------------------------------------------------
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["ring", "erdos_renyi", "regular"]),
       n_new=st.integers(1, 4), seed=st.integers(0, 5))
def test_grow_shrink_round_trip(kind, n_new, seed):
    """Growing by n and retiring the n newest nodes recovers the original
    graph and its MH mixing exactly — joins and LIFO departures are
    inverse operations on the topology."""
    topo = build_topology(kind, M_OLD, p=0.5, seed=seed)
    grown = mb.grown_topology(topo, n_new, degree=2, seed=seed)
    back = mb.shrunk_topology(grown, tuple(range(M_OLD, M_OLD + n_new)))
    assert back.m == topo.m
    np.testing.assert_array_equal(back.adjacency, topo.adjacency)
    np.testing.assert_allclose(back.mixing, topo.mixing, atol=1e-12)


# ---------------------------------------------------------------------------
# Chaos timeline validation
# ---------------------------------------------------------------------------
def test_crash_faults_refused_with_leaves():
    with pytest.raises(ValueError, match="crash"):
        mb.check_membership_faults(
            FaultModel(name="c", crash=0.02, rejoin=0.2),
            (mb.ChaosEvent(step=5, kind="leave", n=1),),
        )


def test_leave_join_same_step_refused():
    evs = (mb.ChaosEvent(step=5, kind="leave", n=1),
           mb.ChaosEvent(step=5, kind="join", n=1))
    with pytest.raises(ValueError, match="same step"):
        mb.check_membership_faults(None, evs)


def test_membership_change_inside_partition_window_refused():
    evs = (mb.ChaosEvent(step=4, kind="partition", n=2),
           mb.ChaosEvent(step=6, kind="leave", n=1),
           mb.ChaosEvent(step=8, kind="heal"))
    with pytest.raises(ValueError, match="partition window"):
        mb.check_membership_faults(None, evs)
    # after the heal the same leave is fine
    ok = (mb.ChaosEvent(step=4, kind="partition", n=2),
          mb.ChaosEvent(step=8, kind="heal"),
          mb.ChaosEvent(step=9, kind="leave", n=1))
    mb.check_membership_faults(None, ok, m0=8)


def test_timeline_emptying_graph_refused():
    evs = (mb.ChaosEvent(step=2, kind="leave", n=3),
           mb.ChaosEvent(step=4, kind="leave", n=1))
    with pytest.raises(ValueError, match="retire"):
        mb.check_membership_faults(None, evs, m0=4)
    mb.check_membership_faults(None, evs[:1], m0=4)  # one node remains


def test_partition_wider_than_remaining_graph_refused():
    evs = (mb.ChaosEvent(step=2, kind="leave", n=2),
           mb.ChaosEvent(step=4, kind="partition", n=4))
    with pytest.raises(ValueError, match="3 nodes remain"):
        mb.check_membership_faults(None, evs, m0=5)


def test_loss_faults_allowed_with_timeline():
    mb.check_membership_faults(
        FaultModel(name="l", loss=0.2),
        (mb.ChaosEvent(step=5, kind="leave", n=1),), m0=8)
