"""Elastic-membership conformance: joins stay doubly stochastic and
mean-preserving, zero joins are bitwise no-ops, and checkpoint catch-up
equals live catch-up for a frozen donor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.algorithms import PaMEHp, get_algorithm
from repro.core.faults import FaultModel
from repro.core.pame import make_topology_arrays
from repro.core.scenarios import (
    Scenario,
    make_scenario_arrays,
    realization_matrix,
    realize,
)
from repro.core.topology import build_topology
from repro.serve import membership as mb

M_OLD = 8


def _grown(n_new=4, degree=2, seed=0):
    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    return topo, mb.grown_topology(topo, n_new, degree=degree, seed=seed)


# ---------------------------------------------------------------------------
# Topology growth invariants
# ---------------------------------------------------------------------------
def test_grown_mixing_doubly_stochastic():
    _, g = _grown()
    assert g.m == M_OLD + 4
    np.testing.assert_allclose(g.mixing.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(g.mixing.sum(axis=0), 1.0, atol=1e-12)
    assert np.array_equal(g.mixing, g.mixing.T)


def test_grown_preserves_old_graph_and_mean():
    topo, g = _grown()
    assert np.array_equal(g.adjacency[:M_OLD, :M_OLD], topo.adjacency)
    x = np.random.default_rng(0).standard_normal((g.m, 7))
    np.testing.assert_allclose((g.mixing @ x).mean(axis=0), x.mean(axis=0),
                               atol=1e-12)


def test_realized_matrix_across_join_doubly_stochastic():
    """The in-scan realization over the GROWN node set keeps the paper's
    doubly-stochasticity / mean-preservation invariants — with dynamics."""
    _, g = _grown()
    scen = Scenario(name="harsh", edge_drop=0.2, straggler=0.3, seed=1)
    arrays = make_scenario_arrays(g, scen)
    for k in range(5):
        r = realize(scen, arrays, jnp.int32(k))
        w = np.asarray(realization_matrix(arrays, r))
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-5)
        np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-5)
        x = np.random.default_rng(k).standard_normal((g.m, 3))
        np.testing.assert_allclose((w @ x).mean(axis=0), x.mean(axis=0),
                                   atol=1e-5)


def test_new_nodes_attach_to_old_nodes_only():
    _, g = _grown(n_new=4, degree=3)
    for i in range(M_OLD, g.m):
        assert all(j < M_OLD for j in g.neighbor_sets[i])
        assert len(g.neighbor_sets[i]) == 3


def test_zero_join_topology_is_same_object():
    topo = build_topology("ring", M_OLD)
    assert mb.grown_topology(topo, 0) is topo


def test_kappa_stable_for_incumbent_nodes():
    """PaME's per-node kappa draws are sequential, so incumbents keep
    their communication periods across a join."""
    topo, g = _grown()
    cfg = PaMEHp(kappa_lo=3, kappa_hi=7)
    old = np.asarray(make_topology_arrays(topo, cfg, seed=5).kappa)
    new = np.asarray(make_topology_arrays(g, cfg, seed=5).kappa)
    np.testing.assert_array_equal(new[:M_OLD], old)


def test_join_spec_parsing():
    evs = mb.parse_join_spec("40:2,20:1:3", degree=2)
    assert evs == (mb.JoinEvent(20, 1, 3), mb.JoinEvent(40, 2, 2))
    assert mb.parse_join_spec(None) == ()
    assert mb.parse_join_spec("") == ()
    with pytest.raises(ValueError):
        mb.parse_join_spec("40")
    with pytest.raises(ValueError):
        mb.JoinEvent(step=1, n_new=1, degree=0)


def test_topology_from_adjacency_validates():
    a = np.zeros((3, 3), np.int64)
    a[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        mb.topology_from_adjacency(a)


# ---------------------------------------------------------------------------
# State expansion
# ---------------------------------------------------------------------------
def _trained_state(steps=6):
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M_OLD, 4, 5)).astype(np.float32)
    y = rng.standard_normal((M_OLD, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    topo = build_topology("erdos_renyi", M_OLD, p=0.5, seed=3)
    bound = get_algorithm("pame").bind(grad_fn, topo,
                                       PaMEHp(nu=0.5, p=0.5))
    batch = (jnp.asarray(A), jnp.asarray(y))
    state, _ = bound.run(jax.random.PRNGKey(1), np.zeros(5, np.float32),
                         M_OLD, lambda k: batch, steps)
    return state


def test_expand_state_zero_joins_bitwise_noop():
    state = _trained_state()
    out = mb.expand_state(state, M_OLD, [])
    assert out is state  # not even a copy


def test_expand_state_clones_donors():
    state = _trained_state()
    donors = np.array([2, 0, 5])
    grown = mb.expand_state(state, M_OLD, donors)
    p_old = np.asarray(state.params)
    p_new = np.asarray(grown.params)
    assert p_new.shape[0] == M_OLD + 3
    np.testing.assert_array_equal(p_new[:M_OLD], p_old)
    np.testing.assert_array_equal(p_new[M_OLD:], p_old[donors])
    # per-node sigma rows clone too; scalar step counter passes through
    np.testing.assert_array_equal(np.asarray(grown.sigma)[M_OLD:],
                                  np.asarray(state.sigma)[donors])
    assert np.asarray(grown.step) == np.asarray(state.step)


def test_expand_state_validates_donors():
    state = _trained_state()
    with pytest.raises(ValueError):
        mb.expand_state(state, M_OLD, [M_OLD])


def test_checkpoint_catchup_equals_live_for_frozen_state(tmp_path):
    """A donor whose state has not moved since the save: catch-up from
    the checkpoint is bitwise identical to catch-up from live state."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    state = _trained_state()
    save_checkpoint(str(tmp_path), 6, {"state": state})
    restored = restore_checkpoint(str(tmp_path), {"state": state}, 6)["state"]
    donors = np.array([1, 4])
    via_live = mb.expand_state(state, M_OLD, donors)
    via_ckpt = mb.expand_state(state, M_OLD, donors, source_state=restored)
    for a, b in zip(jax.tree_util.tree_leaves(via_live),
                    jax.tree_util.tree_leaves(via_ckpt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grown_state_trains_under_grown_topology():
    """End-to-end: expand a trained state over the grown graph and keep
    training — losses stay finite, incumbents keep learning."""
    state = _trained_state()
    topo, g = _grown()
    donors = mb.default_donors(g, M_OLD)
    grown = mb.expand_state(state, M_OLD, donors)

    rng = np.random.default_rng(1)
    A = rng.standard_normal((g.m, 4, 5)).astype(np.float32)
    y = rng.standard_normal((g.m, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    bound = get_algorithm("pame").bind(grad_fn, g, PaMEHp(nu=0.5, p=0.5))
    batch = (jnp.asarray(A), jnp.asarray(y))
    new_state, hist = B.run_algorithm(
        bound.step, grown, lambda k: batch, 5,
        params_of=bound.params_of)
    assert np.all(np.isfinite(hist["loss"]))
    assert np.asarray(bound.params_of(new_state)).shape[0] == g.m


# ---------------------------------------------------------------------------
# Fault / membership separation
# ---------------------------------------------------------------------------
def test_crash_faults_refused_with_joins():
    with pytest.raises(ValueError, match="fixed-m"):
        mb.check_join_faults(FaultModel(name="c", crash=0.02, rejoin=0.2))


def test_non_crash_faults_allowed_with_joins():
    mb.check_join_faults(None)
    mb.check_join_faults(FaultModel(name="l", loss=0.2))
