"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pme_average.ops import pme_average
from repro.kernels.pme_average.ref import pme_average_ref
from repro.kernels.ssd_scan.ops import ssd_intra_chunk
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref, ssd_sequential_ref


# ---------------------------------------------------------------------------
# pme_average
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n", [(4, 64), (8, 100), (16, 700), (3, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pme_average_kernel_shapes(m, n, dtype):
    rng = np.random.default_rng(m * 1000 + n)
    w = jnp.asarray(rng.standard_normal((m, n)), dtype)
    masks = jnp.asarray(rng.random((m, n)) < 0.3)
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.5) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    out = pme_average(w, masks, a, block_n=128)
    ref = pme_average_ref(w, masks.astype(w.dtype), a)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize("m,block_m", [(16, 4), (24, 8), (7, 2), (12, 128)])
@pytest.mark.parametrize("n,block_n", [(100, 64), (257, 64), (513, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pme_average_kernel_node_grid(m, block_m, n, block_n, dtype):
    """Node-axis grid: m spanning multiple BM tiles (incl. non-divisible m
    and n) must match the oracle for f32 and bf16."""
    rng = np.random.default_rng(m * 7 + n)
    w = jnp.asarray(rng.standard_normal((m, n)), dtype)
    masks = jnp.asarray(rng.random((m, n)) < 0.25)
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.4) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    out = pme_average(w, masks, a, block_n=block_n, block_m=block_m)
    ref = pme_average_ref(w, masks.astype(w.dtype), a)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 10),
    n=st.integers(5, 300),
    p_mask=st.sampled_from([0.05, 0.3, 0.9]),
    seed=st.integers(0, 10_000),
)
def test_pme_average_kernel_property(m, n, p_mask, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    masks = jnp.asarray(rng.random((m, n)) < p_mask)
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.5) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    out = pme_average(w, masks, a, block_n=64)
    ref = pme_average_ref(w, masks.astype(w.dtype), a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # convex-combination bound (Lemma 3 ingredient)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(w))) + 1e-5


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,kv,d,win,blocks",
    [
        (2, 64, 4, 2, 16, None, 32),
        (1, 128, 4, 4, 32, None, 64),
        (2, 64, 4, 2, 16, 24, 16),
        (1, 64, 8, 1, 64, None, 32),   # extreme GQA
        (1, 32, 2, 2, 8, 5, 16),       # window < block
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, d, win, blocks, dtype):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    out = flash_attention(q, k, v, window=win, block_q=blocks, block_k=blocks)
    ref = attention_ref(q, k, v, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,nc,l,h,p,g,n",
    [(2, 3, 16, 4, 8, 2, 8), (1, 2, 32, 2, 16, 1, 4), (1, 1, 8, 8, 4, 4, 16)],
)
def test_ssd_intra_chunk_vs_ref(b, nc, l, h, p, g, n):
    rng = np.random.default_rng(b * 100 + l)
    xc = jnp.asarray(rng.standard_normal((b, nc, l, h, p)), jnp.float32)
    dtc = jnp.asarray(rng.random((b, nc, l, h)) * 0.2 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.2), jnp.float32)
    cum = jnp.cumsum(dtc * a[None, None, None], axis=2)
    bc = jnp.asarray(rng.standard_normal((b, nc, l, g, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, nc, l, g, n)), jnp.float32)
    y_k, st_k = ssd_intra_chunk(xc, dtc, cum, bc, cc, h // g)
    y_r, st_r = ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, h // g)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r), atol=1e-4)


def test_full_ssd_layer_kernel_path_vs_sequential():
    """End-to-end: chunked SSD (kernel path) == naive per-token recurrence."""
    from repro.models.config import ModelConfig
    from repro.models.ssm import _ssd_chunked

    B, Nc, L, H, P, G, N = 2, 4, 8, 4, 8, 2, 8
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((B, Nc * L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, Nc * L, H)) * 0.2 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.standard_normal(H) * 0.2), jnp.float32)
    b_ = jnp.asarray(rng.standard_normal((B, Nc * L, G, N)), jnp.float32)
    c_ = jnp.asarray(rng.standard_normal((B, Nc * L, G, N)), jnp.float32)
    y_seq = ssd_sequential_ref(x, dt, a, b_, c_, H // G)
    for use_kernel in (False, True):
        cfg = ModelConfig(
            "t", "ssm", n_layers=1, d_model=32, vocab=8,
            ssm_state=N, ssm_head_dim=P, ssm_chunk=L, ssm_groups=G,
            use_ssd_kernel=use_kernel,
        )
        y, _ = _ssd_chunked(cfg, x, dt, a, b_, c_)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_seq), atol=2e-4,
            err_msg=f"use_kernel={use_kernel}",
        )


def test_flash_attention_kernel_interpret_explicit():
    """Explicit interpret=True smoke at the kernel layer (not through the
    backend-gated ops wrapper), so the Pallas program itself is exercised
    in tier-1 on CPU regardless of wrapper defaults."""
    from repro.kernels.flash_attention.kernel import flash_attention_pallas

    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    out = flash_attention_pallas(
        q, k, v, block_q=32, block_k=32, interpret=True
    )
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_ssd_scan_kernel_interpret_explicit():
    """Explicit interpret=True smoke for the SSD intra-chunk kernel."""
    from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_pallas

    rng = np.random.default_rng(13)
    b, nc, l, h, p, g, n = 1, 2, 16, 4, 8, 2, 8
    xc = jnp.asarray(rng.standard_normal((b, nc, l, h, p)), jnp.float32)
    dtc = jnp.asarray(rng.random((b, nc, l, h)) * 0.2 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.2), jnp.float32)
    cum = jnp.cumsum(dtc * a[None, None, None], axis=2)
    bc = jnp.asarray(rng.standard_normal((b, nc, l, g, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, nc, l, g, n)), jnp.float32)
    y_k, st_k = ssd_intra_chunk_pallas(xc, dtc, cum, bc, cc, h // g,
                                       interpret=True)
    y_r, st_r = ssd_intra_chunk_ref(xc, dtc, cum, bc, cc, h // g)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r), atol=1e-4)


def test_gossip_kernel_interpret_explicit():
    """Explicit interpret=True smoke for the fused gossip kernel (full
    equivalence suite lives in tests/test_gossip_kernel.py)."""
    from repro.kernels.gossip.ops import gather_terms_pallas
    from repro.kernels.gossip.ref import gather_terms_ref

    rng = np.random.default_rng(17)
    m, k = 12, 4
    nbrs = jnp.asarray(rng.integers(0, m, (m, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, 50)), jnp.float32)
    out = gather_terms_pallas(nbrs, [(w, x)], interpret=True)[0]
    ref = gather_terms_ref(nbrs, [(w, x)])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_flash_attention_through_model():
    """cfg.use_flash routes GQA through the kernel; logits must match."""
    from repro.models import ModelConfig, init_params
    from repro.models.model import train_loss

    cfg = ModelConfig(
        "flash", "dense", n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 64)), jnp.int32)
    l_ref = train_loss(params, cfg, {"tokens": tok})
    l_flash = train_loss(params, cfg.replace(use_flash=True), {"tokens": tok})
    np.testing.assert_allclose(float(l_ref), float(l_flash), rtol=1e-4)
