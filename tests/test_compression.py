"""Compression operator invariants (hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real pkg or the conftest stub
from hypothesis import given, settings, strategies as st

from repro.core.compression import one_bit, qsgd, rand_k, top_k


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 128), seed=st.integers(0, 1000))
def test_rand_k_unbiased(n, seed):
    """E C(x) = x within ~6 standard errors per coordinate."""
    rng = np.random.default_rng(seed)
    x = np.asarray(rng.standard_normal(n), np.float32)
    comp = rand_k(0.25, rescale=True)
    acc = np.zeros(n)
    trials = 600
    for t in range(trials):
        acc += np.asarray(comp.apply(jax.random.PRNGKey(t), x))
    est = acc / trials
    s = max(1, round(0.25 * n))
    stderr = np.abs(x) * np.sqrt((n / s - 1) / trials)
    assert (np.abs(est - x) <= 6 * stderr + 0.02).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 200), seed=st.integers(0, 1000), frac=st.sampled_from([0.1, 0.3, 0.5]))
def test_rand_k_contractive(n, seed, frac):
    """||C(x) - x||^2 <= (1 - s/n) ||x||^2 in expectation (holds a.s. here)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = rand_k(frac, rescale=False)
    y = comp.apply(jax.random.PRNGKey(seed), x)
    err = float(jnp.sum((y - x) ** 2))
    assert err <= float(jnp.sum(x**2)) + 1e-6


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 100), seed=st.integers(0, 1000))
def test_top_k_keeps_largest(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = top_k(0.25)
    y = np.asarray(comp.apply(jax.random.PRNGKey(0), x))
    s = max(1, round(0.25 * n))
    kept = np.nonzero(y)[0]
    assert len(kept) <= s + 1
    thr = np.sort(np.abs(np.asarray(x)))[-s]
    assert (np.abs(np.asarray(x)[kept]) >= thr - 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 100), seed=st.integers(0, 1000))
def test_qsgd_bounded_error(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = qsgd(16)
    y = comp.apply(jax.random.PRNGKey(seed), x)
    norm = float(jnp.linalg.norm(x))
    assert float(jnp.max(jnp.abs(y - x))) <= norm / 16 + 1e-5


def test_bit_accounting_ordering():
    n = 10_000
    assert rand_k(0.1).bits(n) < rand_k(0.5).bits(n) < 64 * n
    assert one_bit().bits(n) < qsgd(16).bits(n) < 64 * n
