"""Registry-wide conformance: the paper's consensus invariants as tests.

Zero-gradient runs isolate the *communication* half of every registered
algorithm.  Two invariants are pinned over `list_algorithms()`:

  * consensus fixed point — from identical per-node parameters, a
    zero-gradient run preserves the per-leaf global mean at the initial
    value and each leaf's dtype; algorithms whose communication state
    starts consistent (D-PSGD, DFedSAM, PaME, ANQ-NIDS) additionally
    keep every *node* at the initial point (under churn only the
    memory-free three: drop-aware NIDS's correction memory desyncs on
    frozen nodes, so mass redistributes mean-preservingly until
    consensus re-forms).  Any mixing-weight
    regression (rows not summing to 1, padding slots leaking weight,
    realized scenario matrices losing stochasticity) breaks this for the
    affected algorithm immediately — on ring / Erdős–Rényi / regular
    graphs, host and scan drivers, static and dynamic networks.  CHOCO /
    BEER move individual nodes while their error-feedback surrogates
    warm up from hats = 0, but the corrections telescope to zero across
    the network, so the global mean still holds exactly.
  * global mean preservation — from *heterogeneous* per-node parameters,
    zero-gradient steps of the doubly-stochastic gossip algorithms
    preserve the per-leaf global mean (column sums of B are 1).  PaME is
    excluded by design: PME is receiver-normalized (count-weighted),
    unbiased in expectation but not mean-preserving per realization —
    its guarantee is the consensus fixed point above.  ANQ-NIDS now
    passes the *dynamic* heterogeneous case too: the old 2x − x_prev
    extrapolation re-injected per-node history (a node with nonzero
    displacement skipping a round broke the telescoping sum), while the
    drop-aware exact-diffusion form routes every memory term through
    (Atilde − I), whose column sums over any realized surviving subgraph
    are exactly zero (see repro.core.baselines.nids_step).

(AN)Q-NIDS mixes lossy public surrogates (off-diagonal traffic is
quantized), so its invariants hold up to quantizer resolution; the tests
drive QSGD to 2^20 levels, pushing that error below fp32 noise, so the
assertions exercise the *weights*.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core.scenarios import Scenario
from repro.core.topology import build_topology

# resolved at collection time: the six built-in registrations
ALGOS = tuple(ALG.list_algorithms())
GRAPHS = [
    ("ring", {}),
    ("erdos_renyi", dict(p=0.5, seed=0)),
    ("regular", dict(degree=4, seed=0)),
]
M = 8
DYNAMIC = Scenario(name="inv", churn=0.3, edge_drop=0.3, straggler=0.3, seed=2)


def _zero_grad_fn(w, batch, key):
    del batch, key
    return jnp.zeros(()), jax.tree_util.tree_map(jnp.zeros_like, w)


def _params0(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal(5), jnp.float32),
    }


def _batch():
    return {"x": jnp.zeros((M, 2), jnp.float32)}


def _hps(name):
    return {
        "pame": ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0),
        "dpsgd": ALG.DPSGDHp(lr=0.1),
        "dfedsam": ALG.DFedSAMHp(lr=0.1, rho=0.01),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        "beer": ALG.BeerHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        # 2^20 QSGD levels: quantizer error below fp32 resolution, so the
        # mixing weights are what the invariant actually exercises
        "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=1 << 20),
    }.get(name)


def _atol(name):
    return 1e-4 if name == "anq_nids" else 2e-6


# communication state starts consistent => every node is a fixed point;
# CHOCO/BEER warm their error-feedback surrogates up from hats = 0 and
# only guarantee the global mean until the surrogates converge
PER_NODE_FIXED_POINT = ("pame", "dpsgd", "dfedsam", "anq_nids")
# under churn, drop-aware NIDS's correction memory c stops accumulating
# on frozen nodes and desyncs from the survivors' — mass redistributes
# (global mean exactly preserved) until consensus re-forms, the same
# caveat class as the CHOCO/BEER surrogate warm-up above
PER_NODE_FIXED_POINT_DYNAMIC = ("pame", "dpsgd", "dfedsam")


def _check_fixed_point(name, bound, state, params0, tag,
                       per_node=PER_NODE_FIXED_POINT):
    out = bound.params_of(state)
    for key in params0:
        leaf = np.asarray(out[key])
        ref = np.asarray(params0[key])
        assert out[key].dtype == params0[key].dtype, f"{tag}/{key}"
        assert leaf.shape == (M,) + ref.shape
        np.testing.assert_allclose(
            leaf.mean(axis=0), ref, atol=max(_atol(name), 5e-6),
            err_msg=f"{tag}/{key} (global mean)",
        )
        if name in per_node:
            np.testing.assert_allclose(
                leaf, np.broadcast_to(ref, leaf.shape), atol=_atol(name),
                err_msg=f"{tag}/{key} (per node)",
            )


@pytest.mark.parametrize("name", ALGOS)
@pytest.mark.parametrize("kind,kwargs", GRAPHS)
@pytest.mark.parametrize("driver", ["scan", "host"])
def test_zero_grad_consensus_fixed_point(name, kind, kwargs, driver):
    """Identical params + zero gradients: every algorithm must preserve the
    per-leaf global mean and dtype (and, where the communication state
    starts consistent, every node) — one parametrized net over all six
    registrations x graph families x drivers."""
    topo = build_topology(kind, M, **kwargs)
    bound = ALG.get_algorithm(name).bind(_zero_grad_fn, topo, _hps(name))
    params0 = _params0()
    batch = _batch()
    state, _ = bound.run(
        jax.random.PRNGKey(0), params0, M, lambda k: batch, 3,
        tol_std=0.0, driver=driver, chunk_size=2,
    )
    _check_fixed_point(name, bound, state, params0, f"{name}/{kind}/{driver}")


@pytest.mark.parametrize("name", ALGOS)
def test_zero_grad_consensus_fixed_point_dynamic(name):
    """Same invariant under a dynamic-network scenario: every realized
    matrix is doubly stochastic and dropped nodes are frozen, so the
    consensus invariant survives churn, link failures, and stragglers."""
    topo = build_topology("erdos_renyi", M, p=0.5, seed=0)
    bound = ALG.get_algorithm(name).bind(
        _zero_grad_fn, topo, _hps(name), scenario=DYNAMIC
    )
    assert bound.dynamic
    params0 = _params0()
    batch = _batch()
    state, hist = bound.run(
        jax.random.PRNGKey(0), params0, M, lambda k: batch, 4,
        tol_std=0.0, chunk_size=2,
    )
    _check_fixed_point(name, bound, state, params0, f"{name}/dynamic",
                       per_node=PER_NODE_FIXED_POINT_DYNAMIC)
    assert len(hist["wire_bits"]) == 4
    assert all(b >= 0.0 and np.isfinite(b) for b in hist["wire_bits"])


@pytest.mark.parametrize(
    "name,scenario",
    [(n, s) for n in ALGOS for s in (None, DYNAMIC)
     if n in ("dpsgd", "dfedsam", "choco", "beer", "anq_nids")],
)
def test_zero_grad_heterogeneous_mean_preserved(name, scenario):
    """Heterogeneous params + zero gradients: zero-gradient steps of the
    doubly-stochastic gossip algorithms preserve the per-leaf global mean
    (static and dynamic networks).  This is the column-sum-1 property the
    realized scenario matrices must uphold pointwise."""
    topo = build_topology("erdos_renyi", M, p=0.5, seed=1)
    bound = ALG.get_algorithm(name).bind(
        _zero_grad_fn, topo, _hps(name), scenario=scenario
    )
    rng = np.random.default_rng(3)
    stacked = {
        "w": jnp.asarray(rng.standard_normal((M, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((M, 5)), jnp.float32),
    }
    batch = _batch()
    state = bound.init(jax.random.PRNGKey(1), stacked, batch)
    for k in range(2):
        state, _ = (
            bound.step(state, batch, k) if bound.dynamic
            else bound.step(state, batch)
        )
    out = bound.params_of(state)
    atol = 1e-4 if name == "anq_nids" else 1e-5
    for key in stacked:
        np.testing.assert_allclose(
            np.asarray(out[key]).mean(axis=0),
            np.asarray(stacked[key]).mean(axis=0),
            atol=atol, err_msg=f"{name}/{key}",
        )
