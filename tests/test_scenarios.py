"""Dynamic-network scenario engine: paper-invariant conformance suite.

Pins the properties the scenario subsystem must guarantee for Assumption 1
(and the paper's accounting) to keep holding pointwise on time-varying
graphs:

  * every realized per-step matrix is doubly stochastic, symmetric, and
    nonnegative — across seeds, churn rates, and graph families;
  * non-participating nodes self-loop with weight exactly 1, and dropped
    nodes' parameters are bitwise untouched for the dropped step;
  * realized `wire_bits` equals the Eq.-(8) hand count on the realized
    edge set (gossip baselines and PaME's message-level accounting);
  * static-scenario runs are bit-identical to the fixed-`Topology` path
    (same program on both sides — per the FMA caveat, never compared
    across differently-lowered programs);
  * the spectral gap zeta of the *expected* dynamic matrix predicts the
    measured consensus-error contraction slope;
  * sparse and dense scenario mixing agree on time-varying graphs,
    including the m=2, isolated-node, and fully-dropped-step edge cases.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import pme
from repro.core.pame import make_topology_arrays
from repro.core.scenarios import (
    Scenario,
    expected_matrix,
    get_scenario,
    list_scenarios,
    make_scenario_arrays,
    realization_from_masks,
    realization_matrix,
    realize,
    scenario_mixer,
)
from repro.core.topology import build_topology, spectral_gap_zeta

GRAPHS = [
    ("ring", {}),
    ("erdos_renyi", dict(p=0.5, seed=0)),
    ("regular", dict(degree=4, seed=0)),
]
DYNAMICS = [
    dict(edge_drop=0.3),
    dict(churn=0.3),
    dict(straggler=0.4),
    dict(edge_drop=0.25, churn=0.2, straggler=0.2),
]


def _linreg(m, n, spn=32, seed=0):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    batch = (jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32))

    def grad_fn(w, b, key):
        aa, yy = b
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    return batch, grad_fn


def test_scenario_validation_and_presets():
    with pytest.raises(ValueError, match="probability"):
        Scenario(churn=1.5)
    with pytest.raises(ValueError, match="probability"):
        Scenario(edge_drop=-0.1)
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")
    assert get_scenario("static").is_static
    for name in list_scenarios():
        assert get_scenario(name).name == name
    assert not Scenario(churn=0.1).is_static


@pytest.mark.parametrize("kind,kwargs", GRAPHS)
@pytest.mark.parametrize("dyn", DYNAMICS)
@pytest.mark.parametrize("seed", [0, 7])
def test_realized_matrix_doubly_stochastic(kind, kwargs, dyn, seed):
    """Assumption 1 pointwise: every realized B^k is symmetric, doubly
    stochastic, and nonnegative, for every graph family x dynamics x seed."""
    m = 12
    topo = build_topology(kind, m, **kwargs)
    scen = Scenario(name="t", seed=seed, **dyn)
    arrays = make_scenario_arrays(topo, scen)
    for k in range(5):
        r = realize(scen, arrays, k)
        b = np.asarray(realization_matrix(arrays, r), np.float64)
        np.testing.assert_allclose(b.sum(axis=1), 1.0, atol=1e-5)
        np.testing.assert_allclose(b.sum(axis=0), 1.0, atol=1e-5)
        assert b.min() >= 0.0
        np.testing.assert_allclose(b, b.T, atol=1e-7)


def test_non_participants_self_loop_weight_one():
    """Dropped and straggling nodes get B_ii = 1 exactly (no traffic in or
    out), so the realized matrix stays doubly stochastic over survivors."""
    m = 12
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    scen = Scenario(name="t", churn=0.5, straggler=0.3, seed=2)
    arrays = make_scenario_arrays(topo, scen)
    saw_out = 0
    for k in range(6):
        r = realize(scen, arrays, k)
        b = np.asarray(realization_matrix(arrays, r))
        out = ~np.asarray(r.participating)
        saw_out += int(out.sum())
        for i in np.nonzero(out)[0]:
            assert b[i, i] == 1.0
            assert np.all(b[i, np.arange(m) != i] == 0.0)
            assert np.all(b[np.arange(m) != i, i] == 0.0)
    assert saw_out > 0  # churn=0.5 over 6 steps: certain in practice


def test_zero_probability_realization_matches_base_topology():
    """With all probabilities 0 the realized weights reproduce the static
    Metropolis matrix (fp tolerance) and the full base edge set."""
    for kind, kwargs in GRAPHS:
        topo = build_topology(kind, 12, **kwargs)
        scen = Scenario(name="static")
        arrays = make_scenario_arrays(topo, scen)
        r = realize(scen, arrays, 0)
        assert bool(jnp.all(r.edge_alive == arrays.valid))
        assert int(r.directed_edges) == int(topo.degrees.sum())
        b = np.asarray(realization_matrix(arrays, r), np.float64)
        np.testing.assert_allclose(b, topo.mixing, atol=1e-6)


def test_fully_dropped_step_is_identity_and_frozen():
    """churn=1.0: B^k = I exactly, zero realized edges, zero wire bits, and
    every node's parameters are bitwise untouched across the run."""
    m, n = 8, 20
    topo = build_topology("erdos_renyi", m, p=0.6, seed=0)
    scen = Scenario(name="dead", churn=1.0, seed=0)
    arrays = make_scenario_arrays(topo, scen)
    r = realize(scen, arrays, 0)
    assert int(r.directed_edges) == 0
    np.testing.assert_array_equal(
        np.asarray(realization_matrix(arrays, r)), np.eye(m, dtype=np.float32)
    )
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=0.1), scenario=scen
    )
    state, hist = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 4,
        tol_std=0.0, chunk_size=2,
    )
    np.testing.assert_array_equal(np.asarray(state.params), np.zeros((m, n)))
    assert hist["wire_bits"] == [0.0] * 4
    assert hist["wire_bits_total"] == 0.0


def test_edge_cases_m2_and_isolated_nodes():
    """m=2 single-link graph under link failure, and a star whose hub drops
    (isolating every leaf): each realization stays doubly stochastic and
    the isolated-node matrix is exactly the identity."""
    # m = 2: the one edge is either up (B = [[.5,.5],[.5,.5]]) or down (I)
    topo2 = build_topology("ring", 2)
    scen = Scenario(name="t", edge_drop=0.5, seed=0)
    arrays2 = make_scenario_arrays(topo2, scen)
    seen = set()
    for k in range(12):
        b = np.asarray(realization_matrix(arrays2, realize(scen, arrays2, k)))
        np.testing.assert_allclose(b.sum(axis=1), 1.0, atol=1e-6)
        np.testing.assert_allclose(b, b.T, atol=1e-7)
        up = bool(b[0, 1] > 0)
        seen.add(up)
        expected = np.full((2, 2), 0.5) if up else np.eye(2)
        np.testing.assert_allclose(b, expected, atol=1e-6)
    assert seen == {True, False}  # both outcomes realized over 12 draws

    # star with the hub dropped: every leaf is isolated -> B = I exactly
    topo_s = build_topology("star", 7)
    arrays_s = make_scenario_arrays(topo_s, Scenario(name="s"))
    m, d = arrays_s.nbrs.shape
    alive = jnp.ones((m,), bool).at[0].set(False)
    r = realization_from_masks(
        arrays_s, jnp.ones((m, d), bool), alive, jnp.zeros((m,), bool)
    )
    np.testing.assert_array_equal(
        np.asarray(realization_matrix(arrays_s, r)), np.eye(m, dtype=np.float32)
    )
    assert int(r.directed_edges) == 0


@pytest.mark.parametrize("kind,kwargs", GRAPHS)
def test_scenario_mixer_sparse_matches_dense_timevarying(kind, kwargs):
    """Sparse (padded gather) and dense/matrix scenario mixers agree to fp
    tolerance on every realized graph, for every gossip operator variant."""
    m = 10
    topo = build_topology(kind, m, **kwargs)
    scen = Scenario(name="t", edge_drop=0.3, churn=0.2, seed=4)
    arrays = make_scenario_arrays(topo, scen)
    rng = np.random.default_rng(1)
    tree = {
        "w": jnp.asarray(rng.standard_normal((m, 5, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((m,)), jnp.float32),
    }
    for k in range(3):
        r = realize(scen, arrays, k)
        mx_s = scenario_mixer(arrays, r, "sparse")
        mx_m = scenario_mixer(arrays, r, "matrix")
        for fn in ("mix", "mix_lazy", "mix_half"):
            out_s = getattr(mx_s, fn)(tree)
            out_m = getattr(mx_m, fn)(tree)
            for key in tree:
                np.testing.assert_allclose(
                    np.asarray(out_s[key]), np.asarray(out_m[key]),
                    rtol=1e-5, atol=1e-6, err_msg=f"{fn} step {k}",
                )
        hats = jax.tree_util.tree_map(lambda x: 0.5 * x, tree)
        out_s = mx_s.mix_nids_quantized(hats, tree)
        out_m = mx_m.mix_nids_quantized(hats, tree)
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(out_s[key]), np.asarray(out_m[key]),
                rtol=1e-5, atol=1e-6,
            )


@pytest.mark.parametrize("name", ["dpsgd", "pame"])
def test_static_scenario_bit_identical_to_fixed_topology(name):
    """bind(scenario=static) is the existing fixed-Topology program: same
    jitted scan, bit-identical curves and final parameters."""
    m, n = 8, 20
    topo = build_topology("erdos_renyi", m, p=0.6, seed=1)
    batch, grad_fn = _linreg(m, n)
    hps = {
        "dpsgd": ALG.DPSGDHp(lr=0.1),
        "pame": ALG.PaMEHp(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0),
    }[name]
    runs = {}
    for scen in (None, get_scenario("static")):
        bound = ALG.get_algorithm(name).bind(grad_fn, topo, hps, scenario=scen)
        assert not bound.dynamic
        state, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 8,
            tol_std=0.0, chunk_size=4,
        )
        runs[scen is None] = (np.asarray(bound.params_of(state)), hist)
    assert runs[True][1]["loss"] == runs[False][1]["loss"]
    np.testing.assert_array_equal(runs[True][0], runs[False][0])
    assert "wire_bits" not in runs[True][1] and "wire_bits" not in runs[False][1]


def test_dropped_params_untouched_stragglers_update_locally():
    """Per step: dropped nodes' parameters are bitwise frozen; stragglers
    skip the exchange (self-loop) but still apply their local gradient."""
    m, n = 12, 16
    topo = build_topology("erdos_renyi", m, p=0.5, seed=2)
    scen = Scenario(name="t", churn=0.4, straggler=0.3, seed=5)
    lr = 0.1
    batch, grad_fn = _linreg(m, n, seed=3)
    bound = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=lr), scenario=scen
    )
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    state = bound.init(jax.random.PRNGKey(0), stacked)
    saw_drop = saw_strag = 0
    for k in range(3):
        r = realize(scen, bound.scen_arrays, k)
        old = np.asarray(state.params)
        # reproduce the per-node gradients the step draws
        key = jax.random.fold_in(state.key, state.step)
        keys = jax.random.split(key, m)
        _, grads = jax.vmap(grad_fn)(state.params, batch, keys)
        new_state, metrics = bound.step(state, batch, k)
        new = np.asarray(new_state.params)
        alive = np.asarray(r.alive)
        participating = np.asarray(r.participating)
        for i in range(m):
            if not alive[i]:
                saw_drop += 1
                np.testing.assert_array_equal(new[i], old[i])
            elif not participating[i]:  # straggler: local SGD, no exchange
                saw_strag += 1
                np.testing.assert_array_equal(
                    new[i], np.asarray(-lr * grads[i] + state.params[i])
                )
        state = new_state
    assert saw_drop > 0 and saw_strag > 0


def test_realized_wire_bits_match_hand_count_gossip():
    """For a gossip baseline, per-step wire_bits == (realized directed
    edges) x message_bits(n, n), recomputed independently per step."""
    m, n = 10, 24
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    scen = Scenario(name="t", edge_drop=0.3, churn=0.2, seed=6)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=0.1), scenario=scen
    )
    steps = 6
    _, hist = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, steps,
        tol_std=0.0, chunk_size=3,
    )
    per_msg = pme.message_bits(n, n)
    expected = [
        float(int(realize(scen, bound.scen_arrays, k).directed_edges) * per_msg)
        for k in range(steps)
    ]
    assert hist["wire_bits"] == expected
    assert hist["wire_bits_total"] == sum(expected)


@pytest.mark.parametrize("exchange,value_bits", [("dense", 64),
                                                 ("compressed_q8", 8)])
def test_realized_wire_bits_match_hand_count_pame(exchange, value_bits):
    """PaME's realized accounting: per-step wire_bits == (number of
    selected surviving sender->receiver messages) x message_bits(s, n,
    value_bits), with the selection reproduced from the same PRNG stream
    and the int8 wire format honored for exchange="compressed_q8"."""
    m, n = 10, 30
    seed = 0
    topo = build_topology("erdos_renyi", m, p=0.5, seed=2)
    scen = Scenario(name="t", edge_drop=0.25, churn=0.25, seed=7)
    cfg = ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0,
                     exchange=exchange)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("pame").bind(
        grad_fn, topo, cfg, seed=seed, scenario=scen
    )
    steps = 8
    key = jax.random.PRNGKey(0)
    _, hist = bound.run(
        key, jnp.zeros(n), m, lambda k: batch, steps,
        tol_std=0.0, chunk_size=4,
    )
    arrs = make_topology_arrays(topo, cfg, seed=seed)
    s = max(1, int(round(cfg.p * n)))
    per_msg = pme.message_bits(s, n, value_bits)
    expected = []
    for k in range(steps):
        r = realize(scen, bound.scen_arrays, k)
        k_sel = jax.random.fold_in(key, k * 3)
        comm = ((jnp.asarray(k) % arrs.kappa) == 0) & r.participating
        sel = pme.sample_neighbor_selection_padded(
            k_sel, arrs.nbrs, arrs.valid, arrs.t, comm, survivors=r.edge_alive
        )
        expected.append(float(int(sel.sum()) * per_msg))
    assert hist["wire_bits"] == expected
    # sanity: the dynamics actually bit — some steps communicated
    assert sum(expected) > 0


def test_dynamic_run_host_equals_scan():
    """The scenario-wrapped step gives the same curves through the host
    loop and the scan engine (the realization rides the step index)."""
    m, n = 8, 16
    topo = build_topology("ring", m)
    scen = Scenario(name="t", edge_drop=0.3, churn=0.2, straggler=0.2, seed=1)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("choco").bind(
        grad_fn, topo, ALG.ChocoHp(lr=0.05), scenario=scen
    )
    outs = {}
    for driver in ("scan", "host"):
        _, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 6,
            tol_std=0.0, driver=driver, chunk_size=3,
        )
        outs[driver] = hist
    np.testing.assert_allclose(
        outs["scan"]["loss"], outs["host"]["loss"], rtol=1e-5, atol=1e-7
    )
    assert outs["scan"]["wire_bits"] == outs["host"]["wire_bits"]
    # the README-documented schema holds on both drivers
    assert outs["scan"]["alive_nodes"] == outs["host"]["alive_nodes"]
    assert len(outs["scan"]["alive_nodes"]) == 6
    assert all(0 <= a <= m for a in outs["scan"]["alive_nodes"])


@pytest.mark.parametrize(
    "kind,kwargs,dyn",
    [
        ("erdos_renyi", dict(p=0.5, seed=1), dict(churn=0.2, edge_drop=0.2)),
        ("ring", {}, dict(edge_drop=0.3)),
    ],
)
def test_zeta_of_expected_matrix_predicts_contraction(kind, kwargs, dyn):
    """Spectral conformance: the consensus error of the pure-mixing dynamic
    process contracts at the rate predicted by the expected matrix —
    measured log-slope within tolerance of 2·log zeta(E[B]), and no faster
    than the E[B^T B] bound allows."""
    m = 16
    topo = build_topology(kind, m, **kwargs)
    scen = Scenario(name="z", seed=3, **dyn)
    arrays = make_scenario_arrays(topo, scen)
    eb = expected_matrix(topo, scen, num_samples=400)
    np.testing.assert_allclose(eb.sum(axis=1), 1.0, atol=1e-6)
    zeta = spectral_gap_zeta(eb)
    assert 0.0 < zeta < 1.0
    predicted = 2.0 * np.log(zeta)
    # E[B^T B] restricted to the mean-orthogonal subspace upper-bounds the
    # per-step expected contraction; zeta(E[B])^2 lower-bounds it (Jensen).
    mats = np.stack([
        np.asarray(
            realization_matrix(arrays, realize(scen, arrays, k)), np.float64
        )
        for k in range(400)
    ])
    ebtb = np.einsum("kij,kil->kjl", mats, mats).mean(axis=0)
    rho2 = np.sort(np.linalg.eigvalsh(ebtb))[::-1][1]
    assert zeta**2 <= rho2 + 1e-9
    # measure the actual dynamic process on fresh realizations (f64 host
    # math: no fp32 noise floor over 120 steps)
    mats2 = np.stack([
        np.asarray(
            realization_matrix(arrays, realize(scen, arrays, 1000 + k)),
            np.float64,
        )
        for k in range(120)
    ])
    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, 64))
    # remove the consensus component up front: it is preserved by every
    # B^k, and its O(1) magnitude would otherwise put an fp64-roundoff
    # floor under the exponentially decaying deviation we are measuring
    x -= x.mean(axis=0, keepdims=True)
    errs = []
    for b in mats2:
        errs.append(np.sum((x - x.mean(axis=0, keepdims=True)) ** 2))
        x = b @ x
    errs.append(np.sum((x - x.mean(axis=0, keepdims=True)) ** 2))
    slope = (np.log(errs[110]) - np.log(errs[10])) / 100.0
    tol = max(0.15 * abs(predicted), 0.02)
    assert abs(slope - predicted) < tol, (slope, predicted)
    assert slope <= np.log(rho2) + 0.05
