"""The unified algorithm registry: contract, drivers, wire accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core.topology import build_topology

EXPECTED = ("pame", "dpsgd", "dfedsam", "choco", "beer", "anq_nids")
CHUNK = 4


@pytest.fixture(scope="module")
def problem():
    m, n, spn = 8, 24, 32
    topo = build_topology("erdos_renyi", m, p=0.6, seed=1)
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    a_j, y_j = jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    return topo, grad_fn, (a_j, y_j), m, n


# well-behaved small-problem hyperparameters per algorithm
def _hps(name):
    return {
        "pame": ALG.PaMEHp(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0),
        "dpsgd": ALG.DPSGDHp(lr=0.05),
        "dfedsam": ALG.DFedSAMHp(lr=0.05, rho=0.01),
        "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
        "beer": ALG.BeerHp(lr=0.02, gossip_gamma=0.3, comp_frac=0.3),
        "anq_nids": ALG.AnqNidsHp(lr=0.05, qsgd_levels=64),
    }[name]


def test_all_expected_algorithms_registered():
    names = ALG.list_algorithms()
    for name in EXPECTED:
        assert name in names
    with pytest.raises(ValueError, match="unknown algorithm"):
        ALG.get_algorithm("nope")


@pytest.mark.parametrize("name", EXPECTED)
def test_registry_contract_scan_host_same_curves(name, problem):
    """Every registered algorithm runs 2x chunk steps under driver="scan"
    and driver="host" from the same seed with identical loss curves, and
    its wire_bits is finite and positive."""
    topo, grad_fn, batch, m, n = problem
    bound = ALG.get_algorithm(name).bind(grad_fn, topo, _hps(name))
    outs = {}
    for driver in ("scan", "host"):
        state, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch,
            2 * CHUNK, tol_std=0.0, driver=driver, chunk_size=CHUNK,
        )
        outs[driver] = (state, hist)
    h_s, h_h = outs["scan"][1], outs["host"][1]
    assert h_s["steps_run"] == h_h["steps_run"] == 2 * CHUNK
    assert h_s["steps_dispatched"] == h_h["steps_dispatched"] == 2 * CHUNK
    np.testing.assert_allclose(h_s["loss"], h_h["loss"], rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(bound.params_of(outs["scan"][0])),
        np.asarray(bound.params_of(outs["host"][0])),
        rtol=1e-5, atol=1e-6,
    )
    wb = bound.wire_bits(n)
    assert np.isfinite(wb) and wb > 0
    assert h_s["wire_bits_per_step"] == wb
    assert h_s["wire_bits_total"] == pytest.approx(wb * h_s["steps_run"])


@pytest.mark.parametrize("name", EXPECTED)
def test_registry_default_hps_construct(name):
    alg = ALG.get_algorithm(name)
    hps = alg.hp_cls()
    assert dataclasses.is_dataclass(hps)


def test_bind_rejects_wrong_hp_type(problem):
    topo, grad_fn, _, _, _ = problem
    with pytest.raises(TypeError, match="dpsgd expects DPSGDHp"):
        ALG.get_algorithm("dpsgd").bind(grad_fn, topo, ALG.BeerHp())


def test_needs_batch0_enforced(problem):
    topo, grad_fn, batch, m, n = problem
    bound = ALG.get_algorithm("beer").bind(grad_fn, topo, _hps("beer"))
    stacked = jnp.zeros((m, n))
    with pytest.raises(ValueError, match="batch0"):
        bound.init(jax.random.PRNGKey(0), stacked)


def test_make_runner_persistent_and_consistent(problem):
    """The persistent runner matches the one-shot driver and can be
    re-invoked without re-init side effects."""
    topo, grad_fn, batch, m, n = problem
    bound = ALG.get_algorithm("dpsgd").bind(grad_fn, topo, _hps("dpsgd"))
    runner = bound.make_runner(chunk_size=CHUNK)
    _, h1 = runner(jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 2 * CHUNK)
    _, h2 = runner(jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 2 * CHUNK)
    assert h1["loss"] == h2["loss"]
    _, h3 = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 2 * CHUNK,
        tol_std=0.0, chunk_size=CHUNK,
    )
    np.testing.assert_allclose(h1["loss"], h3["loss"], rtol=1e-6)


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        ALG.register(ALG.get_algorithm("dpsgd"))


def test_custom_registration_roundtrip(problem):
    """The README extension example: a custom algorithm registers, binds,
    and runs through the same drivers."""
    topo, grad_fn, batch, m, n = problem

    @dataclasses.dataclass(frozen=True)
    class GDHp:
        lr: float = 0.1

    name = "_test_local_gd"
    if name not in ALG.list_algorithms():
        from collections import namedtuple

        S = namedtuple("S", "params step key")

        def _init(key, stacked, ctx, batch0):
            return S(stacked, jnp.zeros((), jnp.int32), key)

        def _step(state, batch, ctx):
            key = jax.random.fold_in(state.key, state.step)
            keys = jax.random.split(key, ctx.topo.m)
            losses, grads = jax.vmap(ctx.grad_fn)(state.params, batch, keys)
            new = jax.tree_util.tree_map(
                lambda p, g: p - ctx.hps.lr * g, state.params, grads
            )
            return state._replace(params=new, step=state.step + 1), {
                "loss_mean": jnp.mean(losses)
            }

        ALG.register(ALG.Algorithm(
            name=name, hp_cls=GDHp, init=_init, step=_step,
            wire_bits=lambda topo_, hps_, n_: 1.0,  # local-only: no traffic
        ))
    bound = ALG.get_algorithm(name).bind(grad_fn, topo, GDHp(lr=0.05))
    _, hist = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 8,
        tol_std=0.0, chunk_size=CHUNK,
    )
    assert hist["loss"][-1] < hist["loss"][0]


def test_pame_history_schema_aligned_across_drivers(problem):
    """Satellite: run_pame host/scan drivers share one schema — both carry
    steps_dispatched and neither carries the dead "bits" list."""
    from repro.core import PaMEConfig, run_pame

    topo, grad_fn, batch, m, n = problem
    cfg = PaMEConfig(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0)
    for driver in ("host", "scan"):
        _, hist = run_pame(
            jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn, lambda k: batch,
            topo, cfg, num_steps=6, tol_std=0.0, driver=driver, chunk_size=3,
        )
        assert "bits" not in hist, driver
        assert hist["steps_dispatched"] == 6, driver
        assert hist["steps_run"] == 6, driver
        assert len(hist["loss"]) == 6, driver
