"""ssm_split_proj (E4 sharding variant) is mathematically identical to the
fused in_proj when initialised from its slices — full-seq and decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.config import ModelConfig


def _split_from_fused(pf: dict, cfg: ModelConfig) -> dict:
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    w = pf["in_proj"]
    ps = {k: v for k, v in pf.items() if k not in ("in_proj", "conv_w", "conv_b")}
    ps.update(
        {
            "in_z": w[:, :di],
            "in_x": w[:, di : 2 * di],
            "in_B": w[:, 2 * di : 2 * di + g * n],
            "in_C": w[:, 2 * di + g * n : 2 * di + 2 * g * n],
            "in_dt": w[:, 2 * di + 2 * g * n :],
            "conv_x_w": pf["conv_w"][:, :di],
            "conv_x_b": pf["conv_b"][:di],
            "conv_B_w": pf["conv_w"][:, di : di + g * n],
            "conv_B_b": pf["conv_b"][di : di + g * n],
            "conv_C_w": pf["conv_w"][:, di + g * n :],
            "conv_C_b": pf["conv_b"][di + g * n :],
        }
    )
    return ps


@pytest.mark.parametrize("groups", [1, 2])
def test_split_proj_equivalence(groups):
    cfg = ModelConfig(
        "t", "ssm", n_layers=1, d_model=32, vocab=8,
        ssm_state=8, ssm_head_dim=8, ssm_chunk=4, ssm_groups=groups,
    )
    cfg_split = cfg.replace(ssm_split_proj=True)
    pf = ssm.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    ps = _split_from_fused(pf, cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 12, 32)), jnp.float32)
    yf, cf = ssm.mamba_apply(pf, cfg, x, return_cache=True)
    ys, cs = ssm.mamba_apply(ps, cfg_split, x, return_cache=True)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(ys), atol=2e-6)
    np.testing.assert_allclose(np.asarray(cf.conv), np.asarray(cs.conv), atol=2e-6)
    x1 = jnp.asarray(np.random.default_rng(1).standard_normal((2, 1, 32)), jnp.float32)
    yd_f, _ = ssm.mamba_decode(pf, cfg, x1, cf)
    yd_s, _ = ssm.mamba_decode(ps, cfg_split, x1, cs)
    np.testing.assert_allclose(np.asarray(yd_f), np.asarray(yd_s), atol=2e-6)


def test_split_proj_model_end_to_end():
    cfg = ModelConfig(
        "t", "ssm", n_layers=2, d_model=64, vocab=64,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, ssm_split_proj=True,
    )
    from repro.models import init_params, train_loss

    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 16)), jnp.int32)
    loss, grads = jax.value_and_grad(lambda p: train_loss(p, cfg, {"tokens": tok}))(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree_util.tree_leaves(grads))
