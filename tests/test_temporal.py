"""Temporal-dynamics subsystem conformance suite.

Pins the properties `repro.core.temporal` must guarantee:

  * the Gilbert–Elliott edge chain and the session node chain match their
    stationary laws (empirical occupancy over long scans) and transition
    statistics (burst persistence);
  * degenerate Markov rates (burst_up = 1 − burst_down, rejoin = 1 −
    leave) reproduce the i.i.d. `Scenario` realization *bitwise*, and a
    staleness-0 temporal run of the plain straggler process is
    bit-identical to the existing i.i.d. straggler path (compared in
    eager mode — per the FMA caveat, bitwise equality is only asserted
    within one lowering);
  * every temporal realization is doubly stochastic with delayed
    stragglers participating and over-stale/churned nodes self-looped at
    exactly 1;
  * bounded-staleness mixing gathers the right ring snapshot (hand-built
    reference: realized matrix × substituted stack + innovation add-back
    + churn freeze) and preserves the per-leaf global parameter mean for
    every registered algorithm;
  * host and scan drivers produce identical trajectories on a fixed-seed
    temporal scenario, with the Markov state and the staleness ring in
    the scan carry (chunked runs agree across chunk sizes);
  * mobility resampling holds the active edge subset fixed within an
    epoch and redraws it across epochs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core.scenarios import (
    Scenario,
    make_scenario_arrays,
    realization_matrix,
    realize,
)
from repro.core.temporal import (
    TemporalScenario,
    advance,
    get_temporal_scenario,
    list_temporal_scenarios,
    temporal_state_init,
)
from repro.core.topology import build_topology

M = 8


def _zero_grad_fn(w, batch, key):
    del batch, key
    return jnp.zeros(()), jax.tree_util.tree_map(jnp.zeros_like, w)


def _linreg(m, n, spn=32, seed=0):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    batch = (jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32))

    def grad_fn(w, b, key):
        aa, yy = b
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    return batch, grad_fn


def _scan_chain(scen, arrays, steps):
    """Advance the Markov chains `steps` times, stacking the states."""
    ts0 = temporal_state_init(scen, arrays)

    def body(ts, k):
        ts2, _, _, _ = advance(scen, arrays, ts, k)
        return ts2, (ts2.edge_bad, ts2.node_down)

    _, (bad, down) = jax.jit(
        lambda t0: jax.lax.scan(body, t0, jnp.arange(steps))
    )(ts0)
    return np.asarray(bad), np.asarray(down)


def test_temporal_validation_and_presets():
    with pytest.raises(ValueError, match="probability"):
        TemporalScenario(burst_down=1.5)
    with pytest.raises(ValueError, match="staleness"):
        TemporalScenario(staleness=-1)
    with pytest.raises(ValueError, match="permanent"):
        TemporalScenario(burst_down=0.1, burst_up=0.0)
    with pytest.raises(ValueError, match="permanent"):
        TemporalScenario(leave=0.1, rejoin=0.0)
    with pytest.raises(ValueError, match="unknown temporal"):
        get_temporal_scenario("nope")
    for name in list_temporal_scenarios():
        scen = get_temporal_scenario(name)
        assert scen.name == name
        assert not scen.is_static
    assert TemporalScenario().is_static
    assert TemporalScenario(resample_every=10).is_static  # keep = 1.0
    s = TemporalScenario(burst_down=0.1, burst_up=0.3)
    assert abs(s.stationary_bad - 0.25) < 1e-12
    assert abs(s.mean_burst_len - 1 / 0.3) < 1e-12
    s = TemporalScenario(leave=0.1, rejoin=0.3)
    assert abs(s.stationary_down - 0.25) < 1e-12


def test_gilbert_elliott_stationary_occupancy():
    """Empirical bad-state occupancy over a long scan matches the chain's
    stationary law, and the one-step persistence P[bad -> bad] matches
    1 - burst_up (the burstiness i.i.d. draws cannot produce)."""
    scen = TemporalScenario(name="ge", burst_down=0.1, burst_up=0.25, seed=3)
    topo = build_topology("ring", 10)
    arrays = make_scenario_arrays(topo, scen)
    bad, _ = _scan_chain(scen, arrays, 3000)  # [T, m, d]
    valid = np.asarray(arrays.valid)
    occ = bad[:, valid].mean()
    assert abs(occ - scen.stationary_bad) < 0.03, (occ, scen.stationary_bad)
    prev, cur = bad[:-1, valid], bad[1:, valid]
    stay_bad = (prev & cur).sum() / max(prev.sum(), 1)
    assert abs(stay_bad - (1.0 - scen.burst_up)) < 0.03, stay_bad
    # the i.i.d. chain at the same occupancy would persist at ~28.6%
    assert stay_bad > scen.stationary_bad + 0.2


def test_session_stationary_occupancy():
    """Node session chain: stationary down-fraction and geometric session
    persistence (P[down -> down] = 1 - rejoin)."""
    scen = TemporalScenario(name="sess", leave=0.1, rejoin=0.3, seed=4)
    topo = build_topology("ring", 32)
    arrays = make_scenario_arrays(topo, scen)
    _, down = _scan_chain(scen, arrays, 2000)  # [T, m]
    occ = down.mean()
    assert abs(occ - scen.stationary_down) < 0.02, (occ, scen.stationary_down)
    stay_down = (down[:-1] & down[1:]).sum() / max(down[:-1].sum(), 1)
    assert abs(stay_down - (1.0 - scen.rejoin)) < 0.03, stay_down


def test_straggler_session_stationary_occupancy():
    """Markov straggler sessions: stationary late-fraction and geometric
    session persistence (P[late -> late] = 1 - straggle_off) — the
    burstiness the i.i.d. straggler draw cannot produce."""
    scen = TemporalScenario(
        name="ss", straggle_on=0.1, straggle_off=0.25, staleness=2, seed=9
    )
    assert abs(scen.stationary_late - 0.1 / 0.35) < 1e-12
    topo = build_topology("ring", 32)
    arrays = make_scenario_arrays(topo, scen)
    ts = temporal_state_init(scen, arrays)

    def body(t, k):
        t2, _, _, _ = advance(scen, arrays, t, k)
        return t2, t2.late

    _, late = jax.jit(
        lambda t0: jax.lax.scan(body, t0, jnp.arange(2000))
    )(ts)
    late = np.asarray(late)
    occ = late.mean()
    assert abs(occ - scen.stationary_late) < 0.02, (occ, scen.stationary_late)
    stay = (late[:-1] & late[1:]).sum() / max(late[:-1].sum(), 1)
    assert abs(stay - (1.0 - scen.straggle_off)) < 0.03, stay
    assert stay > scen.stationary_late + 0.2  # genuinely bursty


def test_straggler_sessions_degenerate_to_iid_bitwise():
    """straggle_off = 1 - straggle_on forgets the session state: every
    realization equals the i.i.d. straggler Scenario draw bitwise (same
    uniform region), pinning the two paths to one PRNG layout."""
    s, seed = 0.4, 11
    topo = build_topology("erdos_renyi", 12, p=0.5, seed=2)
    iid = Scenario(name="i", straggler=s, seed=seed)
    tmp = TemporalScenario(
        name="t", straggle_on=s, straggle_off=1.0 - s, staleness=0, seed=seed
    )
    arrays = make_scenario_arrays(topo, iid)
    ts = temporal_state_init(tmp, arrays)
    saw_straggle = 0
    for k in range(6):
        r_iid = realize(iid, arrays, k)
        ts, r_tmp, delayed, tau = advance(tmp, arrays, ts, k)
        assert not bool(delayed.any()) and not bool(tau.any())
        for field in ("edge_alive", "alive", "participating", "weights"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_tmp, field)),
                np.asarray(getattr(r_iid, field)), err_msg=f"{field}@{k}",
            )
        saw_straggle += int((~np.asarray(r_tmp.participating)).sum())
    assert saw_straggle > 0


def test_degenerate_markov_matches_iid_bitwise():
    """With burst_up = 1 − burst_down and rejoin = 1 − leave the chains
    forget their state: every temporal mask equals the i.i.d. `Scenario`
    draw bitwise — the anchor that ties the two realization paths to one
    PRNG layout."""
    e, c, s, seed = 0.3, 0.2, 0.4, 7
    topo = build_topology("erdos_renyi", 12, p=0.5, seed=1)
    iid = Scenario(name="i", edge_drop=e, churn=c, straggler=s, seed=seed)
    tmp = TemporalScenario(
        name="t", burst_down=e, burst_up=1.0 - e,
        leave=c, rejoin=1.0 - c, straggler=s, staleness=0, seed=seed,
    )
    arrays = make_scenario_arrays(topo, iid)
    ts = temporal_state_init(tmp, arrays)
    for k in range(6):
        r_iid = realize(iid, arrays, k)
        ts, r_tmp, delayed, tau = advance(tmp, arrays, ts, k)
        assert not bool(delayed.any()) and not bool(tau.any())
        for field in ("edge_alive", "alive", "participating", "weights",
                      "directed_edges"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_tmp, field)),
                np.asarray(getattr(r_iid, field)), err_msg=f"{field}@{k}",
            )


def test_staleness_zero_bit_identical_to_iid_straggler_path():
    """staleness=0 keeps the current straggler semantics exactly: a plain
    straggler TemporalScenario and the i.i.d. Scenario produce
    bit-identical parameters step by step (eager mode on both paths)."""
    m, n = 8, 16
    topo = build_topology("erdos_renyi", m, p=0.6, seed=2)
    batch, grad_fn = _linreg(m, n)
    b_iid = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=0.1),
        scenario=Scenario(name="i", straggler=0.4, seed=3),
    )
    b_tmp = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=0.1),
        scenario=TemporalScenario(name="t", straggler=0.4, staleness=0, seed=3),
    )
    assert b_tmp.temporal and not b_iid.temporal
    s_iid = b_iid.init(jax.random.PRNGKey(0), jnp.zeros((m, n)))
    s_tmp = b_tmp.init(jax.random.PRNGKey(0), jnp.zeros((m, n)))
    aux = b_tmp.aux_init(s_tmp)
    for k in range(5):
        s_iid, m_iid = b_iid.step(s_iid, batch, k)
        s_tmp, m_tmp, aux = b_tmp.step(s_tmp, batch, k, aux)
        np.testing.assert_array_equal(
            np.asarray(s_iid.params), np.asarray(s_tmp.params), err_msg=str(k)
        )
        assert float(m_iid["wire_bits"]) == float(m_tmp["wire_bits"])
        assert "stale_hist" not in m_tmp  # ring-free program, iid schema


def test_temporal_realizations_doubly_stochastic_delayed_participate():
    """Every temporal realization is symmetric doubly stochastic; delayed
    stragglers keep participating (row != identity possible), while
    churned and over-stale nodes self-loop at exactly 1."""
    scen = TemporalScenario(
        name="t", burst_down=0.15, burst_up=0.3, leave=0.2, rejoin=0.4,
        straggler=0.5, staleness=2, seed=6,
    )
    topo = build_topology("erdos_renyi", 12, p=0.5, seed=0)
    arrays = make_scenario_arrays(topo, scen)
    ts = temporal_state_init(scen, arrays)
    saw_delayed = saw_over = 0
    for k in range(10):
        ts, r, delayed, tau = advance(scen, arrays, ts, k)
        b = np.asarray(realization_matrix(arrays, r), np.float64)
        np.testing.assert_allclose(b.sum(axis=0), 1.0, atol=1e-5)
        np.testing.assert_allclose(b.sum(axis=1), 1.0, atol=1e-5)
        np.testing.assert_allclose(b, b.T, atol=1e-7)
        assert b.min() >= 0.0
        dl = np.asarray(delayed)
        part = np.asarray(r.participating)
        assert np.all(part[dl])          # delayed nodes participate
        assert np.all(np.asarray(tau)[dl] >= 1)
        assert np.all(np.asarray(tau) <= scen.staleness)  # bounded
        over = np.asarray(ts.age) > scen.staleness        # past the bound
        saw_delayed += int(dl.sum())
        saw_over += int(over.sum())
        for i in np.nonzero(~part)[0]:
            assert b[i, i] == 1.0
    assert saw_delayed > 0 and saw_over > 0


def test_stale_mixing_matches_hand_reference():
    """One-step conformance of the bounded-staleness exchange: realized
    matrix x ring-substituted stack, + each delayed node's private
    innovation, with churned nodes frozen — reproduced by hand from the
    same chain and compared against the wrapped step."""
    m, n = 10, 12
    scen = TemporalScenario(
        name="t", burst_down=0.2, burst_up=0.4, leave=0.2, rejoin=0.5,
        straggler=0.5, staleness=2, seed=5,
    )
    topo = build_topology("erdos_renyi", m, p=0.5, seed=3)
    bound = ALG.get_algorithm("dpsgd").bind(
        _zero_grad_fn, topo, ALG.DPSGDHp(lr=0.3), scenario=scen
    )
    rng = np.random.default_rng(1)
    stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    state = bound.init(jax.random.PRNGKey(0), stacked)
    aux = bound.aux_init(state)
    batch = {"x": jnp.zeros((m, 2), jnp.float32)}

    arrays = bound.scen_arrays
    ts = temporal_state_init(scen, arrays)
    ring = np.broadcast_to(np.asarray(stacked), (2, m, n)).copy()
    saw_tau2 = 0
    for k in range(8):
        ts, r, delayed, tau = advance(scen, arrays, ts, k)
        x = np.asarray(state.params)
        slot = np.mod(k - np.asarray(tau), scen.staleness)
        x_eff = np.where(
            np.asarray(delayed)[:, None], ring[slot, np.arange(m)], x
        )
        bmat = np.asarray(realization_matrix(arrays, r))
        expected = np.einsum("ji,jn->in", bmat, x_eff)
        expected += np.where(np.asarray(delayed)[:, None], x - x_eff, 0.0)
        expected = np.where(np.asarray(r.alive)[:, None], expected, x)
        state, metrics, aux = bound.step(state, batch, k, aux)
        np.testing.assert_allclose(
            np.asarray(state.params), expected, rtol=1e-5, atol=1e-6,
            err_msg=f"step {k}",
        )
        hist = np.asarray(metrics["stale_hist"])
        assert hist.sum() == np.asarray(r.participating).sum()
        assert hist[1:].sum() == np.asarray(delayed).sum()
        saw_tau2 += int((np.asarray(tau) == 2).sum())
        ring[k % scen.staleness] = x
    assert saw_tau2 > 0  # the ring actually served a 2-step-old snapshot


STALE = TemporalScenario(
    name="stale", burst_down=0.1, burst_up=0.3, leave=0.1, rejoin=0.4,
    straggler=0.4, staleness=3, seed=1,
)


@pytest.mark.parametrize("name", tuple(ALG.list_algorithms()))
def test_stale_mixing_preserves_invariants_all_algorithms(name):
    """Bounded-staleness runs keep the registry-wide zero-gradient
    invariants: the five doubly-stochastic gossip algorithms preserve the
    per-leaf global mean from heterogeneous parameters, and every
    algorithm (PaME included — PME is receiver-normalized, so its
    guarantee is the fixed point) preserves the global mean from
    identical parameters, with the memory-free algorithms additionally
    pinning every node (CHOCO/BEER surrogates and NIDS's correction
    memory desync under churn, redistributing mean-preservingly)."""
    m, n = M, 12
    topo = build_topology("erdos_renyi", m, p=0.5, seed=0)
    hps = {
        "pame": ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0),
        "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=1 << 20),
    }.get(name)
    bound = ALG.get_algorithm(name).bind(
        _zero_grad_fn, topo, hps, scenario=STALE
    )
    batch = {"x": jnp.zeros((m, 2), jnp.float32)}
    rng = np.random.default_rng(2)
    atol = 1e-4 if name == "anq_nids" else 1e-5

    if name != "pame":  # heterogeneous global-mean preservation
        stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        state = bound.init(jax.random.PRNGKey(1), stacked, batch)
        aux = bound.aux_init(state)
        for k in range(6):
            state, _, aux = bound.step(state, batch, k, aux)
        np.testing.assert_allclose(
            np.asarray(bound.params_of(state)).mean(axis=0),
            np.asarray(stacked).mean(axis=0), atol=atol,
        )

    # identical parameters: global mean pinned for everyone; per-node
    # fixed point for the memory-free algorithms (stale copy == fresh)
    w0 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    state, _ = bound.run(
        jax.random.PRNGKey(0), w0, m, lambda k: batch, 4,
        tol_std=0.0, chunk_size=2,
    )
    out = np.asarray(bound.params_of(state))
    np.testing.assert_allclose(
        out.mean(axis=0), np.asarray(w0), atol=max(atol, 2e-5)
    )
    if name in ("pame", "dpsgd", "dfedsam"):
        np.testing.assert_allclose(
            out, np.broadcast_to(np.asarray(w0), out.shape),
            atol=max(atol, 2e-5),
        )


def test_temporal_host_equals_scan_and_chunk_invariance():
    """Acceptance: host and scan drivers produce identical trajectories on
    a fixed-seed temporal scenario (the Markov state and the staleness
    ring ride the scan carry), and the scan trajectory is invariant to
    the chunk size (the aux carry survives chunk boundaries)."""
    m, n = M, 16
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn = _linreg(m, n)
    scen = get_temporal_scenario("markov_harsh")
    bound = ALG.get_algorithm("choco").bind(
        grad_fn, topo, ALG.ChocoHp(lr=0.05), scenario=scen
    )
    outs = {}
    for tag, kwargs in (
        ("host", dict(driver="host")),
        ("scan2", dict(driver="scan", chunk_size=2)),
        ("scan4", dict(driver="scan", chunk_size=4)),
    ):
        _, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 8,
            tol_std=0.0, **kwargs,
        )
        outs[tag] = hist
    for tag in ("scan2", "scan4"):
        np.testing.assert_allclose(
            outs[tag]["loss"], outs["host"]["loss"], rtol=1e-5, atol=1e-7
        )
        assert outs[tag]["wire_bits"] == outs["host"]["wire_bits"]
        assert outs[tag]["alive_nodes"] == outs["host"]["alive_nodes"]
        assert outs[tag]["stale_nodes"] == outs["host"]["stale_nodes"]
        assert outs[tag]["staleness_hist"] == outs["host"]["staleness_hist"]
    hist = outs["scan4"]
    assert len(hist["staleness_hist"]) == scen.staleness + 1
    assert sum(hist["staleness_hist"]) > 0
    assert hist["wire_bits_total"] == sum(hist["wire_bits"])


def test_mobility_resampling_epochs():
    """Mobility: the active edge subset is constant within an epoch and is
    redrawn across epochs."""
    scen = TemporalScenario(
        name="mob", resample_every=4, mobility_keep=0.5, seed=2
    )
    topo = build_topology("erdos_renyi", 12, p=0.6, seed=0)
    arrays = make_scenario_arrays(topo, scen)
    ts = temporal_state_init(scen, arrays)
    masks = []
    for k in range(12):
        ts, r, _, _ = advance(scen, arrays, ts, k)
        masks.append(np.asarray(r.edge_alive))
    for e0 in range(0, 12, 4):
        for k in range(e0 + 1, e0 + 4):
            np.testing.assert_array_equal(masks[k], masks[e0])
    diffs = sum(
        int(not np.array_equal(masks[a], masks[b]))
        for a, b in ((0, 4), (4, 8), (0, 8))
    )
    assert diffs >= 2  # epochs actually resample
    # realized matrices stay doubly stochastic under resampling
    b = np.asarray(realization_matrix(arrays, r), np.float64)
    np.testing.assert_allclose(b.sum(axis=0), 1.0, atol=1e-5)
