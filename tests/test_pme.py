"""PME mechanism: Theorem 1 unbiasedness, boundedness, mask invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pme


def test_coordinate_masks_exact_cardinality():
    key = jax.random.PRNGKey(0)
    masks = pme.sample_coordinate_masks(key, m=6, n=50, s=7, mode="exact")
    assert masks.shape == (6, 50)
    assert np.all(np.asarray(masks.sum(axis=1)) == 7)


def test_paper_worked_example():
    """The exact worked example from Sec. III-B of the paper."""
    w_i = jnp.array([2.0, 8.0, 3.0, 6.0])
    w = jnp.stack(
        [
            w_i,                                  # receiver i = node 0
            jnp.array([2.0, 8.0, 1.0, 4.0]),      # node 2 in the paper
            jnp.array([4.0, 7.0, 2.0, 5.0]),      # node 4
            jnp.array([3.0, 6.0, 0.0, 6.0]),      # node 5 (note the real 0!)
        ]
    )
    masks = jnp.array(
        [
            [False, False, False, False],
            [True, False, False, True],   # T_2 = {1, 4}
            [False, False, True, True],   # T_4 = {3, 4}
            [False, False, True, True],   # T_5 = {3, 4}
        ]
    )
    a = jnp.zeros((4, 4)).at[1, 0].set(1.0).at[2, 0].set(1.0).at[3, 0].set(1.0)
    out = pme.pme_average(w, masks, a)
    # paper: v_bar = [2, 8, 1, 5]  ('*' = transmitted true zero participates)
    np.testing.assert_allclose(np.asarray(out[0]), [2.0, 8.0, 1.0, 5.0], atol=1e-6)


def test_theorem1_unbiased_montecarlo():
    """E[v_bar | lambda>0] = mean(w);  E[v_tilde] = (s/n) mean(w)."""
    q, n, s = 5, 8, 3
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((q, n)), jnp.float32)
    a = jnp.ones((q, q)) - jnp.eye(q)
    a = a.at[:, 1:].set(0.0)  # single receiver 0, neighbors = everyone else
    a = jnp.zeros((q, q)).at[1:, 0].set(1.0)
    target = np.asarray(w[1:]).mean(axis=0)

    trials = 4000
    acc = np.zeros(n)
    cnt_pos = np.zeros(n)
    acc_naive = np.zeros(n)
    for t in range(trials):
        key = jax.random.PRNGKey(t)
        masks = pme.sample_coordinate_masks(key, q, n, s, mode="exact")
        masks = masks.at[0].set(False)  # receiver transmits nothing
        vbar = np.asarray(pme.pme_average(w, masks, a)[0])
        lam = np.asarray(masks[1:].sum(axis=0))
        sel = lam > 0
        acc[sel] += vbar[sel]
        cnt_pos[sel] += 1
        vnaive = np.asarray(pme.naive_average(w, masks, a)[0])
        acc_naive += vnaive
    est = acc / np.maximum(cnt_pos, 1)
    np.testing.assert_allclose(est, target, atol=0.12)  # unbiased
    est_naive = acc_naive / trials
    np.testing.assert_allclose(est_naive, (s / n) * target, atol=0.12)  # biased by s/n


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(3, 8),
    n=st.integers(4, 32),
    seed=st.integers(0, 10_000),
)
def test_pme_output_bounded_by_inputs(m, n, seed):
    """Lemma 3 ingredient: every PME output coord is a convex combination of
    input coords => ||v_bar||_inf <= ||W||_inf."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    masks = jnp.asarray(rng.random((m, n)) < rng.uniform(0.05, 0.9))
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.5) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    out = pme.pme_average(w, masks, a)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(w))) + 1e-5


def test_pme_no_communication_returns_self():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((4, 10)), jnp.float32)
    masks = jnp.ones((4, 10), bool)
    a = jnp.zeros((4, 4))  # nobody selected (k not in K_i)
    out = pme.pme_average(w, masks, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w))


def test_pme_pytree_modes_agree_in_expectation():
    rng = np.random.default_rng(2)
    m, n = 6, 40
    tree = {"a": jnp.asarray(rng.standard_normal((m, 5, 8)), jnp.float32)}
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.6) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    outs = {}
    for mode in ("exact", "bernoulli"):
        acc = np.zeros((m, 5, 8))
        for t in range(300):
            out = pme.pme_average_pytree(
                jax.random.PRNGKey(t), tree, a, p=0.4, mode=mode
            )
            acc += np.asarray(out["a"])
        outs[mode] = acc / 300
    np.testing.assert_allclose(outs["exact"], outs["bernoulli"], atol=0.15)


def test_message_bits_eq8():
    # paper: 63 s + n bits, and the example 1.63e4 << 6.4e5 for n = 100 s = 1e4
    assert pme.message_bits(100, 10_000) == 63 * 100 + 10_000
    assert pme.message_bits(100, 10_000) < 64 * 10_000 / 30


def test_neighbor_selection_counts_and_validity():
    from repro.core.topology import build_topology
    from repro.core.pame import PaMEConfig, make_topology_arrays

    topo = build_topology("erdos_renyi", 10, p=0.6, seed=0)
    cfg = PaMEConfig(nu=0.4)
    arrs = make_topology_arrays(topo, cfg)
    comm = jnp.ones((10,), bool)
    a = pme.sample_neighbor_selection(
        jax.random.PRNGKey(0), arrs.nbrs, arrs.valid, arrs.t, comm
    )
    a_np = np.asarray(a)
    # column i has exactly t_i senders, all true neighbors of i
    for i in range(10):
        assert a_np[:, i].sum() == int(arrs.t[i])
        senders = np.nonzero(a_np[:, i])[0]
        for j in senders:
            assert topo.adjacency[j, i] == 1
    # non-communicating receiver -> empty column
    comm2 = comm.at[3].set(False)
    a2 = np.asarray(
        pme.sample_neighbor_selection(
            jax.random.PRNGKey(0), arrs.nbrs, arrs.valid, arrs.t, comm2
        )
    )
    assert a2[:, 3].sum() == 0
