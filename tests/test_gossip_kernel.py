"""Conformance suite for the fused Pallas gossip kernel (impl="pallas").

Pins the three-way equivalence slots == segsum == pallas(interpret) over
random topologies and the known degenerate graphs, the multi-term /
shared-weight semantics, lane batching under vmap, the PME padded path,
and the loud-validation contract shared by every impl entry point.

Tolerance discipline: the kernel contracts over senders with one MXU
matmul, so its reduction order differs from the slots chain — continuous
data is compared at tight fp tolerance (like segsum), while
integer-valued data (sums < 2^24, exactly representable in f32) must
match BITWISE across all three impls.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_topology
from repro.core import mixing
from repro.core.mixing import (
    IMPLS, PaddedMixing, default_impl, gather_terms, make_mixer,
)
from repro.kernels.gossip.ops import gather_terms_pallas
from repro.kernels.gossip.ref import gather_terms_ref

ATOL = 1e-5


def _assert_impls_agree(pm, tree, atol=ATOL, bitwise=False):
    outs = {
        impl: jax.tree_util.tree_leaves(
            mixing.mix_padded(pm, tree, impl=impl)
        )
        for impl in IMPLS
    }
    for impl in ("segsum", "pallas"):
        for a, b in zip(outs["slots"], outs[impl]):
            if bitwise:
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b), err_msg=impl
                )
            else:
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), atol=atol, err_msg=impl
                )


# ---------------------------------------------------------------------------
# property-style equivalence over random topologies
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 24),
    kind=st.sampled_from(["ring", "regular", "erdos_renyi", "star"]),
    n=st.integers(1, 70),
    seed=st.integers(0, 10_000),
)
def test_impls_agree_random_topologies(m, kind, n, seed):
    kwargs = {}
    if kind == "regular":
        kwargs = dict(degree=min(4, m - 1), seed=seed)
    elif kind == "erdos_renyi":
        kwargs = dict(p=0.5, seed=seed)
    elif kind == "star" and m < 3:
        m = 3
    topo = build_topology(kind, m, **kwargs)
    pm = make_mixer(topo, "sparse").pm
    rng = np.random.default_rng(seed)
    tree = {
        "v": jnp.asarray(rng.standard_normal((m,)), jnp.float32),
        "w": jnp.asarray(rng.standard_normal((m, n)), jnp.float32),
    }
    _assert_impls_agree(pm, tree)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(3, 16), seed=st.integers(0, 1000))
def test_impls_agree_bitwise_on_integer_data(m, seed):
    """Metropolis weights are dyadic on regular graphs only, so use a
    uniform-weight table (1/2^3) and small-integer data: every partial
    sum is exactly representable, making any impl disagreement a real
    indexing/masking bug, not reduction order."""
    rng = np.random.default_rng(seed)
    k = 4
    nbrs = jnp.asarray(rng.integers(0, m, (m, k)), jnp.int32)
    w = jnp.full((m, k), 0.125, jnp.float32)
    x = jnp.asarray(rng.integers(-64, 64, (m, 9)).astype(np.float32))
    pm = PaddedMixing(nbrs, w, jnp.zeros((m, k), bool), None)
    _assert_impls_agree(pm, x, bitwise=True)


# ---------------------------------------------------------------------------
# degenerate graphs
# ---------------------------------------------------------------------------
def _poison_pad(pm):
    """NaN-poison the padding weights: every impl must mask them out."""
    assert pm.pad is not None and bool(pm.pad.sum() > 0)
    return pm.with_weights(jnp.where(pm.pad, jnp.nan, pm.w))


def test_star_hub_and_poisoned_padding():
    m = 9
    topo = build_topology("star", m)
    pm = make_mixer(topo, "sparse").pm
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, 33)), jnp.float32)
    _assert_impls_agree(pm, x)
    # leaf rows are heavily padded against the hub row's full table —
    # poisoned padding weights must not leak through any impl.
    poisoned = _poison_pad(pm)
    out = gather_terms_pallas(poisoned.nbrs, [(poisoned.w, x)], pad=poisoned.pad)[0]
    assert np.isfinite(np.asarray(out)).all()
    ref = mixing.mix_padded(pm, x, impl="slots")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)


def test_isolated_node():
    """An all-padding row (isolated node: only the self slot carries
    weight 1) must pass its own value through unchanged, bitwise, in
    every impl — same fixture as the segsum degenerate test."""
    m = 4
    nbrs = jnp.asarray([[1, 0], [0, 1], [0, 2], [3, 3]], jnp.int32)
    w = jnp.asarray(
        [[0.5, 0.5], [0.5, 0.5], [1.0, 0.0], [1.0, 0.0]], jnp.float32
    )
    is_self = jnp.asarray(
        [[False, True], [False, True], [False, True], [True, False]]
    )
    pad = jnp.asarray(
        [[False, False], [False, False], [False, False], [False, True]]
    )
    pm = PaddedMixing(nbrs, w, is_self, pad)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((m, 11)), jnp.float32)
    _assert_impls_agree(pm, x)
    for impl in IMPLS:
        out = mixing.mix_padded(pm, x, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(out[3]), np.asarray(x[3]), err_msg=impl
        )
    # poisoned padding slot: the kernel's dead-slot masking must hold
    out_bad = mixing.mix_padded(pm.with_weights(jnp.where(pad, jnp.nan, w)),
                                x, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(out_bad), np.asarray(mixing.mix_padded(pm, x, impl="pallas")),
        atol=0.0,
    )


def test_m2_minimal_graph():
    topo = build_topology("complete", 2)
    pm = make_mixer(topo, "sparse").pm
    x = jnp.asarray([[1.0, 2.0], [3.0, 5.0]], jnp.float32)
    _assert_impls_agree(pm, x, bitwise=False)


def test_fully_dropped_all_weights_zero():
    """All-zero weight table (every message dropped): exact zeros out of
    every impl — the kernel's masked scatter must not fabricate values."""
    m, k = 5, 3
    nbrs = jnp.asarray(np.random.default_rng(0).integers(0, m, (m, k)), jnp.int32)
    w = jnp.zeros((m, k), jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((m, 8)), jnp.float32)
    for impl in IMPLS:
        out = gather_terms(nbrs, [(w, x)], impl=impl)[0]
        np.testing.assert_array_equal(np.asarray(out), 0.0, err_msg=impl)


# ---------------------------------------------------------------------------
# multi-term semantics + shared-weight dedup
# ---------------------------------------------------------------------------
def test_multi_term_single_walk_matches_ref():
    """Distinct weight tables per term, plus a term sharing table 0 —
    exercising the kernel's shared-S build — against the dense scatter
    reference and the slots chain."""
    m, k = 12, 5
    rng = np.random.default_rng(3)
    nbrs = jnp.asarray(rng.integers(0, m, (m, k)), jnp.int32)
    w0 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    x0 = jnp.asarray(rng.standard_normal((m, 20)), jnp.float32)
    x1 = jnp.asarray(rng.standard_normal((m, 20)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((m, 20)), jnp.float32)
    terms = [(w0, x0), (w1, x1), (w0, x2)]  # term 2 shares w0
    got = gather_terms_pallas(nbrs, terms)
    ref = gather_terms_ref(nbrs, terms)
    chain = gather_terms(nbrs, terms, impl="slots")
    for g, r, c in zip(got, ref, chain):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=ATOL)
        np.testing.assert_allclose(np.asarray(g), np.asarray(c), atol=ATOL)


def test_mixed_leaf_ranks_bucketed():
    """[m], [m, n] and [m, a, b] leaves in one call — the ops wrapper
    buckets by trailing size and restores shapes."""
    m, k = 7, 3
    rng = np.random.default_rng(5)
    nbrs = jnp.asarray(rng.integers(0, m, (m, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    xs = [
        jnp.asarray(rng.standard_normal((m,)), jnp.float32),
        jnp.asarray(rng.standard_normal((m, 6)), jnp.float32),
        jnp.asarray(rng.standard_normal((m, 2, 3)), jnp.float32),
    ]
    got = gather_terms_pallas(nbrs, [(w, x) for x in xs])
    want = gather_terms(nbrs, [(w, x) for x in xs], impl="slots")
    for g, r, x in zip(got, want, xs):
        assert g.shape == x.shape
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=ATOL)


# ---------------------------------------------------------------------------
# lane batching (bind_batched rides vmap over the step)
# ---------------------------------------------------------------------------
def test_vmap_lane_batching_matches_per_lane():
    m, lanes = 16, 5
    topo = build_topology("regular", m, degree=6, seed=0)
    mx = make_mixer(topo, "sparse", impl="pallas")
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.standard_normal((lanes, m, 29)), jnp.float32)
    batched = jax.vmap(mx.mix)(xs)
    per_lane = jnp.stack([mx.mix(x) for x in xs])
    np.testing.assert_allclose(
        np.asarray(batched), np.asarray(per_lane), atol=ATOL
    )
    slots_ref = jnp.stack(
        [mixing.mix_padded(mx.pm, x, impl="slots") for x in xs]
    )
    np.testing.assert_allclose(
        np.asarray(batched), np.asarray(slots_ref), atol=ATOL
    )


def test_receiver_grid_multiple_tiles():
    """m spanning several receiver-row blocks (block_m < m), non-divisible."""
    m, k = 37, 4
    rng = np.random.default_rng(9)
    nbrs = jnp.asarray(rng.integers(0, m, (m, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, 130)), jnp.float32)
    got = gather_terms_pallas(nbrs, [(w, x)], block_m=16, block_n=64)[0]
    want = gather_terms(nbrs, [(w, x)], impl="slots")[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


# ---------------------------------------------------------------------------
# PME padded path + dense-exchange kernel routing under the env gate
# ---------------------------------------------------------------------------
def test_pme_padded_pallas_matches_slots():
    from repro.core.pme import (
        pme_average_pytree_padded, sample_neighbor_selection_padded,
    )

    m = 10
    topo = build_topology("erdos_renyi", m, p=0.5, seed=4)
    nbrs, valid = (jnp.asarray(v) for v in topo.neighbor_matrix_padded())
    t = jnp.maximum(
        (0.6 * valid.sum(axis=1)).astype(jnp.int32), 1
    )
    sel = sample_neighbor_selection_padded(
        jax.random.PRNGKey(0), nbrs, valid, t, jnp.ones(m, bool)
    )
    params = {
        "w": jnp.asarray(
            np.random.default_rng(6).standard_normal((m, 4, 8)), jnp.float32
        ),
    }
    outs = {
        impl: pme_average_pytree_padded(
            jax.random.PRNGKey(1), params, nbrs, sel, 0.5,
            pad=~valid, impl=impl,
        )
        for impl in IMPLS
    }
    for impl in ("segsum", "pallas"):
        np.testing.assert_allclose(
            np.asarray(outs[impl]["w"]), np.asarray(outs["slots"]["w"]),
            atol=ATOL, err_msg=impl,
        )


def test_env_gate_routes_dense_exchange_through_kernel(monkeypatch):
    """REPRO_GOSSIP_IMPL=pallas must (a) win default_impl and (b) route
    the exact-mode dense exchange through the pme_average kernel with
    unchanged results."""
    from repro.core.pme import pme_average_pytree

    m, n = 8, 40
    rng = np.random.default_rng(7)
    a_sel = jnp.asarray((rng.random((m, m)) < 0.6).astype(np.float32))
    params = {"w": jnp.asarray(rng.standard_normal((m, n)), jnp.float32)}
    key = jax.random.PRNGKey(3)
    monkeypatch.delenv("REPRO_GOSSIP_IMPL", raising=False)
    base = pme_average_pytree(key, params, a_sel, 0.5, mode="exact")
    monkeypatch.setenv("REPRO_GOSSIP_IMPL", "pallas")
    assert default_impl() == "pallas"
    routed = pme_average_pytree(key, params, a_sel, 0.5, mode="exact")
    np.testing.assert_allclose(
        np.asarray(routed["w"]), np.asarray(base["w"]), atol=ATOL
    )


# ---------------------------------------------------------------------------
# loud validation everywhere (satellite: gather_terms used to fall through)
# ---------------------------------------------------------------------------
def test_unknown_impl_fails_loudly_everywhere(monkeypatch):
    m = 4
    topo = build_topology("ring", m)
    nbrs = jnp.zeros((m, 2), jnp.int32)
    terms = [(jnp.zeros((m, 2)), jnp.zeros((m, 3)))]
    with pytest.raises(ValueError, match="bogus"):
        gather_terms(nbrs, terms, impl="bogus")
    with pytest.raises(ValueError, match="bogus"):
        make_mixer(topo, "sparse", impl="bogus")
    monkeypatch.setenv("REPRO_GOSSIP_IMPL", "bogus")
    with pytest.raises(ValueError, match="REPRO_GOSSIP_IMPL"):
        default_impl()


def test_env_accepts_every_registered_impl(monkeypatch):
    for impl in IMPLS:
        monkeypatch.setenv("REPRO_GOSSIP_IMPL", impl)
        assert default_impl() == impl
