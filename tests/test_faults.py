"""Message-level fault-injection conformance suite.

Pins the properties `repro.core.faults` must guarantee:

  * `FaultModel` validation, presets, static gating (a zero-rate model
    binds to the fault-free program — zero-loss runs are bit-identical
    to the pre-fault-layer path);
  * the faulted realization is *row*-stochastic by construction under
    arbitrary asymmetric per-direction loss, with the column-sum defect
    reported exactly and accumulated into the mean-drift tracker;
  * the Gilbert–Elliott lossy-link chain matches its stationary law and
    burst persistence;
  * crashed nodes freeze bitwise (the local checkpoint they rejoin
    from) and catch up by mixing again after rejoin;
  * identical parameters are a per-node fixed point for the
    row-stochastic mixers (PaME / D-PSGD / DFedSAM) under arbitrary
    loss — the structural graceful-degradation invariant — while
    direct parameter mixing under asymmetric loss leaks the global
    mean by exactly the tracked column defect;
  * host and scan drivers agree on a fault-injected trajectory (the
    fault Markov state and delay ring ride the scan carry), invariant
    to the chunk size;
  * m=2 and loss=1 (fully partitioned) edge cases stay finite and
    degenerate to local-only updates;
  * a seeded degradation-regression guard: PaME's final objective at
    20% loss stays within a pinned factor of the fault-free run (the
    check CI runs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core.faults import (
    FAULT_PRESETS,
    FaultModel,
    advance_faults,
    fault_matrix,
    fault_state_init,
    get_fault_model,
    list_fault_models,
)
from repro.core.scenarios import Scenario, make_scenario_arrays, sample_masks
from repro.core.topology import build_topology

M = 8


def _zero_grad_fn(w, batch, key):
    del batch, key
    return jnp.zeros(()), jax.tree_util.tree_map(jnp.zeros_like, w)


def _linreg(m, n, spn=32, seed=0):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    batch = (jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32))

    def grad_fn(w, b, key):
        aa, yy = b
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    return batch, grad_fn


def _static_arrays(topo):
    scen = Scenario(name="static")
    return scen, make_scenario_arrays(topo, scen)


def test_fault_model_validation_and_presets():
    with pytest.raises(ValueError, match="probability"):
        FaultModel(loss=1.5)
    with pytest.raises(ValueError, match="max_delay"):
        FaultModel(max_delay=-1)
    with pytest.raises(ValueError, match="max_delay"):
        FaultModel(delay=0.2)  # delay without a staleness bound
    with pytest.raises(ValueError, match="permanent"):
        FaultModel(burst_down=0.1, burst_up=0.0)
    with pytest.raises(ValueError, match="permanent"):
        FaultModel(crash=0.1, rejoin=0.0)
    with pytest.raises(ValueError, match="unknown fault"):
        get_fault_model("nope")
    for name in list_fault_models():
        fm = get_fault_model(name)
        assert fm.name == name
        assert not fm.is_static
    assert FaultModel().is_static
    assert FaultModel(repair=False).is_static  # repair alone fires nothing
    fm = FaultModel(burst_down=0.1, burst_up=0.3)
    assert abs(fm.stationary_lossy - 0.25) < 1e-12
    assert set(FAULT_PRESETS) == set(list_fault_models())


def test_faulted_matrix_row_stochastic_col_defect_asymmetric():
    """Every faulted realization is row-stochastic to machine precision
    under i.i.d. per-direction loss; the reported column defect equals
    the materialized matrix's |colsum - 1| mass, `dropped` counts the
    realized-but-lost directed messages, and the drift tracker is their
    running defect sum."""
    fm = FaultModel(loss=0.3, seed=1)
    topo = build_topology("erdos_renyi", 12, p=0.5, seed=0)
    scen, arrays = _static_arrays(topo)
    key = jax.random.PRNGKey(fm.seed)
    fs = fault_state_init(fm, arrays, key)
    saw_asym = saw_drop = 0
    drift = 0.0
    for k in range(8):
        e, a, s = sample_masks(scen, arrays, k)
        fs, fr = advance_faults(fm, arrays, fs, key, k, e, a, s)
        b = np.asarray(fault_matrix(arrays, fr), np.float64)
        np.testing.assert_allclose(b.sum(axis=1), 1.0, atol=1e-6)
        assert b.min() >= 0.0
        defect = np.abs(b.sum(axis=0) - 1.0).sum()
        np.testing.assert_allclose(float(fr.col_defect), defect, atol=1e-4)
        drift += float(fr.col_defect)
        saw_asym += int(not np.allclose(b, b.T))
        saw_drop += int(fr.dropped)
        # realized-but-lost messages are exactly the dropped count
        lost = np.asarray(fr.base.edge_alive) & ~np.asarray(fr.recv_ok)
        assert int(fr.dropped) == int(lost.sum())
    assert saw_asym > 0       # per-direction draws break symmetry
    assert saw_drop > 0
    np.testing.assert_allclose(float(fs.drift), drift, rtol=1e-5)


def test_gilbert_elliott_link_occupancy_and_persistence():
    """The lossy-link burst chain matches its stationary occupancy and the
    one-step persistence P[lossy -> lossy] = 1 - burst_up."""
    fm = FaultModel(burst_down=0.1, burst_up=0.25, seed=3)
    topo = build_topology("ring", 10)
    scen, arrays = _static_arrays(topo)
    key = jax.random.PRNGKey(fm.seed)

    def body(fs, k):
        e, a, s = sample_masks(scen, arrays, k)
        fs2, _ = advance_faults(fm, arrays, fs, key, k, e, a, s)
        return fs2, fs2.link_bad

    fs0 = fault_state_init(fm, arrays, key)
    _, bad = jax.jit(
        lambda f0: jax.lax.scan(body, f0, jnp.arange(3000))
    )(fs0)
    bad = np.asarray(bad)[:, np.asarray(arrays.valid)]
    occ = bad.mean()
    assert abs(occ - fm.stationary_lossy) < 0.03, (occ, fm.stationary_lossy)
    stay = (bad[:-1] & bad[1:]).sum() / max(bad[:-1].sum(), 1)
    assert abs(stay - (1.0 - fm.burst_up)) < 0.03, stay


def test_static_fault_model_binds_to_fault_free_program():
    """Acceptance: a zero-rate FaultModel binds to the plain program — the
    same-seed run is bit-identical to the pre-fault-layer path."""
    m, n = M, 12
    topo = build_topology("erdos_renyi", m, p=0.5, seed=0)
    batch, grad_fn = _linreg(m, n)
    for name, hps in (("pame", ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01,
                                          sigma0=8.0)),
                      ("choco", ALG.ChocoHp(lr=0.05))):
        plain = ALG.get_algorithm(name).bind(grad_fn, topo, hps)
        gated = ALG.get_algorithm(name).bind(
            grad_fn, topo, hps, faults=FaultModel()
        )
        assert not gated.faulty and not gated.carries_aux
        stacked = jnp.zeros((m, n))
        s_a = plain.init(jax.random.PRNGKey(0), stacked, batch)
        s_b = gated.init(jax.random.PRNGKey(0), stacked, batch)
        for _ in range(3):
            s_a, _ = plain.step(s_a, batch)
            s_b, _ = gated.step(s_b, batch)
        np.testing.assert_array_equal(
            np.asarray(plain.params_of(s_a)), np.asarray(gated.params_of(s_b))
        )


def test_crash_freeze_bitwise_and_rejoin_catchup():
    """Crashed nodes freeze bitwise (weight-1 self-loop, state untouched =
    the local checkpoint they rejoin from); on rejoin they mix again and
    their parameters move.  Verified against an externally replayed fault
    chain (same key stream)."""
    m, n = M, 10
    fm = FaultModel(crash=0.3, rejoin=0.4, seed=5)
    topo = build_topology("erdos_renyi", m, p=0.6, seed=1)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("dpsgd").bind(
        grad_fn, topo, ALG.DPSGDHp(lr=0.1), faults=fm
    )
    assert bound.faulty and bound.carries_aux
    arrays = bound.scen_arrays
    fs = fault_state_init(fm, arrays, bound.fault_key)
    state = bound.init(jax.random.PRNGKey(0), jnp.zeros((m, n)))
    aux = bound.aux_init(state)
    prev_crashed = np.zeros(m, bool)
    saw_crash = saw_rejoin = 0
    for k in range(10):
        e, a, s = sample_masks(bound.scenario, arrays, k)
        fs, fr = advance_faults(fm, arrays, fs, bound.fault_key, k, e, a, s)
        crashed = ~np.asarray(fr.base.alive)
        prev = np.asarray(state.params)
        state, metrics, aux = bound.step(state, batch, k, aux)
        cur = np.asarray(state.params)
        np.testing.assert_array_equal(cur[crashed], prev[crashed])
        rejoined = prev_crashed & ~crashed
        for i in np.nonzero(rejoined)[0]:
            assert not np.array_equal(cur[i], prev[i])  # catching up again
        assert int(metrics["crashed_nodes"]) == int(np.asarray(fs.crashed).sum())
        saw_crash += int(crashed.sum())
        saw_rejoin += int(rejoined.sum())
        prev_crashed = crashed
    assert saw_crash > 0 and saw_rejoin > 0


@pytest.mark.parametrize("name", ["pame", "dpsgd", "dfedsam"])
def test_identical_params_pinned_under_arbitrary_loss(name):
    """The graceful-degradation invariant: row-stochastic mixers (PaME's
    count-normalized average, D-PSGD/DFedSAM under the per-receiver
    renormalized weights) hold identical parameters as a per-node fixed
    point under ANY asymmetric loss pattern — lost messages shrink the
    count / fold mass into the self slot, never skew the average."""
    m, n = M, 12
    fm = FaultModel(loss=0.3, burst_down=0.1, burst_up=0.3, crash=0.1,
                    rejoin=0.4, seed=2)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=0)
    hps = {"pame": ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0)}.get(name)
    bound = ALG.get_algorithm(name).bind(
        _zero_grad_fn, topo, hps, faults=fm
    )
    batch = {"x": jnp.zeros((m, 2), jnp.float32)}
    rng = np.random.default_rng(3)
    w0 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    state, hist = bound.run(
        jax.random.PRNGKey(0), w0, m, lambda k: batch, 6,
        tol_std=0.0, chunk_size=3,
    )
    out = np.asarray(bound.params_of(state))
    np.testing.assert_allclose(
        out, np.broadcast_to(np.asarray(w0), out.shape), atol=2e-5
    )
    assert sum(hist["dropped_msgs"]) > 0  # faults actually fired


def test_mean_drift_tracks_column_defect():
    """Direct parameter mixing under asymmetric loss leaks the global
    mean; the engine's `mean_drift` tracker is the running column-defect
    sum and grows monotonically while messages drop."""
    m, n = M, 12
    fm = FaultModel(loss=0.3, seed=4)
    topo = build_topology("erdos_renyi", m, p=0.6, seed=2)
    bound = ALG.get_algorithm("dpsgd").bind(
        _zero_grad_fn, topo, ALG.DPSGDHp(lr=0.1), faults=fm
    )
    batch = {"x": jnp.zeros((m, 2), jnp.float32)}
    rng = np.random.default_rng(1)
    stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    state = bound.init(jax.random.PRNGKey(0), stacked)
    aux = bound.aux_init(state)
    drifts, defects, dropped = [], [], []
    for k in range(6):
        state, metrics, aux = bound.step(state, batch, k, aux)
        drifts.append(float(metrics["mean_drift"]))
        defects.append(float(metrics["col_defect"]))
        dropped.append(int(metrics["dropped_msgs"]))
    np.testing.assert_allclose(drifts, np.cumsum(defects), rtol=1e-5)
    assert all(b >= a for a, b in zip(drifts, drifts[1:]))
    assert sum(dropped) > 0 and drifts[-1] > 0.0
    # the mean actually moved (zero grads: only the column defect can)
    mean0 = np.asarray(stacked).mean(axis=0)
    mean1 = np.asarray(state.params).mean(axis=0)
    assert float(np.abs(mean1 - mean0).max()) > 1e-4


def test_fault_host_equals_scan_and_chunk_invariance():
    """Host and scan drivers produce identical fault-injected trajectories
    (fault Markov state + delay ring in the scan carry), invariant to the
    chunk size — including the repair/desync accounting."""
    m, n = M, 14
    fm = FaultModel(loss=0.15, burst_down=0.1, burst_up=0.3, crash=0.1,
                    rejoin=0.4, delay=0.3, max_delay=2, seed=2)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("choco").bind(
        grad_fn, topo, ALG.ChocoHp(lr=0.05), faults=fm
    )
    outs = {}
    for tag, kwargs in (
        ("host", dict(driver="host")),
        ("scan2", dict(driver="scan", chunk_size=2)),
        ("scan4", dict(driver="scan", chunk_size=4)),
    ):
        _, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 8,
            tol_std=0.0, **kwargs,
        )
        outs[tag] = hist
    for tag in ("scan2", "scan4"):
        np.testing.assert_allclose(
            outs[tag]["loss"], outs["host"]["loss"], rtol=1e-5, atol=1e-7
        )
        for key in ("wire_bits", "repair_bits", "dropped_msgs",
                    "crashed_nodes", "stale_nodes", "col_defect"):
            np.testing.assert_allclose(
                outs[tag][key], outs["host"][key], rtol=1e-5, atol=1e-6,
                err_msg=key,
            )
        np.testing.assert_allclose(
            outs[tag]["surrogate_desync"], outs["host"]["surrogate_desync"],
            rtol=1e-4, atol=1e-6,
        )
    hist = outs["scan4"]
    assert sum(hist["dropped_msgs"]) > 0
    assert hist["wire_bits_total"] == sum(hist["wire_bits"])


def test_full_partition_and_m2_edge_cases():
    """loss=1 fully partitions the network: every row degenerates to a
    weight-1 self-loop, zero-gradient parameters are bitwise frozen, and
    PaME's count-normalized fallback keeps it finite and pinned.  The
    m=2 single-link graph runs through the same path."""
    for m in (2, 6):
        topo = build_topology("complete", m)
        fm = FaultModel(loss=1.0, seed=0)
        n = 8
        rng = np.random.default_rng(m)
        stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        bound = ALG.get_algorithm("dpsgd").bind(
            _zero_grad_fn, topo, ALG.DPSGDHp(lr=0.1), faults=fm
        )
        batch = {"x": jnp.zeros((m, 2), jnp.float32)}
        state = bound.init(jax.random.PRNGKey(0), stacked)
        aux = bound.aux_init(state)
        for k in range(3):
            state, metrics, aux = bound.step(state, batch, k, aux)
            assert int(metrics["dropped_msgs"]) == int(
                np.asarray(bound.scen_arrays.valid).sum()
            )
        np.testing.assert_array_equal(np.asarray(state.params),
                                      np.asarray(stacked))
        # PaME stays finite and pinned from identical params
        pame = ALG.get_algorithm("pame").bind(
            _zero_grad_fn, topo,
            ALG.PaMEHp(nu=0.5, p=0.5, gamma=1.01, sigma0=8.0), faults=fm,
        )
        w0 = jnp.asarray(rng.standard_normal(n), jnp.float32)
        st, hist = pame.run(
            jax.random.PRNGKey(0), w0, m, lambda k: batch, 3, tol_std=0.0
        )
        out = np.asarray(pame.params_of(st))
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(
            out, np.broadcast_to(np.asarray(w0), out.shape), atol=2e-5
        )


def test_degradation_regression_pame_seeded():
    """Seeded degradation-regression guard (run in CI): PaME's final
    objective under 20% message loss + 1% crashes stays within a pinned
    factor of the fault-free same-seed run."""
    m, n = M, 12
    topo = build_topology("erdos_renyi", m, p=0.5, seed=0)
    batch, grad_fn = _linreg(m, n, seed=1)
    hps = ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0)
    finals = {}
    for tag, fm in (
        ("clean", None),
        ("lossy", FaultModel(loss=0.2, crash=0.01, rejoin=0.3, seed=0)),
    ):
        bound = ALG.get_algorithm("pame").bind(grad_fn, topo, hps, faults=fm)
        _, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 40,
            tol_std=0.0,
        )
        finals[tag] = float(hist["loss"][-1])
    assert np.isfinite(finals["lossy"])
    # pinned tolerance: graceful degradation, not divergence
    assert finals["lossy"] <= 1.5 * finals["clean"] + 1e-2, finals
