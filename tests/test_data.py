"""Data pipeline: synthetic generators, partitioners, per-node batcher."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (
    NodeBatcher,
    SyntheticClassification,
    SyntheticTokens,
    dirichlet_partition,
    iid_partition,
    label_skew_partition,
)
from repro.data.synthetic import make_linear_regression, make_logistic_regression


def test_linear_regression_matches_paper_spec():
    a, b, w_star = make_linear_regression(8, 32, 200, seed=0)
    nnz = np.nonzero(w_star)[0]
    assert len(nnz) == 2  # 1% of 200
    assert ((0.5 <= np.abs(w_star[nnz])) & (np.abs(w_star[nnz]) <= 2.0)).all()
    assert a.shape == (8, 32, 200) and b.shape == (8, 32)


def test_logistic_regression_labels_binary():
    a, b, w_star = make_logistic_regression(4, 16, 50, seed=1)
    assert set(np.unique(b)) <= {0.0, 1.0}
    assert np.count_nonzero(w_star) == 25


def test_iid_partition_covers_everything():
    labels = np.random.default_rng(0).integers(0, 10, 333)
    parts = iid_partition(labels, 7)
    allidx = np.concatenate(parts)
    assert len(allidx) == 333 and len(np.unique(allidx)) == 333


@settings(max_examples=10, deadline=None)
@given(c=st.sampled_from([1, 3, 7, 10]), m=st.sampled_from([4, 8, 10]))
def test_label_skew_class_budget(c, m):
    ds = SyntheticClassification.make(600, (4, 4, 1), 10, seed=0)
    parts = label_skew_partition(ds.labels, m, c, seed=0)
    for p in parts:
        assert len(np.unique(ds.labels[p])) <= c
    # no index assigned twice
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(np.unique(allidx))


def test_dirichlet_partition_heterogeneity_ordering():
    """Lower beta => more skewed class distributions (on average)."""
    ds = SyntheticClassification.make(4000, (2, 2, 1), 10, seed=0)

    def mean_entropy(beta):
        parts = dirichlet_partition(ds.labels, 8, beta, seed=0)
        ents = []
        for p in parts:
            hist = np.bincount(ds.labels[p], minlength=10).astype(float)
            q = hist / hist.sum()
            q = q[q > 0]
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert mean_entropy(0.1) < mean_entropy(10.0)


def test_node_batcher_epochs_and_shapes():
    ds = SyntheticClassification.make(64, (4, 4, 1), 10, seed=0)
    parts = iid_partition(ds.labels, 4, seed=0)
    nb = NodeBatcher({"x": ds.images, "y": ds.labels}, parts, batch_size=8, seed=0)
    seen = [set() for _ in range(4)]
    for _ in range(2):  # exactly one epoch per node (16 samples / 8 batch)
        b = nb.next()
        assert b["x"].shape == (4, 8, 4, 4, 1)
        for i in range(4):
            seen[i] |= set(b["y"][i].tolist())
    # after one epoch every node has cycled its own shard
    for i in range(4):
        assert seen[i] == set(ds.labels[parts[i]].tolist())


def test_synthetic_tokens_heterogeneous():
    corpus = SyntheticTokens.make(4, 2048, 1000, seed=0)
    supports = [set(np.unique(corpus.tokens[i])) for i in range(4)]
    # Dirichlet unigram draws: different nodes see mostly different tokens
    inter = supports[0] & supports[1]
    assert len(inter) < 0.8 * min(len(supports[0]), len(supports[1]))
