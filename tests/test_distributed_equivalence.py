"""The sharded PaME train step (compressed exchange, (node, fsdp, model)
mesh) is numerically identical to the single-device step — run in a
subprocess with 8 fake devices."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.launch.mesh import mesh_axis_kwargs
    from repro.configs import get_config
    from repro.core.pame import PaMEConfig, pame_init, pame_step, make_topology_arrays
    from repro.core.topology import build_topology
    from repro.models.model import init_params, train_loss
    from repro import sharding as shd

    cfg = get_config("stablelm-1.6b", "smoke")
    m = 4
    pcfg = PaMEConfig(nu=0.5, p=0.25, gamma=1.01, sigma0=20.0,
                      mask_mode="bernoulli", homogeneous_kappa=2,
                      exchange="EXCHANGE")
    topo = build_topology("ring", m)
    arrs = make_topology_arrays(topo, pcfg)
    params0 = init_params(jax.random.PRNGKey(0), cfg)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), params0)
    state = pame_init(jax.random.PRNGKey(1), stacked, m, pcfg)
    tok = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (m, 2, 32)), jnp.int32)
    batch = {"tokens": tok}

    def grad_fn(p, b, k):
        return jax.value_and_grad(lambda pp: train_loss(pp, cfg, b))(p)

    ref_state, ref_m = jax.jit(
        lambda s, b: pame_step(s, b, grad_fn, arrs, pcfg))(state, batch)

    devs = np.array(jax.devices()[:8]).reshape(4, 1, 2)
    mesh = Mesh(devs, ("node", "fsdp", "model"), **mesh_axis_kwargs(3))
    state_specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    state_sh = shd.state_shardings(state_specs, mesh)
    batch_sh = shd.batch_shardings(
        {"tokens": jax.ShapeDtypeStruct(tok.shape, tok.dtype)}, mesh, True)
    with mesh:
        fn = jax.jit(
            lambda s, b: pame_step(s, b, grad_fn, arrs, pcfg,
                                   param_shardings=state_sh.params),
            in_shardings=(state_sh, batch_sh))
        sh_state, sh_m = fn(jax.device_put(state, state_sh),
                            jax.device_put(batch, batch_sh))

    err = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(ref_state.params),
                        jax.tree_util.tree_leaves(sh_state.params)))
    assert err < 1e-5, err
    assert abs(float(ref_m["loss_mean"]) - float(sh_m["loss_mean"])) < 1e-5
    print("OK err", err)
    """
)


def _run(exchange: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", CODE.replace("EXCHANGE", exchange)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK err" in res.stdout


def test_sharded_step_matches_single_device_compressed():
    _run("compressed")


def test_sharded_step_matches_single_device_dense():
    _run("dense")
