import os

# Tests must see exactly ONE device (the dry-run sets its own flag in a
# subprocess); make sure a stray env var doesn't leak in.
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
