import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flag in a
# subprocess); make sure a stray env var doesn't leak in.
os.environ.pop("XLA_FLAGS", None)

# The property tests use `hypothesis` (see requirements-dev.txt).  In
# hermetic environments without it, fall back to the deterministic stub so
# the suites still run instead of failing collection.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
