"""The comparison algorithms all make progress on a well-conditioned task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.compression import identity, one_bit, qsgd, rand_k, top_k
from repro.core.topology import build_topology


@pytest.fixture(scope="module")
def problem():
    m, n, spn = 8, 30, 32
    topo = build_topology("erdos_renyi", m, p=0.6, seed=1)
    bmat = jnp.asarray(topo.mixing)
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    a_j, y_j = jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    batch = (a_j, y_j)
    w0 = B.stack_params(jnp.zeros(n), m)
    return bmat, grad_fn, batch, w0


def _run(step_fn, state, batch, steps=250):
    _, hist = B.run_algorithm(step_fn, state, lambda k: batch, steps, tol_std=0.0)
    return hist["loss"]


def test_dpsgd(problem):
    bmat, grad_fn, batch, w0 = problem
    st = B.dpsgd_init(jax.random.PRNGKey(0), w0)
    loss = _run(lambda s, b: B.dpsgd_step(s, b, grad_fn, bmat, 0.05), st, batch)
    assert loss[-1] < 0.05 * loss[0]


def test_dfedsam(problem):
    bmat, grad_fn, batch, w0 = problem
    st = B.dfedsam_init(jax.random.PRNGKey(0), w0)
    loss = _run(
        lambda s, b: B.dfedsam_step(s, b, grad_fn, bmat, 0.05, rho=0.01), st, batch
    )
    assert loss[-1] < 0.05 * loss[0]


def test_choco_contractive(problem):
    bmat, grad_fn, batch, w0 = problem
    st = B.choco_init(jax.random.PRNGKey(0), w0)
    comp = rand_k(0.3, rescale=False)
    loss = _run(
        lambda s, b: B.choco_step(s, b, grad_fn, bmat, 0.05, comp, 0.3),
        st, batch, steps=400,
    )
    assert loss[-1] < 0.05 * loss[0]


def test_beer(problem):
    bmat, grad_fn, batch, w0 = problem
    st = B.beer_init(jax.random.PRNGKey(0), w0, batch, grad_fn)
    comp = rand_k(0.3, rescale=False)
    loss = _run(
        lambda s, b: B.beer_step(s, b, grad_fn, bmat, 0.02, comp, 0.3),
        st, batch, steps=400,
    )
    assert loss[-1] < 0.05 * loss[0]


def test_nids_and_anq(problem):
    bmat, grad_fn, batch, w0 = problem
    st = B.nids_init(jax.random.PRNGKey(0), w0, batch, grad_fn, 0.05)
    loss = _run(lambda s, b: B.nids_step(s, b, grad_fn, bmat, 0.05), st, batch)
    assert loss[-1] < 0.05 * loss[0]
    st = B.nids_init(jax.random.PRNGKey(0), w0, batch, grad_fn, 0.05)
    loss_q = _run(
        lambda s, b: B.nids_step(s, b, grad_fn, bmat, 0.05, qsgd(64)),
        st, batch, steps=400,
    )
    assert loss_q[-1] < 0.1 * loss_q[0]
