"""Non-IID partitioner validation (label skew) + heterogeneity example smoke.

The label-skew partitioner used to accept classes_per_node > n_classes
(silently double-assigning a class to the same node) and could emit empty
shards that break NodeBatcher downstream — both now fail loudly.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.partition import label_skew_partition

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_label_skew_valid_partition_covers_all_samples():
    labels = np.repeat(np.arange(10), 20)
    m = 4
    parts = label_skew_partition(labels, m, classes_per_node=3, seed=0)
    assert len(parts) == m
    # disjoint cover of all samples
    joined = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(joined, np.arange(len(labels)))
    # each shard touches at most C classes
    for p in parts:
        assert len(np.unique(labels[p])) <= 3
        assert len(p) > 0


def test_label_skew_full_class_coverage_is_iid_like():
    labels = np.repeat(np.arange(5), 8)
    parts = label_skew_partition(labels, 2, classes_per_node=5, seed=1)
    for p in parts:
        assert set(np.unique(labels[p])) == set(range(5))


def test_label_skew_rejects_classes_per_node_above_n_classes():
    labels = np.repeat(np.arange(5), 10)
    with pytest.raises(ValueError, match="classes_per_node"):
        label_skew_partition(labels, 3, classes_per_node=6, seed=0)


def test_label_skew_rejects_nonpositive_classes_per_node():
    labels = np.repeat(np.arange(5), 10)
    with pytest.raises(ValueError, match="classes_per_node"):
        label_skew_partition(labels, 3, classes_per_node=0, seed=0)


def test_label_skew_rejects_empty_shards():
    # 10 classes x 1 sample, 12 nodes at C=1: classes 0 and 1 each get two
    # takers but hold a single sample, so some node's shard must be empty
    labels = np.arange(10)
    with pytest.raises(ValueError, match="empty shard"):
        label_skew_partition(labels, 12, classes_per_node=1, seed=0)


def test_label_skew_rejects_missing_class():
    # class 1 absent although labels.max() == 2
    labels = np.array([0, 0, 2, 2])
    with pytest.raises(ValueError, match="no samples"):
        label_skew_partition(labels, 2, classes_per_node=1, seed=0)


@pytest.mark.parametrize("partition", ["flat", "tree"])
def test_cnn_heterogeneity_example_smoke(partition):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "cnn_heterogeneity.py"),
         "--steps", "4", "--nodes", "4", "--classes", "3",
         "--partition", partition],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[hetero] PaME" in proc.stdout
    assert "[hetero] D-PSGD" in proc.stdout
