"""Deliverable (f): per-arch REDUCED smoke — one forward/train step on CPU,
asserting output shapes and no NaNs, for every assigned architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models import init_params, train_loss, prefill, decode_step


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.vision_dim)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_train_step(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.n_layers <= 2 or cfg.arch_type == "hybrid"
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: train_loss(p, cfg, batch)))(
        params
    )
    assert jnp.isfinite(loss)
    assert float(loss) > 0
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # one SGD step changes the params
    new = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
    loss2 = train_loss(new, cfg, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch, "smoke")
    b, s = 2, 16
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b, s)
    logits, caches = prefill(params, cfg, batch, capacity=s + 4)
    assert logits.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = s + (cfg.n_patches if cfg.arch_type == "vlm" else 0)
    logits2, caches = decode_step(params, cfg, tok, jnp.int32(pos), caches)
    assert logits2.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits2))
