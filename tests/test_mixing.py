"""Sparse neighbor-exchange mixing vs the dense einsum reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import DPSGDHp, get_algorithm
from repro.core.mixing import as_mixer, make_mixer
from repro.core.topology import build_topology

TOPOS = [
    ("ring", {}),
    ("grid", {}),
    ("erdos_renyi", dict(p=0.4, seed=0)),
]


def _random_tree(m, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((m, 7, 5)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((m, 3)), jnp.float32),
        "s": jnp.asarray(rng.standard_normal((m,)), jnp.float32),
    }


def _legacy_mix(bmat, tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.einsum("ji,j...->i...", bmat.astype(x.dtype), x), tree
    )


@pytest.mark.parametrize("kind,kwargs", TOPOS)
def test_padded_gather_matches_dense_einsum(kind, kwargs):
    """mixing_padded gather == the dense _mix einsum to fp32 tolerance on
    random node-stacked pytrees."""
    m = 12
    topo = build_topology(kind, m, **kwargs)
    tree = _random_tree(m, seed=hash(kind) % 1000)
    dense = _legacy_mix(jnp.asarray(topo.mixing), tree)
    sparse = make_mixer(topo, "sparse").mix(tree)
    for key in tree:
        np.testing.assert_allclose(
            np.asarray(sparse[key]), np.asarray(dense[key]),
            rtol=1e-5, atol=1e-6,
        )
    # doubly-stochastic sanity: mixing preserves the node average
    for key in tree:
        np.testing.assert_allclose(
            np.asarray(sparse[key]).mean(axis=0),
            np.asarray(tree[key]).mean(axis=0),
            rtol=1e-4, atol=1e-5,
        )


@pytest.mark.parametrize("kind,kwargs", TOPOS)
def test_dense_escape_hatch_bit_identical_eager(kind, kwargs):
    """mixing="dense" (full-connectivity padded) and "sparse" run the same
    ascending-sender accumulation; the padding slots contribute exact IEEE
    zeros, so op-by-op the two are bit-identical on every topology."""
    m = 12
    topo = build_topology(kind, m, **kwargs)
    tree = _random_tree(m, seed=3)
    mx_d, mx_s = make_mixer(topo, "dense"), make_mixer(topo, "sparse")
    for fn in ("mix", "mix_lazy", "mix_half"):
        out_d = getattr(mx_d, fn)(tree)
        out_s = getattr(mx_s, fn)(tree)
        for key in tree:
            np.testing.assert_array_equal(
                np.asarray(out_d[key]), np.asarray(out_s[key]), err_msg=fn
            )
    hats = jax.tree_util.tree_map(lambda x: 0.5 * x, tree)
    out_d = mx_d.mix_nids_quantized(hats, tree)
    out_s = mx_s.mix_nids_quantized(hats, tree)
    for key in tree:
        np.testing.assert_array_equal(np.asarray(out_d[key]), np.asarray(out_s[key]))


@pytest.mark.parametrize("kind,kwargs", TOPOS)
def test_mixer_variants_match_matrix_forms(kind, kwargs):
    """(B−I), (I+B)/2, and the off/diag NIDS split agree with the legacy
    matrix-mode einsums to fp tolerance."""
    m = 12
    topo = build_topology(kind, m, **kwargs)
    tree = _random_tree(m, seed=7)
    mx_m, mx_s = make_mixer(topo, "matrix"), make_mixer(topo, "sparse")
    for fn in ("mix", "mix_lazy", "mix_half"):
        out_m = getattr(mx_m, fn)(tree)
        out_s = getattr(mx_s, fn)(tree)
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(out_s[key]), np.asarray(out_m[key]),
                rtol=1e-5, atol=1e-6, err_msg=fn,
            )
    hats = jax.tree_util.tree_map(lambda x: 0.1 * x, tree)
    out_m = mx_m.mix_nids_quantized(hats, tree)
    out_s = mx_s.mix_nids_quantized(hats, tree)
    for key in tree:
        np.testing.assert_allclose(
            np.asarray(out_s[key]), np.asarray(out_m[key]), rtol=1e-5, atol=1e-6
        )


def test_as_mixer_wraps_raw_matrix():
    m = 8
    topo = build_topology("ring", m)
    bmat = jnp.asarray(topo.mixing)
    tree = _random_tree(m, seed=1)
    wrapped = as_mixer(bmat).mix(tree)
    legacy = _legacy_mix(bmat, tree)
    for key in tree:
        np.testing.assert_array_equal(np.asarray(wrapped[key]), np.asarray(legacy[key]))
    mx = make_mixer(topo, "sparse")
    assert as_mixer(mx) is mx


def test_dpsgd_curves_bit_identical_dense_vs_sparse():
    """Same-seed D-PSGD loss curves under mixing="dense" and "sparse" are
    bit-identical through the jitted scan engine.  Pinned on a complete
    graph, where the two modes lower to the *same* program over the same
    padded arrays — compiler-proof; sparse-graph identity additionally
    holds op-by-op (see the eager test above)."""
    m, n, spn = 10, 40, 32
    topo = build_topology("complete", m)
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    batch = (jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32))

    def grad_fn(w, b, key):
        aa, yy = b
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    runs = {}
    for mode in ("dense", "sparse"):
        bound = get_algorithm("dpsgd").bind(
            grad_fn, topo, DPSGDHp(lr=0.1), mixing=mode
        )
        state, hist = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 24,
            tol_std=0.0, chunk_size=8,
        )
        runs[mode] = (np.asarray(state.params), hist["loss"])
    assert runs["dense"][1] == runs["sparse"][1]
    np.testing.assert_array_equal(runs["dense"][0], runs["sparse"][0])


def test_pame_sparse_pme_matches_dense_single_step():
    """The padded PME path produces the same v_bar as the dense selection-
    matrix path for the same key (fp tolerance, one exchange)."""
    from repro.core import pme
    from repro.core.pame import PaMEConfig, make_topology_arrays

    m = 10
    topo = build_topology("erdos_renyi", m, p=0.5, seed=2)
    cfg = PaMEConfig(nu=0.5, p=0.3)
    arrs = make_topology_arrays(topo, cfg, seed=0)
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.standard_normal((m, 6, 4)), jnp.float32),
        "v": jnp.asarray(rng.standard_normal((m, 9)), jnp.float32),
    }
    key_sel, key_mask = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    comm = jnp.ones((m,), bool)
    for mode in ("bernoulli", "exact"):
        a = pme.sample_neighbor_selection(key_sel, arrs.nbrs, arrs.valid, arrs.t, comm)
        dense = pme.pme_average_pytree(key_mask, params, a, cfg.p, mode=mode)
        sel = pme.sample_neighbor_selection_padded(
            key_sel, arrs.nbrs, arrs.valid, arrs.t, comm
        )
        sparse = pme.pme_average_pytree_padded(
            key_mask, params, arrs.nbrs, sel, cfg.p, mode=mode
        )
        for key in params:
            np.testing.assert_allclose(
                np.asarray(sparse[key]), np.asarray(dense[key]),
                rtol=1e-5, atol=1e-6, err_msg=mode,
            )


def test_pame_sparse_mixing_converges_like_dense():
    """Full PaME runs with mixing="sparse" track the dense run's objective
    (same seed; fp drift only) and reach the same optimization regime."""
    from repro.core import PaMEConfig, run_pame

    m, n, spn = 10, 30, 48
    rng = np.random.default_rng(4)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.3 * rng.standard_normal((m, spn))
    a_j, y_j = jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - y_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    hists = {}
    for mode in ("dense", "sparse"):
        cfg = PaMEConfig(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0, mixing=mode)
        _, hist = run_pame(
            jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn, lambda k: (a_j, y_j),
            topo, cfg, num_steps=120, objective_fn=objective, tol_std=0.0,
        )
        hists[mode] = np.asarray(hist["objective"])
    # early steps agree tightly; late steps to a few percent (fp drift
    # compounds through the nonlinear dynamics)
    np.testing.assert_allclose(hists["sparse"][:20], hists["dense"][:20], rtol=1e-4)
    assert hists["sparse"][-1] < hists["sparse"][0] * 0.5
    np.testing.assert_allclose(hists["sparse"][-1], hists["dense"][-1], rtol=0.2)
