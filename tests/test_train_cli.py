"""Launcher regressions: seed-dependent batch stream, realized wire-bit
accounting across checkpoint resume, and the tree-partitioned end-to-end
smoke on a >=1M-param registered model.
"""
import json
import os
import re
import zlib

import numpy as np
import pytest

from repro.launch import train as train_mod


def _build_args(extra):
    base = ["--arch", "stablelm-1.6b", "--variant", "smoke",
            "--batch", "2", "--seq", "16", "--nodes", "2", "--steps", "1"]
    return train_mod.make_parser().parse_args(base + extra)


def test_batch_stream_rng_depends_on_seed_and_step():
    a = train_mod.batch_stream_rng(0, 0).integers(0, 1 << 30, 8)
    b = train_mod.batch_stream_rng(1, 0).integers(0, 1 << 30, 8)
    c = train_mod.batch_stream_rng(0, 1).integers(0, 1 << 30, 8)
    a2 = train_mod.batch_stream_rng(0, 0).integers(0, 1 << 30, 8)
    assert not np.array_equal(a, b)  # the --seed used to be ignored here
    assert not np.array_equal(a, c)
    np.testing.assert_array_equal(a, a2)


def test_make_batch_windows_differ_across_seeds():
    _, _, _, mb0, _, _ = train_mod.build_everything(_build_args(["--seed", "0"]))
    _, _, _, mb0b, _, _ = train_mod.build_everything(_build_args(["--seed", "0"]))
    _, _, _, mb1, _, _ = train_mod.build_everything(_build_args(["--seed", "1"]))
    t0 = np.asarray(mb0(0)["tokens"])
    t0b = np.asarray(mb0b(0)["tokens"])
    t1 = np.asarray(mb1(0)["tokens"])
    np.testing.assert_array_equal(t0, t0b)   # same seed reproduces
    assert not np.array_equal(t0, t1)        # different seed, different windows


# ---------------------------------------------------------------------------
# resume accounting
# ---------------------------------------------------------------------------
TRAIN_ARGS = ["--arch", "stablelm-1.6b", "--variant", "smoke",
              "--batch", "2", "--seq", "32", "--nodes", "4",
              "--chunk", "2", "--log-every", "2"]


def _run_main(capsys, extra):
    train_mod.main(TRAIN_ARGS + extra)
    return capsys.readouterr().out


def _wire_gbits(out, step):
    m = re.search(rf"step={step} .*wire_gbits=([0-9.]+)", out)
    assert m, out
    return float(m.group(1))


def _manifest(ckpt_dir, step):
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        return step_dir, json.load(f)


def test_resume_restores_realized_cumulative_wire_bits(tmp_path, capsys):
    ckpt = str(tmp_path / "ckpt")
    out = _run_main(capsys, ["--steps", "4", "--edge-drop", "0.5",
                             "--ckpt-dir", ckpt, "--ckpt-every", "2"])
    static_per_step = float(
        re.search(r"wire_bits/step=([0-9.e+]+)", out).group(1))
    logged = _wire_gbits(out, 4)

    step_dir, manifest = _manifest(ckpt, 4)
    # payload {"cum_bits": ..., "state": ...} flattens with cum_bits first
    cum_meta = manifest["leaves"][0]
    assert "cum_bits" in cum_meta["file"]
    saved = float(np.load(os.path.join(step_dir, cum_meta["file"])))
    assert saved / 1e9 == pytest.approx(logged, abs=2e-4)
    # the pre-fix formula (static full-graph rate x steps) over-charges a
    # run whose edges were dropping half the time
    assert saved != pytest.approx(static_per_step * 4, rel=1e-3)

    # tamper the persisted counter with a sentinel (and fix the crc): a
    # resumed run must CONTINUE from it, proving the restore reads the leaf
    sentinel = np.asarray(2.0e9, np.float64)
    np.save(os.path.join(step_dir, cum_meta["file"]), sentinel)
    cum_meta["crc32"] = zlib.crc32(
        np.ascontiguousarray(sentinel).tobytes()) & 0xFFFFFFFF
    with open(os.path.join(step_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    out2 = _run_main(capsys, ["--steps", "6", "--edge-drop", "0.5",
                              "--ckpt-dir", ckpt, "--ckpt-every", "2"])
    assert "resumed from step 4" in out2
    resumed = _wire_gbits(out2, 6)
    assert 2.0 <= resumed <= 2.0 + 4 * static_per_step / 1e9


def test_resume_accepts_legacy_checkpoint_without_cum_bits(tmp_path, capsys):
    ckpt = str(tmp_path / "ckpt")
    _run_main(capsys, ["--steps", "4", "--edge-drop", "0.5",
                       "--ckpt-dir", ckpt, "--ckpt-every", "2"])
    for step in (2, 4):
        step_dir, manifest = _manifest(ckpt, step)
        cum_meta = manifest["leaves"].pop(0)
        assert "cum_bits" in cum_meta["file"]
        os.remove(os.path.join(step_dir, cum_meta["file"]))
        with open(os.path.join(step_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    out = _run_main(capsys, ["--steps", "6", "--edge-drop", "0.5",
                             "--ckpt-dir", ckpt, "--ckpt-every", "2"])
    # falls back to the static estimate instead of crashing
    assert "resumed from step 4" in out
    assert "[train] done" in out


# ---------------------------------------------------------------------------
# tree partition end-to-end on a real (>=1M-param) registered model
# ---------------------------------------------------------------------------
def test_partition_tree_trains_stablelm_smoke(capsys):
    out = _run_main(capsys, ["--steps", "8", "--chunk", "4",
                             "--partition", "tree", "--sigma0", "50"])
    assert "partition=tree" in out
    n_params = float(re.search(r"params=([0-9.]+)M", out).group(1))
    assert n_params >= 1.0
    losses = [float(x) for x in re.findall(r"loss=([0-9.]+)", out)]
    assert losses and all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert "[train] done" in out
