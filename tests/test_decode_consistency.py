"""Serving correctness: prefill + token-by-token decode must reproduce the
full-sequence forward logits for every cached family (incl. absorbed MLA,
SSD state handoff, sliding-window ring buffer, hybrid shared-attn caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params, prefill, decode_step
from repro.models.model import _embed_inputs, _logits, _run_trunk_full

CONFIGS = {
    "dense": ModelConfig(
        "dense", "dense", n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, qk_norm=True,
    ),
    "window": ModelConfig(
        "window", "dense", n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, window=8,
    ),
    "mla_moe": ModelConfig(
        "mla", "moe", n_layers=3, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=4, head_dim=16, use_mla=True, kv_lora=32,
        q_lora=24, rope_head_dim=8, v_head_dim=16, d_ff=128, n_experts=4,
        n_shared_experts=1, moe_top_k=2, d_ff_expert=32, first_dense_layers=1,
        capacity_factor=4.0,
    ),
    "ssm": ModelConfig(
        "ssm", "ssm", n_layers=2, d_model=64, vocab=64,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    ),
    "hybrid": ModelConfig(
        "hybrid", "hybrid", n_layers=5, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, attn_every=2,
    ),
}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_full_forward(name):
    cfg = CONFIGS[name]
    b, s = 2, 16
    params = init_params(jax.random.PRNGKey(1), cfg)
    tok = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (b, s)), jnp.int32
    )
    batch = {"tokens": tok}
    x = _embed_inputs(params, cfg, batch)
    xf, _, _ = _run_trunk_full(params, cfg, x, jnp.arange(s), False, s)
    full_logits = _logits(params, cfg, xf)
    half = s // 2
    lg, caches = prefill(params, cfg, {"tokens": tok[:, :half]}, s)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, half - 1])))]
    for t in range(half, s):
        lg, caches = decode_step(params, cfg, tok[:, t], jnp.int32(t), caches)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 3e-4, (name, errs)


def test_ring_buffer_wraparound_matches_windowed_attention():
    """Decode past the cache capacity with a window: ring buffer must agree
    with a full-capacity run restricted to the same window."""
    cfg = CONFIGS["window"]  # window=8
    b, s, cap = 1, 24, 8  # capacity == window
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32)
    # ground truth: full forward with window mask
    batch = {"tokens": tok}
    x = _embed_inputs(params, cfg, batch)
    xf, _, _ = _run_trunk_full(params, cfg, x, jnp.arange(s), False, s)
    full_logits = _logits(params, cfg, xf)
    # ring-buffer decode with capacity = window only
    lg, caches = prefill(params, cfg, {"tokens": tok[:, :4]}, cap)
    errs = []
    for t in range(4, s):
        lg, caches = decode_step(params, cfg, tok[:, t], jnp.int32(t), caches)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 3e-4, errs


def test_unrolled_matches_scanned():
    cfg = CONFIGS["dense"]
    params = init_params(jax.random.PRNGKey(2), cfg)
    tok = jnp.asarray(np.random.default_rng(2).integers(0, 64, (2, 12)), jnp.int32)
    from repro.models.model import train_loss

    l_scan = train_loss(params, cfg, {"tokens": tok})
    l_unroll = train_loss(params, cfg.replace(unroll=True), {"tokens": tok})
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)
