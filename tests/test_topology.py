"""Topology substrate: connectivity, doubly-stochastic mixing, spectral gap."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import (
    build_topology,
    complete_graph,
    grid_graph,
    metropolis_matrix,
    ring_graph,
    spectral_gap_zeta,
    star_graph,
)

KINDS = ["ring", "grid", "complete", "star", "erdos_renyi", "regular"]


@pytest.mark.parametrize("kind", KINDS)
def test_doubly_stochastic_and_gap(kind):
    topo = build_topology(kind, 12, p=0.5, degree=4, seed=3)
    b = topo.mixing
    assert np.allclose(b.sum(axis=0), 1.0, atol=1e-9)
    assert np.allclose(b.sum(axis=1), 1.0, atol=1e-9)
    assert np.allclose(b, b.T)
    assert (b >= -1e-12).all()
    # Assumption 1: zeta < 1 iff connected (all our builders guarantee it)
    assert 0.0 <= topo.zeta < 1.0


def test_neighbor_sets_match_adjacency():
    topo = build_topology("erdos_renyi", 10, p=0.6, seed=1)
    for i, ns in enumerate(topo.neighbor_sets):
        assert i not in ns
        for j in ns:
            assert topo.adjacency[i, j] == 1
            assert i in topo.neighbor_sets[j]  # undirected


def test_padded_neighbor_matrix():
    topo = build_topology("star", 7)
    nbrs, valid = topo.neighbor_matrix_padded()
    assert nbrs.shape == valid.shape == (7, topo.max_degree)
    assert valid[0].sum() == 6  # hub sees all
    assert all(valid[i].sum() == 1 for i in range(1, 7))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 24))
def test_ring_spectral_gap_worse_than_complete(m):
    ring = metropolis_matrix(ring_graph(m))
    comp = metropolis_matrix(complete_graph(m))
    assert spectral_gap_zeta(comp) <= spectral_gap_zeta(ring) + 1e-9


@settings(max_examples=15, deadline=None)
@given(m=st.integers(4, 30))
def test_metropolis_always_doubly_stochastic(m):
    for builder in (ring_graph, grid_graph, star_graph):
        b = metropolis_matrix(builder(m))
        assert np.allclose(b.sum(axis=0), 1.0)
        assert np.allclose(b.sum(axis=1), 1.0)
        assert (b >= -1e-12).all()


def test_disconnected_rejected():
    with pytest.raises((ValueError, RuntimeError)):
        build_topology("erdos_renyi", 10, p=0.0)


def test_mixing_padded_star_hub_and_padding_slots():
    """Degenerate inputs for mixing_padded: the star hub fills every
    max_degree+1 slot (no padding at max degree); each leaf carries
    max_degree-1 padding slots that repeat the row's own id with weight
    exactly 0.0, and the scatter-reconstruction equals the dense B —
    padding adds exactly zero to the diagonal."""
    m = 9
    topo = build_topology("star", m)
    nbrs, w, is_self = topo.mixing_padded()
    k = topo.max_degree + 1
    assert nbrs.shape == w.shape == is_self.shape == (m, k)
    assert k == m  # hub degree is m-1
    # hub row: all slots live, none padded
    assert len(set(nbrs[0].tolist())) == m
    assert is_self[0].sum() == 1 and nbrs[0][is_self[0]][0] == 0
    # leaf rows: exactly 2 live slots (hub + self); the rest is padding
    for i in range(1, m):
        live = w[i] != 0.0
        assert live.sum() == 2
        assert np.all(nbrs[i][~live] == i)
        assert not is_self[i][~live].any()
        assert np.all(w[i][~live] == 0.0)  # bitwise IEEE zero
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
    recon = np.zeros((m, m), np.float64)
    for i in range(m):
        for slot in range(k):
            recon[i, nbrs[i, slot]] += w[i, slot]
    np.testing.assert_allclose(recon, topo.mixing, atol=1e-6)


def test_mixing_padded_m2_minimal():
    """Smallest graph: m=2 single link -> 2 slots per row, B = [[.5,.5]]*2."""
    topo = build_topology("ring", 2)
    nbrs, w, is_self = topo.mixing_padded()
    assert nbrs.shape == (2, 2)
    assert is_self.sum(axis=1).tolist() == [1, 1]
    np.testing.assert_allclose(w, 0.5, atol=1e-7)


def test_mix_padded_padding_slots_contribute_exactly_zero():
    """Poison check: redirect every padding slot's gather index at a
    different node; because padding weights are exactly 0.0 the mixed
    output must be bitwise unchanged — padded slots contribute exactly
    zero weight."""
    import jax.numpy as jnp

    from repro.core.mixing import PaddedMixing, mix_padded

    m = 7
    topo = build_topology("star", m)
    nbrs, w, is_self = topo.mixing_padded()
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((m, 4)), jnp.float32)}
    pm = PaddedMixing(jnp.asarray(nbrs), jnp.asarray(w), jnp.asarray(is_self))
    out = mix_padded(pm, tree)
    padding = (w == 0.0) & ~is_self
    poisoned = np.where(padding, (nbrs + 1) % m, nbrs)
    pm_poison = PaddedMixing(
        jnp.asarray(poisoned, np.int32), jnp.asarray(w), jnp.asarray(is_self)
    )
    out_poison = mix_padded(pm_poison, tree)
    np.testing.assert_array_equal(
        np.asarray(out["w"]), np.asarray(out_poison["w"])
    )
