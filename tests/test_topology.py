"""Topology substrate: connectivity, doubly-stochastic mixing, spectral gap."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import (
    build_topology,
    complete_graph,
    grid_graph,
    metropolis_matrix,
    ring_graph,
    spectral_gap_zeta,
    star_graph,
)

KINDS = ["ring", "grid", "complete", "star", "erdos_renyi", "regular"]


@pytest.mark.parametrize("kind", KINDS)
def test_doubly_stochastic_and_gap(kind):
    topo = build_topology(kind, 12, p=0.5, degree=4, seed=3)
    b = topo.mixing
    assert np.allclose(b.sum(axis=0), 1.0, atol=1e-9)
    assert np.allclose(b.sum(axis=1), 1.0, atol=1e-9)
    assert np.allclose(b, b.T)
    assert (b >= -1e-12).all()
    # Assumption 1: zeta < 1 iff connected (all our builders guarantee it)
    assert 0.0 <= topo.zeta < 1.0


def test_neighbor_sets_match_adjacency():
    topo = build_topology("erdos_renyi", 10, p=0.6, seed=1)
    for i, ns in enumerate(topo.neighbor_sets):
        assert i not in ns
        for j in ns:
            assert topo.adjacency[i, j] == 1
            assert i in topo.neighbor_sets[j]  # undirected


def test_padded_neighbor_matrix():
    topo = build_topology("star", 7)
    nbrs, valid = topo.neighbor_matrix_padded()
    assert nbrs.shape == valid.shape == (7, topo.max_degree)
    assert valid[0].sum() == 6  # hub sees all
    assert all(valid[i].sum() == 1 for i in range(1, 7))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 24))
def test_ring_spectral_gap_worse_than_complete(m):
    ring = metropolis_matrix(ring_graph(m))
    comp = metropolis_matrix(complete_graph(m))
    assert spectral_gap_zeta(comp) <= spectral_gap_zeta(ring) + 1e-9


@settings(max_examples=15, deadline=None)
@given(m=st.integers(4, 30))
def test_metropolis_always_doubly_stochastic(m):
    for builder in (ring_graph, grid_graph, star_graph):
        b = metropolis_matrix(builder(m))
        assert np.allclose(b.sum(axis=0), 1.0)
        assert np.allclose(b.sum(axis=1), 1.0)
        assert (b >= -1e-12).all()


def test_disconnected_rejected():
    with pytest.raises((ValueError, RuntimeError)):
        build_topology("erdos_renyi", 10, p=0.0)
