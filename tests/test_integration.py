"""End-to-end integration: DFL LM training via the launcher; CNN example;
PaME vs D-PSGD on the paper's logistic-regression task."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_driver_subprocess_loss_decreases():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "stablelm-1.6b", "--variant", "smoke",
            "--steps", "40", "--batch", "4", "--seq", "64", "--nodes", "4",
            "--sigma0", "50", "--log-every", "10",
        ],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    losses = [
        float(l.split("loss=")[1].split()[0])
        for l in res.stdout.splitlines()
        if "loss=" in l
    ]
    assert len(losses) >= 3
    assert losses[-1] < losses[0]


def test_pame_dfl_on_cnn_heterogeneous():
    """Tiny non-IID CNN federation converges with PaME (Example 3 analogue)."""
    from repro.core import PaMEConfig, build_topology, run_pame
    from repro.data import SyntheticClassification, label_skew_partition, NodeBatcher
    from repro.models.cnn import cnn_apply, cnn_init, ce_loss

    m = 4
    ds = SyntheticClassification.make(512, (28, 28, 1), 10, seed=0, sep=3.0)
    parts = label_skew_partition(ds.labels, m, classes_per_node=5, seed=0)
    nb = NodeBatcher({"x": ds.images, "y": ds.labels}, parts, batch_size=16, seed=0)
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.7, p=0.3, gamma=1.002, sigma0=10.0, homogeneous_kappa=2)

    def grad_fn(params, batch, key):
        def loss(p):
            return ce_loss(cnn_apply(p, batch["x"]), batch["y"])
        return jax.value_and_grad(loss)(params)

    def batch_fn(k):
        b = nb.next()
        return {
            "x": jnp.asarray(b["x"], jnp.float32),
            "y": jnp.asarray(b["y"], jnp.int32),
        }

    params0 = cnn_init(jax.random.PRNGKey(0))
    _, hist = run_pame(
        jax.random.PRNGKey(1), params0, m, grad_fn, batch_fn, topo, cfg,
        num_steps=60, tol_std=0.0,
    )
    losses = hist["loss"]
    assert losses[-1] < losses[0] * 0.7
    assert np.isfinite(losses).all()


def test_pame_beats_naive_average_variant():
    """Ablation: the count-weighted average (paper) vs the biased /t_i
    average — the biased variant shrinks toward zero and converges slower."""
    from repro.core import PaMEConfig, build_topology
    from repro.core.pame import make_topology_arrays, pame_init, pame_step
    from repro.core import pme as pme_mod

    m, n = 8, 30
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, 64, n))
    y = a @ w_star
    a_j, y_j = jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32)
    batch = (a_j, y_j)

    def grad_fn(p, b, key):
        aa, yy = b
        r = aa @ p["w"] - yy
        return 0.5 * jnp.mean(r**2), {"w": aa.T @ r / aa.shape[0]}

    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.9, p=0.2, gamma=1.01, sigma0=8.0, homogeneous_kappa=1)
    arrs = make_topology_arrays(topo, cfg)

    def run(avg_fn, steps=150):
        orig = pme_mod.pme_average
        pme_mod.pme_average = avg_fn
        try:
            state = pame_init(
                jax.random.PRNGKey(0), {"w": jnp.zeros((m, n))}, m, cfg
            )
            losses = []
            for _ in range(steps):
                state, metrics = pame_step(state, batch, grad_fn, arrs, cfg)
                losses.append(float(metrics["loss_mean"]))
            return losses
        finally:
            pme_mod.pme_average = orig

    good = run(pme_mod.pme_average)
    bad = run(pme_mod.naive_average)
    assert good[-1] < bad[-1] * 0.9  # unbiased estimator wins (Thm 1)
