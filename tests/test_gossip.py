"""Compressed (block-systematic) PME exchange: unbiasedness, self-fill,
and convergence parity with the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core.gossip import compressed_pme_average_pytree, systematic_offsets


def test_offsets_uniform():
    counts = np.zeros(5)
    for t in range(500):
        o = np.asarray(systematic_offsets(jax.random.PRNGKey(t), 8, 5))
        for v in o:
            counts[v] += 1
    freq = counts / counts.sum()
    assert np.abs(freq - 0.2).max() < 0.03


def test_compressed_unbiased_and_bounded():
    """E[v_bar] per coordinate = neighbor mean; outputs bounded by inputs."""
    m, d1, d2 = 5, 10, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((m, d1, d2)), jnp.float32)
    # receiver 0 hears from everyone else
    a = jnp.zeros((m, m)).at[1:, 0].set(1.0)
    target = np.asarray(w[1:]).mean(axis=0)
    acc = np.zeros((d1, d2))
    got = np.zeros((d1, d2))
    T = 1500
    for t in range(T):
        out = compressed_pme_average_pytree(
            jax.random.PRNGKey(t), {"w": w}, a, p=0.5
        )["w"]
        o = np.asarray(out[0])
        assert np.abs(o).max() <= np.abs(np.asarray(w)).max() + 1e-5
        # count only rounds where coord was actually received (not self-fill)
        received = ~np.isclose(o, np.asarray(w[0]))
        acc += np.where(received, o, 0.0)
        got += received
    est = acc / np.maximum(got, 1)
    mask = got > 100
    np.testing.assert_allclose(est[mask], target[mask], atol=0.25)


def test_compressed_no_comm_returns_self():
    m = 4
    w = jnp.asarray(np.random.default_rng(1).standard_normal((m, 8, 3)), jnp.float32)
    a = jnp.zeros((m, m))
    out = compressed_pme_average_pytree(jax.random.PRNGKey(0), {"w": w}, a, p=0.3)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w))


def test_compressed_pame_converges_like_dense():
    m, n = 10, 40
    rng = np.random.default_rng(0)
    w_star = rng.standard_normal(n)
    av = rng.standard_normal((m, 64, n))
    y = av @ w_star + 0.2 * rng.standard_normal((m, 64))
    a_j, y_j = jnp.asarray(av, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - y_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    finals = {}
    for exchange in ("dense", "compressed"):
        cfg = PaMEConfig(
            nu=0.3, p=0.25, gamma=1.01, sigma0=8.0,
            mask_mode="bernoulli", exchange=exchange,
        )
        # params as a 2-D pytree leaf so axis-1 blocking is exercised
        _, hist = run_pame(
            jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn,
            lambda k: (a_j, y_j), topo, cfg, num_steps=350,
            objective_fn=objective, tol_std=0.0,
        )
        finals[exchange] = hist["objective"][-1]
    # both reach the same stochastic floor (within 30%)
    assert finals["compressed"] < finals["dense"] * 1.3 + 0.5
    assert np.isfinite(finals["compressed"])


def test_compressed_q8_converges():
    """int8 wire payloads keep convergence (quantization error is bounded
    by absmax/127 per message and averages out)."""
    m, n = 8, 30
    rng = np.random.default_rng(3)
    w_star = rng.standard_normal(n)
    av = rng.standard_normal((m, 48, n))
    y = av @ w_star
    a_j, y_j = jnp.asarray(av, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.6, p=0.25, gamma=1.01, sigma0=8.0,
                     mask_mode="bernoulli", exchange="compressed_q8")
    _, hist = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn,
        lambda k: (a_j, y_j), topo, cfg, num_steps=250, tol_std=0.0,
    )
    assert hist["loss"][-1] < hist["loss"][0] * 0.05
    assert np.isfinite(hist["loss"]).all()
