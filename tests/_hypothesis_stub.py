"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The real dependency is listed in requirements-dev.txt; this stub keeps the
property tests *running* (rather than skipped) in hermetic environments by
replaying a fixed number of seeded pseudo-random examples per test.  Only
the tiny API surface the test-suite uses is implemented:

    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(lo, hi), y=st.sampled_from(seq))

`tests/conftest.py` installs this module under the name ``hypothesis`` in
``sys.modules`` before collection when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import random

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        inner = fn

        @functools.wraps(inner)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            # seed on the test name so each test sees a stable example set
            rng = random.Random(inner.__qualname__)
            for _ in range(n):
                drawn = {
                    name: strat.example(rng)
                    for name, strat in strategy_kwargs.items()
                }
                inner(*args, **drawn, **kwargs)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution (functools.wraps exposes them via __wrapped__)
        del wrapper.__wrapped__
        sig = inspect.signature(inner)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
        )
        return wrapper

    return deco
