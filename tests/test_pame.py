"""PaME (Algorithm 1): convergence, consensus, boundedness, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core.pame import make_topology_arrays, pame_init, pame_step


def _linreg_problem(m=12, n=40, spn=64, noise=0.5, seed=0):
    rng = np.random.default_rng(seed)
    w_star = np.zeros(n)
    idx = rng.choice(n, 3, replace=False)
    w_star[idx] = rng.uniform(0.5, 2, 3) * rng.choice([-1, 1], 3)
    a = rng.standard_normal((m, spn, n))
    b = a @ w_star + noise * rng.standard_normal((m, spn))
    a_j, b_j = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - b_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    noise_floor = m * 0.5 * noise**2
    return (a_j, b_j), grad_fn, objective, noise_floor


def test_pame_converges_linear_regression():
    m = 12
    batch, grad_fn, objective, floor = _linreg_problem(m=m)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0)
    _, hist = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(40), m, grad_fn, lambda k: batch,
        topo, cfg, num_steps=500, objective_fn=objective, tol_std=0.0,
    )
    obj = np.asarray(hist["objective"])
    assert obj[-1] < obj[0] * 0.15
    assert obj[-1] < floor * 1.5  # reaches the stochastic floor
    # consensus error decays
    assert hist["consensus"][-1] < hist["consensus"][10] * 0.5


def test_pame_linear_rate_typeII():
    """Thm 4: f(w^k) - f_inf = O(gamma^{-k/2}) — fit log-gap slope and
    check it's negative & roughly linear (deterministic full batch)."""
    m = 8
    batch, grad_fn, objective, _ = _linreg_problem(m=m, noise=0.0)
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.9, p=0.8, gamma=1.02, sigma0=8.0, homogeneous_kappa=1)
    _, hist = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(40), m, grad_fn, lambda k: batch,
        topo, cfg, num_steps=400, objective_fn=objective, tol_std=0.0,
    )
    obj = np.asarray(hist["objective"])
    f_inf = obj[-1]
    gap = obj[:200] - f_inf
    gap = np.maximum(gap, 1e-12)
    k = np.arange(len(gap))
    slope = np.polyfit(k, np.log(gap), 1)[0]
    assert slope < -0.01  # geometric decay
    # check the fit is decent (log-linear): R^2 > 0.8
    pred = np.polyval(np.polyfit(k, np.log(gap), 1), k)
    ss_res = np.sum((np.log(gap) - pred) ** 2)
    ss_tot = np.sum((np.log(gap) - np.log(gap).mean()) ** 2)
    assert 1 - ss_res / ss_tot > 0.8


def test_pame_iterates_bounded_lemma3():
    """Iterates stay in a bounded region (Lemma 3 / Thm 2.1)."""
    m = 8
    batch, grad_fn, objective, _ = _linreg_problem(m=m)
    topo = build_topology("ring", m)
    cfg = PaMEConfig(nu=0.9, p=0.3, gamma=1.01, sigma0=8.0)
    state, hist = run_pame(
        jax.random.PRNGKey(1), jnp.zeros(40), m, grad_fn, lambda k: batch,
        topo, cfg, num_steps=300, objective_fn=objective, tol_std=0.0,
    )
    w = np.asarray(state.params)
    assert np.isfinite(w).all()
    assert np.abs(w).max() < 10.0


def test_sigma_growth_and_comm_schedule():
    m = 6
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.5, p=0.5, gamma=1.1, sigma0=2.0, homogeneous_kappa=3)
    arrs = make_topology_arrays(topo, cfg)
    params = {"w": jnp.zeros((m, 4))}

    def grad_fn(p, b, k):
        return jnp.sum(p["w"] ** 2), jax.tree_util.tree_map(lambda x: 2 * x, p)

    state = pame_init(jax.random.PRNGKey(0), params, m, cfg)
    batch = {"w": jnp.zeros((m, 4))}
    comm_counts = []
    for k in range(7):
        state, metrics = pame_step(state, batch, grad_fn, arrs, cfg)
        comm_counts.append(int(metrics["comm_nodes"]))
    # homogeneous kappa=3: all m communicate at k = 0, 3, 6
    assert comm_counts[0] == m and comm_counts[3] == m and comm_counts[6] == m
    assert comm_counts[1] == 0 and comm_counts[2] == 0
    np.testing.assert_allclose(
        float(state.sigma[0]), 2.0 * 1.1**7, rtol=1e-5
    )


def test_pame_heterogeneous_kappas_still_converge():
    m = 10
    batch, grad_fn, objective, floor = _linreg_problem(m=m)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=2)
    cfg = PaMEConfig(nu=0.3, p=0.2, gamma=1.01, sigma0=8.0, kappa_lo=3, kappa_hi=7)
    _, hist = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(40), m, grad_fn, lambda k: batch,
        topo, cfg, num_steps=500, objective_fn=objective, tol_std=0.0,
    )
    assert hist["objective"][-1] < hist["objective"][0] * 0.2


def test_paper_termination_rule():
    m = 8
    batch, grad_fn, objective, _ = _linreg_problem(m=m)
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.5, p=0.5, gamma=1.05, sigma0=8.0)
    _, hist = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(40), m, grad_fn, lambda k: batch,
        topo, cfg, num_steps=2000, objective_fn=objective, tol_std=1e-3,
    )
    assert hist["steps_run"] < 2000  # terminated early by the std rule
