"""Optimizers converge on a quadratic; checkpoint round-trips and resumes."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.checkpoint.store import CheckpointCorruptError, latest_step
from repro.optim import adam, apply_updates, momentum, sgd


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adam"])
def test_optimizer_quadratic(opt_name):
    opt = {"sgd": sgd(0.1), "momentum": momentum(0.05), "adam": adam(0.1)}[opt_name]
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8), jnp.float32)
    params = {"w": jnp.zeros(8)}
    state = opt.init(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    assert float(loss_fn(params)) < 1e-3


def test_checkpoint_roundtrip_and_gc():
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "c": [jnp.zeros(3)]},
    }
    with tempfile.TemporaryDirectory() as d:
        for step in (10, 20, 30, 40):
            save_checkpoint(d, step, tree, keep=2)
        steps = sorted(
            int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_")
        )
        assert steps == [30, 40]  # gc kept last 2
        assert latest_step(d) == 40
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        back = restore_checkpoint(d, like)
        np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]))
        assert back["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_rejected():
    tree = {"a": jnp.zeros((3,))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        bad = {"a": jnp.zeros((4,))}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


def _leaf_files(step_dir):
    return sorted(f for f in os.listdir(step_dir) if f.endswith(".npy"))


def test_checkpoint_save_is_atomic_no_tmp_left():
    tree = {"a": jnp.arange(6, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree)
        assert not [x for x in os.listdir(d) if x.endswith(".tmp")]
        # a stale tmp dir from a crashed save is invisible to latest_step
        os.makedirs(os.path.join(d, "step_000000009.tmp"))
        assert latest_step(d) == 3


def test_checkpoint_crc_mismatch_detected():
    """A bit-flip in a leaf payload (valid .npy header, wrong bytes) is
    caught by the per-leaf crc32, not silently restored."""
    tree = {"a": jnp.arange(8, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        fpath = os.path.join(step_dir, _leaf_files(step_dir)[0])
        with open(fpath, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            flipped = f.read(1)[0] ^ 0xFF
            f.seek(-1, os.SEEK_END)
            f.write(bytes([flipped]))
        with pytest.raises(CheckpointCorruptError, match="crc32 mismatch"):
            restore_checkpoint(d, tree)


def test_checkpoint_truncated_leaf_detected():
    tree = {"a": jnp.arange(64, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        fpath = os.path.join(step_dir, _leaf_files(step_dir)[0])
        with open(fpath, "r+b") as f:
            f.truncate(os.path.getsize(fpath) - 40)
        with pytest.raises(CheckpointCorruptError, match="truncated"):
            restore_checkpoint(d, tree)


def test_checkpoint_missing_leaf_and_manifest_detected():
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        os.remove(os.path.join(step_dir, _leaf_files(step_dir)[0]))
        with pytest.raises(CheckpointCorruptError, match="missing"):
            restore_checkpoint(d, tree)
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        with open(os.path.join(step_dir, "manifest.json"), "w") as f:
            f.write("{not json")
        with pytest.raises(CheckpointCorruptError, match="not valid JSON"):
            restore_checkpoint(d, tree)
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        os.remove(os.path.join(step_dir, "manifest.json"))
        with pytest.raises(CheckpointCorruptError, match="manifest"):
            restore_checkpoint(d, tree)
    # no checkpoint at all stays a FileNotFoundError, not corruption
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(d, tree)


def test_checkpoint_backward_compat_manifest_without_crc():
    """Manifests written before checksumming restore cleanly: the crc
    check is skipped for leaves with no crc32 key."""
    import json

    tree = {"a": jnp.arange(5, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        step_dir = save_checkpoint(d, 1, tree)
        mpath = os.path.join(step_dir, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            leaf.pop("crc32")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        back = restore_checkpoint(d, jax.tree_util.tree_map(jnp.zeros_like, tree))
        np.testing.assert_allclose(np.asarray(back["a"]), np.asarray(tree["a"]))


def test_train_driver_resume_consistency():
    """PaME state checkpoint: save at k, restore, continue — bitwise equal
    to an uninterrupted run (counter-based RNG makes this exact)."""
    import jax

    from repro.core import PaMEConfig, build_topology
    from repro.core.pame import make_topology_arrays, pame_init, pame_step

    m = 4
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.5, p=0.5, gamma=1.05, sigma0=8.0, homogeneous_kappa=2)
    arrs = make_topology_arrays(topo, cfg)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, 16, 6)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((m, 16)), jnp.float32)

    def grad_fn(p, batch, key):
        aa, yy = batch
        r = aa @ p["w"] - yy
        return 0.5 * jnp.mean(r**2), {"w": aa.T @ r / aa.shape[0]}

    batch = (a, y)
    params = {"w": jnp.zeros((m, 6))}

    def roll(state, steps):
        for _ in range(steps):
            state, _ = pame_step(state, batch, grad_fn, arrs, cfg)
        return state

    s_full = roll(pame_init(jax.random.PRNGKey(0), {"w": jnp.zeros((m, 6))}, m, cfg), 10)

    s_half = roll(pame_init(jax.random.PRNGKey(0), {"w": jnp.zeros((m, 6))}, m, cfg), 5)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, s_half)
        restored = restore_checkpoint(d, s_half)
    s_resumed = roll(restored, 5)
    np.testing.assert_allclose(
        np.asarray(s_full.params["w"]), np.asarray(s_resumed.params["w"]), atol=1e-6
    )


def test_restore_falls_back_to_newest_intact_step():
    """Restore-without-step walks the fallback chain: a truncated newest
    payload is skipped and the next-older intact checkpoint restores;
    only when every step is corrupt does the newest error propagate."""
    with tempfile.TemporaryDirectory() as d:
        tree10 = {"a": jnp.full((16,), 10.0, jnp.float32)}
        tree20 = {"a": jnp.full((16,), 20.0, jnp.float32)}
        tree30 = {"a": jnp.full((16,), 30.0, jnp.float32)}
        save_checkpoint(d, 10, tree10, keep=5)
        save_checkpoint(d, 20, tree20, keep=5)
        dir30 = save_checkpoint(d, 30, tree30, keep=5)
        fpath = os.path.join(dir30, _leaf_files(dir30)[0])
        with open(fpath, "r+b") as f:
            f.truncate(os.path.getsize(fpath) - 24)
        # newest (30) is truncated -> 20 restores
        back = restore_checkpoint(d, tree10)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree20["a"]))
        # explicit step never falls back
        with pytest.raises(CheckpointCorruptError, match="truncated"):
            restore_checkpoint(d, tree10, 30)
        # corrupt 20 too (crc) -> 10 restores
        dir20 = os.path.join(d, "step_000000020")
        with open(os.path.join(dir20, _leaf_files(dir20)[0]), "r+b") as f:
            f.seek(-1, os.SEEK_END)
            flipped = f.read(1)[0] ^ 0xFF
            f.seek(-1, os.SEEK_END)
            f.write(bytes([flipped]))
        back = restore_checkpoint(d, tree10)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree10["a"]))
        # every step corrupt: the NEWEST step's error is the one raised
        dir10 = os.path.join(d, "step_000000010")
        os.remove(os.path.join(dir10, "manifest.json"))
        with pytest.raises(CheckpointCorruptError, match="truncated"):
            restore_checkpoint(d, tree10)
