"""Serve-while-train event layer: arrival processes, round pacing, and
the paced bind's equivalence guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import DPSGDHp, PaMEHp, get_algorithm
from repro.core.faults import FaultModel
from repro.core.scenarios import Scenario
from repro.core.temporal import TemporalScenario
from repro.core.topology import build_topology
from repro.serve.events import (
    ARRIVAL_PRESETS,
    ArrivalProcess,
    PacedCarry,
    ServePacing,
    expand_events,
    get_arrival,
)

M, N = 8, 5


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((M, 4, N)).astype(np.float32)
    y = rng.standard_normal((M, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    batch = (jnp.asarray(A), jnp.asarray(y))
    return grad_fn, (lambda k: batch), np.zeros(N, np.float32)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------
def test_arrival_presets_resolve():
    for name in ARRIVAL_PRESETS:
        proc = get_arrival(name)
        assert proc.name == name
    with pytest.raises(ValueError):
        get_arrival("nope")


def test_arrival_validation():
    with pytest.raises(ValueError):
        ArrivalProcess(rate=-1.0)
    with pytest.raises(ValueError):
        ArrivalProcess(p_up=1.5)
    with pytest.raises(ValueError):
        ServePacing(capacity=-1)


def test_event_clock_deterministic():
    pac = ServePacing(ArrivalProcess(name="b", rate=1.0, burst_rate=6.0),
                      capacity=2, defer_threshold=3)
    runs = []
    for _ in range(2):
        es = pac.init(M)
        trace = []
        for k in range(20):
            es, busy, _ = pac.advance(es, jnp.int32(k))
            trace.append(np.asarray(es.queue))
        runs.append(np.stack(trace))
    np.testing.assert_array_equal(runs[0], runs[1])


def test_poisson_rate_matches():
    """Mean arrivals per node per round ~ the configured rate."""
    rate = 2.0
    pac = ServePacing(ArrivalProcess(rate=rate), capacity=100,
                      defer_threshold=1000)
    es = pac.init(M)
    steps = 300
    for k in range(steps):
        es, _, _ = pac.advance(es, jnp.int32(k))
    mean = float(np.asarray(es.arrived).sum()) / (M * steps)
    assert abs(mean - rate) < 0.25


def test_littles_law_accounting():
    """wait/served is the mean sojourn: in an always-served system the
    queue never holds, so latency is 0; with capacity 0 nothing is ever
    served and wait grows."""
    pac = ServePacing(ArrivalProcess(rate=1.0), capacity=100,
                      defer_threshold=5)
    es = pac.init(M)
    for k in range(50):
        es, _, _ = pac.advance(es, jnp.int32(k))
    assert float(np.asarray(es.wait).sum()) == 0.0
    assert np.array_equal(np.asarray(es.served), np.asarray(es.arrived))

    starved = ServePacing(ArrivalProcess(rate=1.0), capacity=0,
                          defer_threshold=5)
    es = starved.init(M)
    for k in range(50):
        es, _, _ = starved.advance(es, jnp.int32(k))
    assert int(np.asarray(es.served).sum()) == 0
    assert float(np.asarray(es.wait).sum()) > 0.0


def test_expand_events_preserves_counters():
    pac = ServePacing(ArrivalProcess(rate=2.0), capacity=1,
                      defer_threshold=2)
    es = pac.init(M)
    for k in range(10):
        es, _, _ = pac.advance(es, jnp.int32(k))
    grown = expand_events(es, 3)
    assert grown.queue.shape == (M + 3,)
    np.testing.assert_array_equal(np.asarray(grown.arrived)[:M],
                                  np.asarray(es.arrived))
    assert int(np.asarray(grown.arrived)[M:].sum()) == 0
    assert expand_events(es, 0) is es


# ---------------------------------------------------------------------------
# Paced binds
# ---------------------------------------------------------------------------
def test_zero_rate_pacing_binds_unpaced_program():
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    alg = get_algorithm("dpsgd")
    b0 = alg.bind(grad_fn, topo, DPSGDHp(lr=0.1),
                  pacing=ServePacing(ArrivalProcess()))
    assert not b0.paced and not b0.dynamic and not b0.carries_aux
    key = jax.random.PRNGKey(1)
    s0, _ = b0.run(key, p0, M, batch_fn, 20)
    su, _ = alg.bind(grad_fn, topo, DPSGDHp(lr=0.1)).run(
        key, p0, M, batch_fn, 20)
    np.testing.assert_array_equal(np.asarray(s0.params),
                                  np.asarray(su.params))


def test_always_busy_equals_full_straggler():
    """A node that defers for load is EXACTLY a paper straggler: the
    flooded paced run (every node always over threshold) reproduces the
    straggler=1.0 scenario bitwise."""
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    alg = get_algorithm("dpsgd")
    key = jax.random.PRNGKey(1)
    flooded = ServePacing(ArrivalProcess(name="flood", rate=50.0),
                          capacity=1, defer_threshold=0)
    sp, hp = alg.bind(grad_fn, topo, DPSGDHp(lr=0.1), pacing=flooded).run(
        key, p0, M, batch_fn, 15)
    ss, _ = alg.bind(grad_fn, topo, DPSGDHp(lr=0.1),
                     scenario=Scenario(name="s", straggler=1.0)).run(
        key, p0, M, batch_fn, 15)
    np.testing.assert_array_equal(np.asarray(sp.params),
                                  np.asarray(ss.params))
    assert hp["deferred_nodes"][-1] == M


def test_paced_run_emits_event_metrics():
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    pac = ServePacing(ArrivalProcess(name="bursty", rate=0.5,
                                     burst_rate=8.0),
                      capacity=2, defer_threshold=4)
    bound = get_algorithm("pame").bind(
        grad_fn, topo, PaMEHp(nu=0.5, p=0.5), pacing=pac)
    assert bound.paced and bound.carries_aux
    state, hist = bound.run(jax.random.PRNGKey(0), p0, M, batch_fn, 25)
    for key in ("queue_depth", "served_reqs", "deferred_nodes"):
        assert key in hist and len(hist[key]) == 25
    assert all(0 <= d <= M for d in hist["deferred_nodes"])


def test_paced_composes_with_faults():
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    pac = ServePacing(ArrivalProcess(rate=3.0), capacity=1,
                      defer_threshold=2)
    bound = get_algorithm("dpsgd").bind(
        grad_fn, topo, DPSGDHp(lr=0.1), pacing=pac,
        faults=FaultModel(name="l", loss=0.3))
    assert bound.paced and bound.faulty
    state, hist = bound.run(jax.random.PRNGKey(0), p0, M, batch_fn, 15)
    assert "dropped_msgs" in hist and "deferred_nodes" in hist
    assert np.all(np.isfinite(hist["loss"]))


def test_paced_rejects_temporal():
    grad_fn, _, _ = _problem()
    topo = build_topology("ring", M)
    pac = ServePacing(ArrivalProcess(rate=1.0))
    with pytest.raises(NotImplementedError):
        get_algorithm("dpsgd").bind(
            grad_fn, topo, DPSGDHp(),
            scenario=TemporalScenario(name="t", burst_down=0.1),
            pacing=pac)


def test_paced_aux_is_paced_carry():
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    pac = ServePacing(ArrivalProcess(rate=1.0), capacity=1)
    bound = get_algorithm("dpsgd").bind(grad_fn, topo, DPSGDHp(lr=0.1),
                                        pacing=pac)
    from repro.core import baselines as B
    state = bound.init(jax.random.PRNGKey(0),
                       B.stack_params(p0, M))
    aux = bound.aux_init(state)
    assert isinstance(aux, PacedCarry)
    assert aux.inner is None
    assert aux.events.queue.shape == (M,)


def test_batched_paced_lanes_match_unbatched():
    """Lane (s, c) of a paced bind_batched reproduces the unbatched
    paced bind for that seed to fp tolerance."""
    grad_fn, batch_fn, p0 = _problem()
    topo = build_topology("ring", M)
    alg = get_algorithm("dpsgd")
    pac = ServePacing(ArrivalProcess(name="bursty", rate=0.5,
                                     burst_rate=6.0),
                      capacity=2, defer_threshold=3)
    bb = alg.bind_batched(grad_fn, topo, [DPSGDHp(lr=0.1)],
                          seeds=[0, 1], pacing=pac)
    assert bb.paced and bb.lanes == 2
    stb, hb = bb.run(p0, M, batch_fn, 12)
    for lane, seed in enumerate([0, 1]):
        # unbatched: same per-lane pace key (fold_in of the lane seed)
        pace_key = jax.random.fold_in(
            jax.random.PRNGKey(pac.process.seed), np.uint32(seed))
        bu = alg.bind(grad_fn, topo, DPSGDHp(lr=0.1), pacing=pac)
        bu.pace_key = pace_key
        su, hu = bu.run(jax.random.PRNGKey(seed), p0, M, batch_fn, 12)
        np.testing.assert_allclose(
            np.asarray(stb.params)[lane], np.asarray(su.params),
            rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(hb["deferred_nodes"])[:, lane],
            hu["deferred_nodes"])


# ---------------------------------------------------------------------------
# Consensus-serving failover
# ---------------------------------------------------------------------------
def test_component_mean_params_per_component():
    from repro.serve.serving import component_mean_params

    params = {"w": jnp.asarray([[0.0, 2.0], [2.0, 4.0],
                                [10.0, 20.0], [30.0, 40.0]], jnp.float32),
              "step": jnp.asarray(7)}  # scalar leaves pass through
    comp = np.asarray([0, 0, 1, 1])
    out = component_mean_params(params, comp)
    np.testing.assert_allclose(
        np.asarray(out["w"]),
        [[1.0, 3.0], [1.0, 3.0], [20.0, 30.0], [20.0, 30.0]])
    assert int(out["step"]) == 7
    # comp=None averages globally — every node serves the PME mean
    out = component_mean_params({"w": params["w"]}, None)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full((4, 2), [10.5, 16.5]))


def test_component_mean_params_preserves_dtype_and_shape():
    from repro.serve.serving import component_mean_params

    params = {"w": jnp.ones((4, 2, 3), jnp.bfloat16)}
    out = component_mean_params(params, np.asarray([0, 1, 0, 1]))
    assert out["w"].shape == (4, 2, 3)
    assert out["w"].dtype == jnp.bfloat16


def test_serve_round_rejects_unknown_policy():
    from repro.serve.serving import ServeLoop

    with pytest.raises(ValueError, match="unknown serving policy"):
        ServeLoop.serve_round(None, {"w": jnp.zeros((2, 3))},
                              policy="bogus")


def test_shrink_events_keeps_survivor_accounting():
    from repro.serve.events import shrink_events

    pac = ServePacing(ArrivalProcess(name="s", rate=3.0), capacity=2)
    es = pac.init(4)
    for k in range(6):
        es, _, _ = pac.advance(es, k)
    kept = shrink_events(es, [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(kept.arrived),
                                  np.asarray(es.arrived)[:3])
    np.testing.assert_array_equal(np.asarray(kept.wait),
                                  np.asarray(es.wait)[:3])
    assert shrink_events(es, [0, 1, 2, 3]) is es  # full keep: same object
