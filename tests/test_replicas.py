"""Per-receiver surrogate-replica conformance suite.

Pins the properties the replicated CHOCO/BEER/ANQ-NIDS variants must
guarantee (`repro.core.faults.rep_*`):

  * with faults bound but zero *actual* loss (a lossy-link chain whose
    bad state never drops), the replicated programs reproduce the
    classic single-surrogate trajectories to float tolerance — the
    replica plumbing itself is free;
  * the acceptance conformance: under 10% asymmetric message loss the
    surrogate replicas desync (desync metric > 0) and the ack/repair
    protocol spends real wire bits (repair traffic > 0), while PaME
    under the identical fault stream needs neither;
  * repair unit semantics: a lost innovation sets the pending flag and
    desyncs the replica; the next delivered message carries the full
    surrogate and resyncs it *exactly* (desync back to 0, pending
    cleared), charged at the uncompressed Eq.-(8) rate;
  * with repair disabled the desync is permanent (and free);
  * a batched fault-injected lane is bitwise the corresponding
    unbatched run (per-seed fault key folding).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import faults as flt
from repro.core.compression import identity
from repro.core.pme import message_bits
from repro.core.scenarios import Scenario, make_scenario_arrays, sample_masks
from repro.core.topology import build_topology

M = 8


def _zero_grad_fn(w, batch, key):
    del batch, key
    return jnp.zeros(()), jax.tree_util.tree_map(jnp.zeros_like, w)


def _linreg(m, n, spn=32, seed=0):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.1 * rng.standard_normal((m, spn))
    batch = (jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32))

    def grad_fn(w, b, key):
        aa, yy = b
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    return batch, grad_fn


HPS = {
    "choco": ALG.ChocoHp(lr=0.05, gossip_gamma=0.3, comp_frac=0.3),
    "beer": ALG.BeerHp(lr=0.05, gossip_gamma=0.4, comp_frac=0.2),
    "anq_nids": ALG.AnqNidsHp(lr=0.1, qsgd_levels=16),
}

# a non-static model whose lossy state never actually drops: the fault
# path runs end to end, yet every message is delivered
NO_ACTUAL_LOSS = flt.FaultModel(
    name="noop", burst_down=0.3, burst_up=0.3, loss_bad=0.0, seed=0
)


@pytest.mark.parametrize("name", sorted(HPS))
def test_zero_actual_loss_replicated_matches_classic(name):
    """Replicated programs with every message delivered reproduce the
    classic single-surrogate trajectory: replicas stay exact copies, so
    receiver-side mixing equals the global-surrogate mixing."""
    m, n = M, 12
    topo = build_topology("erdos_renyi", m, p=0.6, seed=0)
    batch, grad_fn = _linreg(m, n)
    classic = ALG.get_algorithm(name).bind(grad_fn, topo, HPS[name])
    faulted = ALG.get_algorithm(name).bind(
        grad_fn, topo, HPS[name], faults=NO_ACTUAL_LOSS
    )
    assert faulted.faulty
    stacked = jnp.zeros((m, n))
    s_c = classic.init(jax.random.PRNGKey(0), stacked, batch)
    s_f = faulted.init(jax.random.PRNGKey(0), stacked, batch)
    aux = faulted.aux_init(s_f)
    for k in range(6):
        s_c, m_c = classic.step(s_c, batch)
        s_f, m_f, aux = faulted.step(s_f, batch, k, aux)
        np.testing.assert_allclose(
            np.asarray(classic.params_of(s_c)),
            np.asarray(faulted.params_of(s_f)),
            rtol=1e-5, atol=1e-6, err_msg=f"step {k}",
        )
        assert float(m_f["surrogate_desync"]) < 1e-8
        assert float(m_f["repair_bits"]) == 0.0
        assert int(m_f["dropped_msgs"]) == 0


@pytest.mark.parametrize("name", sorted(HPS))
def test_lost_innovations_are_not_free(name):
    """Acceptance conformance: 10% asymmetric loss desyncs the surrogate
    replicas (desync > 0) and forces wire-charged repair traffic
    (repair bits > 0) — the cost the symmetric edge-removal scenario
    model could never see."""
    m, n = M, 12
    fm = flt.FaultModel(loss=0.1, seed=1)
    topo = build_topology("erdos_renyi", m, p=0.6, seed=0)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm(name).bind(
        grad_fn, topo, HPS[name], faults=fm
    )
    _, hist = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 10,
        tol_std=0.0,
    )
    assert sum(hist["dropped_msgs"]) > 0
    assert max(hist["surrogate_desync"]) > 0.0
    assert sum(hist["repair_bits"]) > 0.0
    # repair rides on top of the innovation traffic
    assert hist["wire_bits_total"] > sum(
        w - r for w, r in zip(hist["wire_bits"], hist["repair_bits"])
    )


def test_pame_needs_no_repair_under_same_faults():
    """PaME under the identical fault stream: no replicas, no repair keys
    in its history — lost messages only shrink the PME counts."""
    m, n = M, 12
    fm = flt.FaultModel(loss=0.1, seed=1)
    topo = build_topology("erdos_renyi", m, p=0.6, seed=0)
    batch, grad_fn = _linreg(m, n)
    bound = ALG.get_algorithm("pame").bind(
        grad_fn, topo, ALG.PaMEHp(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0),
        faults=fm,
    )
    _, hist = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 10,
        tol_std=0.0,
    )
    assert sum(hist["dropped_msgs"]) > 0
    assert "repair_bits" not in hist
    assert "surrogate_desync" not in hist
    assert all(np.isfinite(hist["loss"]))


def _clean_fault_realization(arrays, scen, k=0):
    """A FaultRealization over the static base graph with every message
    delivered (loss model that can never drop)."""
    fm = flt.FaultModel(burst_down=0.1, burst_up=0.9, loss_bad=0.0, seed=0)
    fs = flt.fault_state_init(fm, arrays, jax.random.PRNGKey(0))
    e, a, s = sample_masks(scen, arrays, k)
    _, fr = flt.advance_faults(
        fm, arrays, fs, jax.random.PRNGKey(0), k, e, a, s
    )
    assert bool(np.asarray(fr.recv_ok)[np.asarray(arrays.valid)].all())
    return fr


def test_repair_resyncs_exactly_and_is_wire_charged():
    """Unit semantics of one lost message: pending set + desync > 0 on the
    loss step; the next delivered message repairs the replica *exactly*
    (desync == 0, pending cleared), charged one full Eq.-(8) message."""
    m, n = 4, 6
    topo = build_topology("complete", m)
    scen = Scenario(name="static")
    arrays = make_scenario_arrays(topo, scen)
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    comp = identity()
    batch = None
    state = flt.rep_choco_init(jax.random.PRNGKey(0), stacked, arrays)

    fr_clean = _clean_fault_realization(arrays, scen)
    drop = np.zeros(np.asarray(arrays.nbrs).shape, bool)
    drop[0, 0] = True  # receiver 0 loses the message from nbrs[0, 0]
    fr_lost = fr_clean._replace(
        recv_ok=jnp.asarray(np.asarray(fr_clean.recv_ok) & ~drop)
    )
    innov = float(message_bits(n, n, 64))

    state, m1 = flt.rep_choco_step(
        state, batch, _zero_grad_fn, 0.1, comp, 0.5, fr_lost, arrays,
        innov, True,
    )
    assert float(m1["surrogate_desync"]) > 0.0
    assert float(m1["repair_bits"]) == 0.0  # nothing pending before the loss
    np.testing.assert_array_equal(np.asarray(state.pending), drop)

    state, m2 = flt.rep_choco_step(
        state, batch, _zero_grad_fn, 0.1, comp, 0.5, fr_clean, arrays,
        innov, True,
    )
    assert float(m2["surrogate_desync"]) == 0.0
    assert float(m2["repair_bits"]) == innov  # one full-surrogate resend
    assert not np.asarray(state.pending).any()


def test_no_repair_desync_is_permanent_and_free():
    """repair=False: the same lost message desyncs the replica forever —
    later deliveries carry only new innovations (zero under zero grads,
    once the surrogate converges), and no repair bits are ever spent."""
    m, n = 4, 6
    topo = build_topology("complete", m)
    scen = Scenario(name="static")
    arrays = make_scenario_arrays(topo, scen)
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    comp = identity()
    state = flt.rep_choco_init(jax.random.PRNGKey(0), stacked, arrays)
    fr_clean = _clean_fault_realization(arrays, scen)
    drop = np.zeros(np.asarray(arrays.nbrs).shape, bool)
    drop[0, 0] = True
    fr_lost = fr_clean._replace(
        recv_ok=jnp.asarray(np.asarray(fr_clean.recv_ok) & ~drop)
    )
    innov = float(message_bits(n, n, 64))
    state, m1 = flt.rep_choco_step(
        state, None, _zero_grad_fn, 0.1, comp, 0.5, fr_lost, arrays,
        innov, False,
    )
    d1 = float(m1["surrogate_desync"])
    assert d1 > 0.0
    for _ in range(3):
        state, mk = flt.rep_choco_step(
            state, None, _zero_grad_fn, 0.1, comp, 0.5, fr_clean, arrays,
            innov, False,
        )
        assert float(mk["surrogate_desync"]) > 0.0
        assert float(mk["repair_bits"]) == 0.0
    assert not np.asarray(state.pending).any()  # repair=False never tracks


def test_batched_fault_lane_matches_unbatched():
    """Each lane of a fault-injected batched run is the corresponding
    unbatched trajectory: per-seed fault keys fold exactly like the
    scenario keys, and the replica state vmaps through the lane axis.
    (Float tolerance, not bitwise: vmapped and unbatched lowerings fuse
    FMAs differently — the repo-wide caveat.)"""
    m, n = 6, 8
    fm = flt.FaultModel(loss=0.2, crash=0.05, rejoin=0.5, seed=3)
    topo = build_topology("erdos_renyi", m, p=0.6, seed=0)
    batch, grad_fn = _linreg(m, n)
    ba = ALG.get_algorithm("choco").bind_batched(
        grad_fn, topo, [HPS["choco"]], seeds=[0, 1], faults=fm
    )
    assert ba.faulty and ba.lanes == 2
    state = ba.init(jnp.zeros(n), m, batch)
    aux = ba.aux_init(state)
    hists = []
    for k in range(4):
        state, metrics, aux = ba.step(state, batch, k, aux)
        hists.append(metrics)
    for lane in range(ba.lanes):
        hp_vals = {f: v[lane] for f, v in ba._lane_hp.items()}
        ex = jax.tree_util.tree_map(lambda x: x[lane], ba._lane_extras)
        bound = ba._lane_bound(
            hp_vals, ex, ba._scen_keys[lane], ba._fault_keys[lane]
        )
        st = bound.init(ba._lane_keys[lane], jnp.zeros((m, n)), batch)
        ax = bound.aux_init(st)
        for k in range(4):
            st, mk, ax = bound.step(st, batch, k, ax)
            np.testing.assert_allclose(
                float(mk["surrogate_desync"]),
                float(hists[k]["surrogate_desync"][lane]),
                rtol=1e-5, atol=1e-7,
            )
        np.testing.assert_allclose(
            np.asarray(bound.params_of(st)),
            np.asarray(ba.params_of(state))[lane],
            rtol=1e-4, atol=1e-6,
        )
