"""Network partitions, healing, and the chaos timeline.

Covers the chaos grammar, the partition schedule's block-doubly-
stochastic realization (zero cross-component mass inside a window, the
base matrix back after heal), per-component consensus metrics in the
history buffers, and the serve_train bitwise pin: an empty chaos
timeline reproduces the plain serve-while-train run exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import PaMEHp, get_algorithm
from repro.core.scenarios import (
    PartitionWindow,
    Scenario,
    active_components,
    component_stats,
    make_scenario_arrays,
    partition_components,
    realization_matrix,
    realize,
)
from repro.core.topology import build_topology
from repro.serve import membership as mb

M = 8


def _topo(seed=3):
    return build_topology("erdos_renyi", M, p=0.5, seed=seed)


# ---------------------------------------------------------------------------
# Chaos grammar
# ---------------------------------------------------------------------------
def test_parse_chaos_spec_grammar():
    evs = mb.parse_chaos_spec(
        "leave@200:2,partition@400:bridge,heal@800,join@900:1", degree=3)
    assert evs == (
        mb.ChaosEvent(step=200, kind="leave", n=2),
        mb.ChaosEvent(step=400, kind="partition", n=2),
        mb.ChaosEvent(step=800, kind="heal"),
        mb.ChaosEvent(step=900, kind="join", n=1, degree=3),
    )
    assert mb.parse_chaos_spec("partition@10:3")[0].n == 3
    assert mb.parse_chaos_spec("join@5:2:4")[0].degree == 4
    assert mb.parse_chaos_spec(None) == ()
    assert mb.parse_chaos_spec("") == ()


def test_parse_chaos_spec_rejects_malformed():
    for bad in ("leave@10", "heal@10:1", "partition@10",
                "reboot@10:1", "leave:10:1"):
        with pytest.raises(ValueError):
            mb.parse_chaos_spec(bad)
    with pytest.raises(ValueError):
        mb.ChaosEvent(step=1, kind="partition", n=1)


def test_chaos_partitions_folds_windows():
    evs = mb.parse_chaos_spec("partition@4:bridge,heal@8,partition@12:3")
    windows = mb.chaos_partitions(evs, num_steps=20, seed=7)
    assert windows == (
        PartitionWindow(start=4, heal=8, n_parts=2, seed=7),
        PartitionWindow(start=12, heal=20, n_parts=3, seed=7),  # unhealed
    )
    assert mb.chaos_partitions(mb.parse_chaos_spec("leave@4:1"), 20) == ()


def test_chaos_partitions_rejects_bad_pairing():
    with pytest.raises(ValueError, match="still open"):
        mb.chaos_partitions(
            mb.parse_chaos_spec("partition@4:2,partition@6:2"), 20)
    with pytest.raises(ValueError, match="without an open"):
        mb.chaos_partitions(mb.parse_chaos_spec("heal@4"), 20)


def test_scenario_rejects_overlapping_windows():
    with pytest.raises(ValueError):
        Scenario(name="x", partitions=(
            PartitionWindow(start=2, heal=10),
            PartitionWindow(start=6, heal=12),
        ))
    scen = Scenario(name="x", partitions=(PartitionWindow(start=2, heal=4),))
    assert not scen.is_static
    assert scen.max_parts == 2


# ---------------------------------------------------------------------------
# Partition schedule realization
# ---------------------------------------------------------------------------
def test_partition_components_connected_cover():
    topo = _topo()
    comp = partition_components(topo, PartitionWindow(start=0, heal=1,
                                                      n_parts=3, seed=1))
    assert comp.shape == (M,)
    assert set(np.unique(comp)) == {0, 1, 2}
    # every part is internally connected in the base graph
    for c in range(3):
        nodes = np.nonzero(comp == c)[0]
        sub = topo.adjacency[np.ix_(nodes, nodes)]
        reach = {0}
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(sub[i])[0]:
                if j not in reach:
                    reach.add(int(j))
                    frontier.append(int(j))
        assert len(reach) == len(nodes)


def test_partition_components_explicit_validated():
    topo = _topo()
    w = PartitionWindow(start=0, heal=1,
                        components=((0, 1, 2, 3), (4, 5, 6, 7)))
    comp = partition_components(topo, w)
    np.testing.assert_array_equal(comp, [0, 0, 0, 0, 1, 1, 1, 1])
    with pytest.raises(ValueError):  # node 7 missing: not a cover
        partition_components(topo, PartitionWindow(
            start=0, heal=1, components=((0, 1, 2, 3), (4, 5, 6))))


def test_partition_realization_block_doubly_stochastic():
    """Inside the window the realized matrix is block-DS per component —
    zero cross-component mass, rows/cols still sum to 1 (the per-step MH
    rebuild keeps Assumption 1 within every component)."""
    topo = _topo()
    scen = Scenario(name="split", edge_drop=0.2, seed=1,
                    partitions=(PartitionWindow(start=3, heal=7, seed=2),))
    arrays = make_scenario_arrays(topo, scen)
    comp = partition_components(topo, scen.partitions[0])
    cross = comp[:, None] != comp[None, :]
    for k in range(10):
        r = realize(scen, arrays, jnp.int32(k))
        w = np.asarray(realization_matrix(arrays, r), np.float64)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-5)
        np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-5)
        if 3 <= k < 7:
            assert w[cross].sum() == 0.0
            # per-component mean preservation (block-DS)
            x = np.random.default_rng(k).standard_normal((M, 3))
            for c in np.unique(comp):
                sel = comp == c
                np.testing.assert_allclose(
                    (w @ x)[sel].mean(axis=0), x[sel].mean(axis=0),
                    atol=1e-5)


def test_heal_restores_base_matrix():
    """A partitions-only scenario realizes the full MH mixing outside the
    window (no PRNG is consumed by the cut, so the heal is exact) and a
    strictly-cut matrix inside."""
    topo = _topo()
    scen = Scenario(name="split-only", seed=1,
                    partitions=(PartitionWindow(start=2, heal=5, seed=2),))
    arrays = make_scenario_arrays(topo, scen)
    comp = partition_components(topo, scen.partitions[0])
    cross = comp[:, None] != comp[None, :]
    for k in (0, 1, 5, 6):
        r = realize(scen, arrays, jnp.int32(k))
        w = np.asarray(realization_matrix(arrays, r))
        np.testing.assert_allclose(w, topo.mixing, atol=1e-6)
    for k in (2, 3, 4):
        r = realize(scen, arrays, jnp.int32(k))
        w = np.asarray(realization_matrix(arrays, r))
        assert w[cross].sum() == 0.0
        assert not np.allclose(w, topo.mixing, atol=1e-6)


def test_active_components_window_gating():
    topo = _topo()
    scen = Scenario(name="split-only", seed=1,
                    partitions=(PartitionWindow(start=2, heal=5, seed=2),))
    arrays = make_scenario_arrays(topo, scen)
    comp = partition_components(topo, scen.partitions[0])
    np.testing.assert_array_equal(
        np.asarray(active_components(arrays, jnp.int32(1))), np.zeros(M))
    np.testing.assert_array_equal(
        np.asarray(active_components(arrays, jnp.int32(3))), comp)
    np.testing.assert_array_equal(
        np.asarray(active_components(arrays, jnp.int32(5))), np.zeros(M))


def test_component_stats_hand_built():
    comp = jnp.asarray([0, 0, 1, 1], jnp.int32)
    x = jnp.asarray([[0.0], [2.0], [10.0], [14.0]], jnp.float32)
    cc, gap = component_stats(comp, x, 2)
    # per-node deviation from own component mean: 1,1,2,2 -> mean sq = 2.5
    assert float(cc) == pytest.approx(2.5)
    # comp means 1 and 12, global mean 6.5 -> max gap 5.5
    assert float(gap) == pytest.approx(5.5)


# ---------------------------------------------------------------------------
# Per-component metrics in the history buffers
# ---------------------------------------------------------------------------
def test_partition_metrics_in_history():
    """A partitioned bind emits comp_consensus / comp_mean_gap per step;
    the component mean gap blows up inside the window and reconverges
    after heal (PaME's memoryless averaging heals the drift)."""
    topo = _topo()
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M, 4, 5)).astype(np.float32)
    y = rng.standard_normal((M, 4)).astype(np.float32)

    def grad_fn(p, b, k):
        Ab, yb = b
        r = Ab @ p - yb
        return 0.5 * jnp.mean(r * r), Ab.T @ r / r.shape[0]

    steps = 40
    scen = Scenario(name="split-only", seed=1,
                    partitions=(PartitionWindow(start=10, heal=20, seed=2),))
    bound = get_algorithm("pame").bind(
        grad_fn, topo, PaMEHp(nu=0.5, p=0.5), scenario=scen)
    batch = (jnp.asarray(A), jnp.asarray(y))
    _, hist = bound.run(jax.random.PRNGKey(1), np.zeros(5, np.float32),
                        M, lambda k: batch, steps)
    assert len(hist["comp_consensus"]) == steps
    gap = np.asarray(hist["comp_mean_gap"])
    in_window = gap[10:20].max()
    assert in_window > 10 * max(gap[:10].max(), 1e-12)
    assert gap[-1] < 0.1 * in_window  # post-heal reconvergence


# ---------------------------------------------------------------------------
# serve_train: empty timeline is bitwise the plain path
# ---------------------------------------------------------------------------
SERVE_ARGS = ["--arch", "stablelm-1.6b", "--variant", "smoke",
              "--steps", "4", "--batch", "1", "--seq", "16",
              "--nodes", "4", "--chunk", "2", "--arrival", "quiet",
              "--prompt-len", "4", "--gen", "2", "--serve-batch", "1",
              "--serve-nodes", "1"]


def test_empty_chaos_timeline_bitwise_pin(capsys):
    """`--chaos ""` must leave every code path of the plain serve_train
    run untouched: final states are bitwise identical leaf by leaf."""
    from repro.launch import serve_train as sv

    plain = sv.main(SERVE_ARGS)
    empty = sv.main(SERVE_ARGS + ["--chaos", ""])
    capsys.readouterr()
    for a, b in zip(jax.tree_util.tree_leaves(plain),
                    jax.tree_util.tree_leaves(empty)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_train_chaos_smoke(capsys):
    """One run through every event kind: leave, partition, heal, with
    consensus serving — monitors must come back green."""
    from repro.launch import serve_train as sv

    sv.main(["--arch", "stablelm-1.6b", "--variant", "smoke",
             "--steps", "8", "--batch", "1", "--seq", "16",
             "--nodes", "5", "--chunk", "2", "--arrival", "quiet",
             "--prompt-len", "4", "--gen", "2", "--serve-batch", "1",
             "--serve-nodes", "1", "--serve-policy", "consensus",
             "--chaos", "leave@2:1,partition@4:bridge,heal@6"])
    out = capsys.readouterr().out
    assert "leave@2: m=5->4" in out
    assert "partition@4: graph split into 2 components" in out
    assert "heal@6: partition re-merged" in out
    assert out.count("(green)") >= 3  # leave conformance + 2 monitors
    assert "[serve-train] done" in out
