"""Scan-fused execution engine: same-seed equivalence with the host loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core import baselines as B
from repro.core.engine import run_scan_loop


def _linreg(m=10, n=32, spn=48, seed=0, noise=0.5):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    b = a @ w_star + noise * rng.standard_normal((m, spn))
    a_j, b_j = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - b_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    return (a_j, b_j), grad_fn, objective


@pytest.mark.parametrize("chunk_size", [7, 32])
def test_scan_driver_matches_host_loop(chunk_size):
    """Same seed, same trajectory: params bit-compatible, metrics <= 1e-5
    relative error, across chunk sizes that do and don't divide num_steps."""
    m, n = 10, 32
    batch, grad_fn, objective = _linreg(m=m, n=n)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    cfg = PaMEConfig(nu=0.3, p=0.2, gamma=1.01, sigma0=8.0)
    kwargs = dict(
        num_steps=60, objective_fn=objective, tol_std=0.0,
    )
    st_h, h_h = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn, lambda k: batch,
        topo, cfg, driver="host", **kwargs,
    )
    st_s, h_s = run_pame(
        jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn, lambda k: batch,
        topo, cfg, driver="scan", chunk_size=chunk_size, **kwargs,
    )
    assert h_h["steps_run"] == h_s["steps_run"] == 60
    np.testing.assert_allclose(
        np.asarray(st_s.params), np.asarray(st_h.params), rtol=1e-6, atol=1e-6
    )
    for key in ("loss", "objective", "consensus"):
        a_ = np.asarray(h_h[key])
        b_ = np.asarray(h_s[key])
        np.testing.assert_allclose(b_, a_, rtol=1e-5, atol=1e-6)


def test_scan_driver_early_termination_matches_host():
    """The std-based rule fires at the same step and the returned state is
    the state *at* the triggering step (frozen inside the scan)."""
    m, n = 8, 24
    batch, grad_fn, objective = _linreg(m=m, n=n, seed=3)
    topo = build_topology("complete", m)
    cfg = PaMEConfig(nu=0.5, p=0.5, gamma=1.05, sigma0=8.0)
    runs = {}
    for driver in ("host", "scan"):
        runs[driver] = run_pame(
            jax.random.PRNGKey(0), jnp.zeros(n), m, grad_fn, lambda k: batch,
            topo, cfg, num_steps=1000, objective_fn=objective, tol_std=1e-3,
            driver=driver,
        )
    st_h, h_h = runs["host"]
    st_s, h_s = runs["scan"]
    assert h_h["steps_run"] < 1000  # the rule actually fired
    assert h_s["steps_run"] == h_h["steps_run"]
    np.testing.assert_allclose(
        np.asarray(st_s.params), np.asarray(st_h.params), rtol=1e-6, atol=1e-6
    )
    assert len(h_s["objective"]) == h_s["steps_run"]


def test_scan_driver_varying_batches():
    """batch_fn returning a fresh pytree per step exercises the stacked-xs
    path; trajectories must still match the host loop."""
    m, n = 6, 16
    rng = np.random.default_rng(0)
    data = [
        (jnp.asarray(rng.standard_normal((m, 8, n)), jnp.float32),
         jnp.asarray(rng.standard_normal((m, 8)), jnp.float32))
        for _ in range(30)
    ]

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    topo = build_topology("ring", m)
    cfg = PaMEConfig(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0)
    outs = {}
    for driver in ("host", "scan"):
        outs[driver] = run_pame(
            jax.random.PRNGKey(1), jnp.zeros(n), m, grad_fn,
            lambda k: data[k], topo, cfg, num_steps=30, tol_std=0.0,
            driver=driver,
        )
    np.testing.assert_allclose(
        np.asarray(outs["scan"][0].params),
        np.asarray(outs["host"][0].params),
        rtol=1e-6, atol=1e-6,
    )
    np.testing.assert_allclose(
        outs["scan"][1]["loss"], outs["host"][1]["loss"], rtol=1e-5, atol=1e-7
    )


def test_run_algorithm_scan_matches_host():
    m, n = 8, 20
    batch, grad_fn, objective = _linreg(m=m, n=n, seed=5)
    topo = build_topology("erdos_renyi", m, p=0.5, seed=0)
    bmat = jnp.asarray(topo.mixing)
    w0 = B.stack_params(jnp.zeros(n), m)
    key = jax.random.PRNGKey(0)
    outs = {}
    for driver in ("host", "scan"):
        outs[driver] = B.run_algorithm(
            lambda s_, b_: B.dpsgd_step(s_, b_, grad_fn, bmat, 0.1),
            B.dpsgd_init(key, w0), lambda k: batch, 50,
            objective_fn=objective, tol_std=0.0, driver=driver,
        )
    np.testing.assert_allclose(
        np.asarray(outs["scan"][0].params),
        np.asarray(outs["host"][0].params),
        rtol=1e-6, atol=1e-6,
    )
    np.testing.assert_allclose(
        outs["scan"][1]["objective"], outs["host"][1]["objective"],
        rtol=1e-5, atol=1e-6,
    )
    # donation must not invalidate the caller's shared initial stack
    assert np.isfinite(np.asarray(w0)).all()


def test_exact_pytree_kernel_route_matches_einsum():
    """The fused Pallas kernel must agree with the einsum path on a leaf
    above the routing threshold (the accelerator hot path; on CPU the
    pytree route itself stays on einsum and the kernel runs interpreted
    here just to pin the equivalence)."""
    from repro.core import pme
    from repro.kernels.pme_average.ops import pme_average as pme_average_fused

    m, d1, d2 = 8, 512, 40  # flat size 8*20480 > _KERNEL_MIN_ELEMS
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((m, d1, d2)), jnp.float32)}
    a = jnp.asarray(
        ((rng.random((m, m)) < 0.5) & ~np.eye(m, dtype=bool)).astype(np.float32)
    )
    key = jax.random.PRNGKey(0)
    flat = tree["w"].reshape(m, -1)
    assert flat.size >= pme._KERNEL_MIN_ELEMS
    n = flat.shape[1]
    s = max(1, int(round(0.2 * n)))
    masks = pme.sample_coordinate_masks(
        jax.random.fold_in(key, 0), m, n, s, mode="exact"
    )
    ref = pme.pme_average(flat, masks, a).reshape(tree["w"].shape)
    fused = pme_average_fused(flat, masks, a).reshape(tree["w"].shape)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), atol=1e-5)
    # and the pytree entry point (whichever route it picks on this backend)
    out = pme.pme_average_pytree(key, tree, a, p=0.2, mode="exact")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref), atol=1e-5)


def test_engine_preserves_initial_state_buffers():
    """run_scan_loop donates its carry; the caller's state must survive."""
    m, n = 6, 12
    batch, grad_fn, _ = _linreg(m=m, n=n, seed=7)
    topo = build_topology("complete", m)
    bmat = jnp.asarray(topo.mixing)
    w0 = B.stack_params(jnp.ones(n), m)
    state0 = B.dpsgd_init(jax.random.PRNGKey(0), w0)
    run_scan_loop(
        lambda s_, b_: B.dpsgd_step(s_, b_, grad_fn, bmat, 0.1),
        state0, lambda k: batch, 10, tol_std=0.0,
    )
    # reusing the same state object for a second run must not raise
    _, metrics, info = run_scan_loop(
        lambda s_, b_: B.dpsgd_step(s_, b_, grad_fn, bmat, 0.1),
        state0, lambda k: batch, 10, tol_std=0.0,
    )
    assert info["steps_run"] == info["steps_dispatched"] == 10
    assert np.isfinite(metrics["loss_mean"]).all()


def test_engine_const_batch_detected_through_fresh_containers():
    """batch_fn rebuilding the tuple around the same arrays every step must
    hit the constant-batch fast path (no chunk_size-fold stacking) and still
    match the host loop."""
    m, n = 6, 16
    batch, grad_fn, _ = _linreg(m=m, n=n, seed=11)
    topo = build_topology("ring", m)
    cfg = PaMEConfig(nu=0.5, p=0.3, gamma=1.01, sigma0=8.0)
    outs = {}
    for driver in ("host", "scan"):
        outs[driver] = run_pame(
            jax.random.PRNGKey(2), jnp.zeros(n), m, grad_fn,
            lambda k: (batch[0], batch[1]),  # fresh tuple, same arrays
            topo, cfg, num_steps=20, tol_std=0.0, driver=driver,
        )
    np.testing.assert_allclose(
        np.asarray(outs["scan"][0].params),
        np.asarray(outs["host"][0].params),
        rtol=1e-6, atol=1e-6,
    )


def test_k_start_offsets_step_index_and_termination_window():
    """run(..., k_start=) hands the global index to 3-arg steps, and the
    std-termination guard counts steps into *this run*: a resumed run with
    a tiny objective must still fill its 3-value window (3 steps), never
    fire on the zero-padded warm-up after 1."""
    from repro.core.engine import make_scan_runner

    seen = []

    def step_fn(state, batch, k):
        seen.append(None)  # trace count, not per-step
        return state + 0.0, {"loss_mean": jnp.zeros(()), "k": k}

    runner = make_scan_runner(
        step_fn,
        objective_fn=lambda p: jnp.asarray(1e-3),  # constant, << 2.1*tol
        params_of=lambda s: s,
        tol_std=1e-2,
        chunk_size=4,
        donate=False,
        step_takes_index=True,
    )
    state = jnp.zeros((4, 2))
    _, metrics, info = runner(state, lambda k: None, 8, k_start=100)
    # window fills at the 3rd step of the run and fires immediately (the
    # objective is constant); firing after 1 step would mean the guard
    # leaked the global index
    assert info["steps_run"] == 3
    np.testing.assert_array_equal(
        np.asarray(metrics["k"]), np.arange(100, 103)
    )
    # fresh runner, no offset: same rule, same step count
    _, metrics0, info0 = runner(state, lambda k: None, 8)
    assert info0["steps_run"] == 3
    np.testing.assert_array_equal(np.asarray(metrics0["k"]), np.arange(3))


def test_setup_compilation_cache(tmp_path, monkeypatch):
    """The cache helper: no-op when unset, env fallback, explicit dir wins,
    and the configured dir actually receives cache entries on compile."""
    import os

    import jax

    from repro.core.engine import setup_compilation_cache

    prior = jax.config.jax_compilation_cache_dir
    try:
        monkeypatch.delenv("REPRO_COMPILE_CACHE", raising=False)
        assert setup_compilation_cache() is None  # unset -> disabled

        env_dir = tmp_path / "env_cache"
        monkeypatch.setenv("REPRO_COMPILE_CACHE", str(env_dir))
        assert setup_compilation_cache() == str(env_dir)

        explicit = tmp_path / "explicit"
        assert setup_compilation_cache(str(explicit)) == str(explicit)
        assert jax.config.jax_compilation_cache_dir == str(explicit)

        # a fresh jit closure compiled now must land an entry on disk
        fn = jax.jit(lambda x: x * 2.0 + 1.0)
        jax.block_until_ready(fn(jnp.arange(8.0)))
        entries = [
            f for f in os.listdir(explicit) if not f.endswith("-atime")
        ]
        assert entries, "persistent cache wrote no entries"
    finally:
        jax.config.update("jax_compilation_cache_dir", prior)
