"""Sharding rules + a miniature end-to-end dry-run in a subprocess
(the subprocess gets its own XLA_FLAGS with fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import jax

from repro.sharding import fit_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_1dev():
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return Mesh(dev, ("node", "fsdp", "model"))


def test_fit_spec_drops_nondivisible():
    mesh_dims = {"node": 4, "fsdp": 2, "model": 8}

    class FakeMesh:
        axis_names = tuple(mesh_dims)
        devices = np.empty(tuple(mesh_dims.values()))

    spec = fit_spec(("fsdp", "model"), (64, 128), FakeMesh())
    assert spec == P("fsdp", "model")
    spec = fit_spec(("fsdp", "model"), (63, 128), FakeMesh())
    assert spec == P(None, "model")
    # padding for extra leading dims
    spec = fit_spec(("fsdp", "model"), (10, 64, 128), FakeMesh())
    assert spec == P(None, "fsdp", "model")
    # duplicate axis collapses to one use
    spec = fit_spec(("model", "model"), (64, 64), FakeMesh())
    assert spec == P("model", None)


def test_fit_spec_fallback_candidates():
    class FakeMesh:
        axis_names = ("node", "fsdp", "model")
        devices = np.empty((2, 1, 16))

    # kv=8 cannot shard over model=16 -> falls to head_dim 128
    spec = fit_spec((("model",), ("model",)), (8, 128), FakeMesh())
    assert spec == P(None, "model")


DRYRUN_SNIPPET = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import numpy as np
    from jax.sharding import Mesh
    import repro.launch.dryrun as dr
    import repro.launch.mesh as lm
    from repro.launch.mesh import mesh_axis_kwargs

    # shrink the production mesh so the test runs fast on 8 fake devices
    def tiny_prod(*, multi_pod=False):
        shape = (2, 2, 2) if multi_pod else (4, 2)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))

    def tiny_logical(cfg, *, multi_pod=False, production=None):
        prod = production or tiny_prod(multi_pod=multi_pod)
        devs = np.asarray(prod.devices).reshape(-1)
        return Mesh(devs.reshape(2, 2, 2), ("node", "fsdp", "model"),
                    **mesh_axis_kwargs(3))

    lm.make_production_mesh = tiny_prod
    dr.make_production_mesh = tiny_prod
    dr.make_logical_mesh = tiny_logical

    # reduced shapes so the smoke config compiles in seconds
    from repro.configs.shapes import InputShape
    dr.INPUT_SHAPES = {
        "train_4k": InputShape("train_4k", 64, 8, "train"),
        "decode_32k": InputShape("decode_32k", 128, 8, "decode"),
        "prefill_32k": InputShape("prefill_32k", 64, 4, "prefill"),
    }
    from repro.configs import get_config as real_get
    dr.get_config = lambda name, variant="full": real_get(name, "smoke")

    out = {}
    for shape in ["train_4k", "prefill_32k", "decode_32k"]:
        for mesh in ["single", "multi"]:
            rec = dr.run_combo("ARCH", shape, mesh, remat=False)
            out[f"{shape}|{mesh}"] = {
                "flops": rec["flops_per_device"],
                "coll": rec["collective_bytes_total"],
                "layout": rec["layout"],
            }
    print("RESULT" + json.dumps(out))
    """
)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "zamba2-1.2b", "deepseek-v2-lite-16b"])
def test_mini_dryrun_subprocess(arch):
    """Every step kind lowers+compiles on an 8-device (node,fsdp,model) mesh,
    single- and multi-pod, for a dense, a hybrid and an MoE/MLA arch."""
    code = DRYRUN_SNIPPET.replace("ARCH", arch)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    payload = [l for l in res.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(payload[len("RESULT"):])
    assert len(out) == 6
    for key, rec in out.items():
        assert rec["flops"] > 0, key
        if "train" in key:
            assert rec["coll"] > 0, f"train step must gossip: {key}"


def test_production_mesh_shapes():
    """make_production_mesh contract (verified abstractly on device counts)."""
    from repro.launch.mesh import fsdp_degree
    from repro.configs import get_config

    # big archs get fsdp > 1, small archs fsdp == 1
    assert fsdp_degree(get_config("stablelm-1.6b"), 256) == 1
    assert fsdp_degree(get_config("yi-34b"), 256) > 1
    assert fsdp_degree(get_config("deepseek-v2-236b"), 256) >= 8
    # node count stays >= 2
    for arch in ("yi-34b", "deepseek-v2-236b"):
        f = fsdp_degree(get_config(arch), 256)
        assert 256 // (f * 16) >= 2
