"""Pytree-partitioned partial exchange (PaMEConfig.partition="tree").

Covers the three contract pieces of the partitioned format:

  * the flat path is BITWISE-identical to the pre-partition code — the
    pinned loss/consensus curves below were captured before the feature
    landed and must reproduce exactly;
  * per-leaf Eq.-(8) accounting matches a hand-computed total, both in
    the static registry estimate (`wire_bits_for`) and in the realized
    per-step metric under a dynamic scenario;
  * config validation fails loudly (bad partition, p_leaf misuse, rate
    bounds, leaf-count mismatch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PaMEConfig, build_topology
from repro.core.algorithms import get_algorithm
from repro.core.pme import leaf_rates, message_bits, tree_message_bits

M = 8


def _problem():
    """Quadratic toward fixed targets over a 2-leaf pytree (sizes 55+37)."""
    rng = np.random.default_rng(0)
    tgt = {"w": jnp.asarray(rng.standard_normal(37), jnp.float32),
           "v": jnp.asarray(rng.standard_normal((5, 11)), jnp.float32)}
    params0 = {"w": jnp.zeros((37,), jnp.float32),
               "v": jnp.zeros((5, 11), jnp.float32)}

    def grad_fn(p, b, k):
        loss = sum(jnp.sum((p[n] - tgt[n]) ** 2) for n in sorted(p))
        g = {n: 2.0 * (p[n] - tgt[n]) for n in p}
        return loss, g

    return params0, grad_fn


def _consensus(params):
    tot = 0.0
    for leaf in jax.tree_util.tree_leaves(params):
        mu = leaf.mean(axis=0, keepdims=True)
        tot = tot + jnp.sum((leaf - mu) ** 2)
    return tot


# Captured at the commit BEFORE partition="tree" existed (same problem,
# same seeds).  Sampled at steps [0, 3, 7, 11] of a 12-step run.
FLAT_PINS = {
    ("bernoulli", "sparse"): (
        [87.24075317382812, 14.000433921813965, 1.6537327766418457,
         0.21160268783569336],
        [6.774550437927246, 38.692359924316406, 61.057186126708984,
         69.43019104003906], 120640),
    ("bernoulli", "dense"): (
        [87.24075317382812, 14.000433921813965, 1.6537327766418457,
         0.21160268783569336],
        [6.774550437927246, 38.692359924316406, 61.057186126708984,
         69.43019104003906], 120640),
    ("exact", "sparse"): (
        [87.24075317382812, 13.98813533782959, 1.582690715789795,
         0.19989681243896484],
        [6.774550437927246, 38.752159118652344, 61.375789642333984,
         69.67984008789062], 120640),
    ("exact", "dense"): (
        [87.24075317382812, 13.98813533782959, 1.582690715789795,
         0.19989681243896484],
        [6.774550437927246, 38.752159118652344, 61.375789642333984,
         69.67984008789062], 120640),
}


@pytest.mark.parametrize("mask_mode,mixing", sorted(FLAT_PINS))
def test_flat_path_bitwise_identical_to_pre_partition_pins(mask_mode, mixing):
    params0, grad_fn = _problem()
    topo = build_topology("erdos_renyi", M, p=0.5, seed=3)
    cfg = PaMEConfig(nu=0.5, p=0.3, gamma=1.01, sigma0=4.0,
                     kappa_lo=2, kappa_hi=4, mask_mode=mask_mode)
    ba = get_algorithm("pame").bind(grad_fn, topo, cfg, mixing=mixing, seed=0)
    state, hist = ba.run(jax.random.PRNGKey(1), params0, M, lambda k: None,
                         12, objective_fn=_consensus, tol_std=0.0)
    pin_loss, pin_obj, pin_wire = FLAT_PINS[(mask_mode, mixing)]
    loss = [float(x) for x in np.asarray(hist["loss"])[[0, 3, 7, 11]]]
    obj = [float(x) for x in np.asarray(hist["objective"])[[0, 3, 7, 11]]]
    assert loss == pin_loss          # bitwise: exact float equality
    assert obj == pin_obj
    assert int(hist["wire_bits_total"]) == pin_wire


# ---------------------------------------------------------------------------
# Eq. (8) per-leaf accounting
# ---------------------------------------------------------------------------
def test_tree_message_bits_matches_hand_computed_total():
    # dict pytrees flatten in sorted key order: "v" (5*11=55), "w" (37)
    sizes = (55, 37)
    # uniform p=0.3:  s_v = round(16.5) = 16 (banker's), s_w = round(11.1) = 11
    hand = (63 * 16 + 55) + (63 * 11 + 37)
    assert tree_message_bits(sizes, 0.3) == hand
    assert tree_message_bits(sizes, (0.3, 0.3)) == hand
    # per-leaf rates (mirror the implementation's round() exactly)
    s_v = max(1, int(round(0.1 * 55)))
    s_w = max(1, int(round(0.8 * 37)))
    hand2 = (63 * s_v + 55) + (63 * s_w + 37)
    assert tree_message_bits(sizes, (0.1, 0.8)) == hand2
    # int8 payload variant: 8s + n + one f32 absmax scale per segment
    assert tree_message_bits(sizes, 0.3, value_bits=8) == \
        (8 * 16 + 55 + 32) + (8 * 11 + 37 + 32)
    with pytest.raises(ValueError, match="rates"):
        tree_message_bits(sizes, (0.3,))


def test_leaf_rates_validation():
    assert leaf_rates(3, 0.2) == (0.2, 0.2, 0.2)
    assert leaf_rates(2, 0.2, (0.1, 0.9)) == (0.1, 0.9)
    with pytest.raises(ValueError, match="leaves"):
        leaf_rates(3, 0.2, (0.1, 0.9))
    with pytest.raises(ValueError, match="rate"):
        leaf_rates(2, 0.2, (0.1, 1.5))
    with pytest.raises(ValueError, match="rate"):
        leaf_rates(2, 0.2, (0.0, 0.5))


def test_static_wire_accounting_is_per_leaf_for_tree():
    params0, grad_fn = _problem()
    topo = build_topology("erdos_renyi", M, p=0.5, seed=3)
    kw = dict(nu=0.5, p=0.3, gamma=1.01, sigma0=4.0, kappa_lo=2, kappa_hi=4,
              mask_mode="exact")
    flat = get_algorithm("pame").bind(grad_fn, topo, PaMEConfig(**kw),
                                      mixing="dense", seed=0)
    tree = get_algorithm("pame").bind(
        grad_fn, topo, PaMEConfig(partition="tree", **kw),
        mixing="dense", seed=0)
    n = 92
    msgs = flat.wire_bits_for(params0) / message_bits(
        max(1, int(round(0.3 * n))), n)
    # same expected message count, different per-message price
    assert tree.wire_bits_for(params0) == pytest.approx(
        msgs * tree_message_bits((55, 37), 0.3))
    assert flat.wire_bits_for(params0) != tree.wire_bits_for(params0)
    # the flat sizes-aware path must agree with the legacy n_total formula
    assert flat.wire_bits_for(params0) == pytest.approx(flat.wire_bits(n))


def test_realized_dynamic_accounting_scales_by_per_leaf_price():
    """Under edge drops both partitions realize the SAME message count per
    step (comm decisions don't depend on the payload format), so the
    realized totals must differ exactly by the per-message Eq.-(8) ratio."""
    params0, grad_fn = _problem()
    topo = build_topology("erdos_renyi", M, p=0.5, seed=3)
    from repro.core.scenarios import get_scenario
    import dataclasses
    scen = dataclasses.replace(get_scenario("flaky_links"), seed=7)
    kw = dict(nu=0.5, p=0.3, gamma=1.01, sigma0=4.0, kappa_lo=2, kappa_hi=4,
              mask_mode="exact")
    totals = {}
    for name, cfg in [("flat", PaMEConfig(**kw)),
                      ("tree", PaMEConfig(partition="tree", **kw))]:
        ba = get_algorithm("pame").bind(grad_fn, topo, cfg, mixing="dense",
                                        seed=0, scenario=scen)
        _, hist = ba.run(jax.random.PRNGKey(1), params0, M, lambda k: None,
                         12, tol_std=0.0)
        totals[name] = float(hist["wire_bits_total"])
    n = 92
    flat_price = message_bits(max(1, int(round(0.3 * n))), n)
    tree_price = tree_message_bits((55, 37), 0.3)
    assert totals["flat"] > 0
    assert totals["tree"] == pytest.approx(
        totals["flat"] * tree_price / flat_price, rel=1e-6)


def test_tree_partition_trains_and_batched_lanes_account_per_leaf():
    params0, grad_fn = _problem()
    topo = build_topology("erdos_renyi", M, p=0.5, seed=3)
    cfg = PaMEConfig(nu=0.5, p=0.3, gamma=1.01, sigma0=4.0, kappa_lo=2,
                     kappa_hi=4, mask_mode="exact", partition="tree",
                     p_leaf=(0.1, 0.8))
    ba = get_algorithm("pame").bind_batched(
        grad_fn, topo, [cfg], seeds=[0, 1, 2], mixing="dense", seed=0)
    state, hist = ba.run(params0, M, lambda k: None, 12, tol_std=0.0)
    loss = np.asarray(hist["loss"])
    assert loss.shape[-1] == 3  # three seed lanes
    assert float(loss[-1].mean()) < float(loss[0].mean())
    # static estimate: per-leaf prices with the per-leaf rates
    s_v = max(1, int(round(0.1 * 55)))
    s_w = max(1, int(round(0.8 * 37)))
    price = (63 * s_v + 55) + (63 * s_w + 37)
    flat_cfg = PaMEConfig(nu=0.5, p=0.3, gamma=1.01, sigma0=4.0, kappa_lo=2,
                          kappa_hi=4, mask_mode="exact")
    flat = get_algorithm("pame").bind(grad_fn, topo, flat_cfg, mixing="dense",
                                      seed=0)
    msgs = flat.wire_bits_for(params0) / message_bits(
        max(1, int(round(0.3 * 92))), 92)
    wps = np.asarray(hist["wire_bits_per_step"])  # per-lane [L]
    np.testing.assert_allclose(wps, np.full(wps.shape, msgs * price),
                               rtol=1e-6)


def test_config_validation():
    with pytest.raises(ValueError, match="partition"):
        PaMEConfig(partition="columns")
    with pytest.raises(ValueError, match="p_leaf"):
        PaMEConfig(p_leaf=(0.5, 0.5))  # flat partition
    with pytest.raises(NotImplementedError, match="dense"):
        PaMEConfig(partition="tree", exchange="compressed")
    params0, grad_fn = _problem()
    topo = build_topology("erdos_renyi", 4, p=0.9, seed=0)
    cfg = PaMEConfig(partition="tree", p_leaf=(0.5, 0.5, 0.5))  # 3 != 2 leaves
    ba = get_algorithm("pame").bind(grad_fn, topo, cfg, mixing="dense", seed=0)
    with pytest.raises(ValueError, match="leaves"):
        ba.run(jax.random.PRNGKey(1), params0, 4, lambda k: None, 2,
               tol_std=0.0)
