"""Batched (vmap-over-lanes) sweep engine + edge-list gossip core.

Lane-equivalence contract: lane (s, c) of a `bind_batched` grid must
reproduce the unbatched `bind(hps_c)` run under `PRNGKey(s)` to fp
tolerance — allclose, NOT bitwise: the batched program is a different XLA
program, and LLVM's FMA contraction makes cross-program bit-identity
non-robust (see tests/test_mixing.py for the discussion; the bitwise
guarantees in this repo are always same-program or op-by-op eager).

Gossip-core contract: impl="segsum" (edge-list + `jax.ops.segment_sum`,
padding routed to a dead segment) agrees with impl="slots" (the fused
sequential chain) to fp tolerance on every graph, including the
degenerate ones — isolated node, star hub, m=2 — and ignores poisoned
padding weights outright.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as ALG
from repro.core import baselines as B
from repro.core import engine
from repro.core.mixing import PaddedMixing, gather_terms, make_mixer, mix_padded
from repro.core.pame import PaMEConfig
from repro.core.scenarios import Scenario
from repro.core.temporal import TemporalScenario
from repro.core.topology import build_topology


def _linreg(m, n, spn=24, seed=0):
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(n)
    a = rng.standard_normal((m, spn, n))
    y = a @ w_star + 0.3 * rng.standard_normal((m, spn))
    a_j, y_j = jnp.asarray(a, jnp.float32), jnp.asarray(y, jnp.float32)

    def grad_fn(w, batch, key):
        aa, yy = batch
        r = aa @ w - yy
        return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]

    def objective(w):
        r = jnp.einsum("mbn,n->mb", a_j, w) - y_j
        return jnp.sum(0.5 * jnp.mean(r**2, axis=1))

    return (a_j, y_j), grad_fn, objective


GRIDS = {
    "pame": [
        PaMEConfig(nu=0.3, p=0.3, gamma=1.01, sigma0=8.0),
        PaMEConfig(nu=0.6, p=0.3, gamma=1.05, sigma0=4.0),
    ],
    "dpsgd": [ALG.DPSGDHp(lr=0.1), ALG.DPSGDHp(lr=0.05)],
    "dfedsam": [
        ALG.DFedSAMHp(lr=0.1, rho=0.01), ALG.DFedSAMHp(lr=0.05, rho=0.05)
    ],
    "choco": [
        ALG.ChocoHp(lr=0.05, gossip_gamma=0.3),
        ALG.ChocoHp(lr=0.02, gossip_gamma=0.5),
    ],
    "beer": [ALG.BeerHp(lr=0.05), ALG.BeerHp(lr=0.02)],
    "anq_nids": [ALG.AnqNidsHp(lr=0.1), ALG.AnqNidsHp(lr=0.05)],
}


@pytest.mark.parametrize("name", sorted(GRIDS))
def test_lane_matches_unbatched_run(name):
    """Per registered algorithm: every lane of a 2-config × 2-seed batched
    grid reproduces the unbatched run with the same seed/config."""
    m, n = 8, 24
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn, objective = _linreg(m, n)
    hps = GRIDS[name]
    ba = ALG.get_algorithm(name).bind_batched(
        grad_fn, topo, hps, seeds=[0, 1]
    )
    assert ba.lanes == 4
    state, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 12,
        objective_fn=objective, tol_std=0.0, chunk_size=6,
    )
    assert hist["objective"].shape == (12, 4)
    params = np.asarray(ba.params_of(state))
    for lane in range(ba.lanes):
        c = int(hist["lane_config"][lane])
        s = int(hist["lane_seed"][lane])
        bound = ALG.get_algorithm(name).bind(grad_fn, topo, hps[c])
        st, h = bound.run(
            jax.random.PRNGKey(s), jnp.zeros(n), m, lambda k: batch, 12,
            objective_fn=objective, tol_std=0.0, chunk_size=6,
        )
        np.testing.assert_allclose(
            hist["objective"][:, lane], h["objective"],
            rtol=5e-5, atol=1e-6, err_msg=f"lane {lane} (cfg {c}, seed {s})",
        )
        np.testing.assert_allclose(
            params[lane], np.asarray(bound.params_of(st)),
            rtol=5e-5, atol=1e-6,
        )


def test_per_lane_termination_freezes_each_lane():
    """The std rule fires per lane; a finished lane's state stays frozen at
    its own stopping step while slower lanes run on."""
    m, n = 8, 24
    topo = build_topology("complete", m)
    batch, grad_fn, objective = _linreg(m, n, seed=3)
    # aggressive vs timid penalty growth => very different stopping steps
    hps = [
        PaMEConfig(nu=0.5, p=0.5, gamma=1.05, sigma0=8.0),
        PaMEConfig(nu=0.5, p=0.5, gamma=1.001, sigma0=0.5),
    ]
    ba = ALG.get_algorithm("pame").bind_batched(grad_fn, topo, hps, seeds=[0])
    state, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 400,
        objective_fn=objective, tol_std=1e-3, chunk_size=25,
    )
    steps_run = hist["steps_run"]
    assert steps_run[0] != steps_run[1]
    params = np.asarray(ba.params_of(state))
    for lane, cfg in enumerate(hps):
        bound = ALG.get_algorithm("pame").bind(grad_fn, topo, cfg)
        st, h = bound.run(
            jax.random.PRNGKey(0), jnp.zeros(n), m, lambda k: batch, 400,
            objective_fn=objective, tol_std=1e-3, chunk_size=25,
        )
        assert h["steps_run"] == int(steps_run[lane])
        np.testing.assert_allclose(
            params[lane], np.asarray(bound.params_of(st)),
            rtol=5e-5, atol=1e-6,
        )
    finals = ALG.lane_finals(hist)
    assert np.isfinite(finals).all()


def test_bind_batched_refuses_trace_shaping_fields():
    m, n = 6, 12
    topo = build_topology("ring", m)
    batch, grad_fn, _ = _linreg(m, n)
    with pytest.raises(ValueError, match="shapes the traced program"):
        ALG.get_algorithm("pame").bind_batched(
            grad_fn, topo, [PaMEConfig(p=0.2), PaMEConfig(p=0.4)]
        )
    with pytest.raises(ValueError, match="shapes the traced program"):
        ALG.get_algorithm("dfedsam").bind_batched(
            grad_fn, topo,
            [ALG.DFedSAMHp(local_steps=1), ALG.DFedSAMHp(local_steps=2)],
        )
    with pytest.raises(TypeError):
        ALG.get_algorithm("dpsgd").bind_batched(
            grad_fn, topo, [PaMEConfig()]
        )
    # an int field that is neither static-listed nor setup-realized cannot
    # ride a lane scalar — the classifier must refuse, not silently bake
    # config 0's value into every lane
    import dataclasses as dc

    @dc.dataclass(frozen=True)
    class OddHp:
        reps: int = 1

    spec = ALG.Algorithm(
        name="odd", hp_cls=OddHp,
        init=lambda key, stacked, ctx, batch0: B.dpsgd_init(key, stacked),
        step=lambda s, b_, ctx: B.dpsgd_step(
            s, b_, ctx.grad_fn, ctx.mixer, 0.1),
        wire_bits=lambda topo_, hps, n_: 0.0,
    )
    with pytest.raises(ValueError, match="non-float"):
        spec.bind_batched(grad_fn, topo, [OddHp(reps=1), OddHp(reps=2)])


def test_batched_static_wire_accounting_per_lane():
    """Static grids charge each lane its config's Eq.-(8) rate."""
    m, n = 8, 24
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn, objective = _linreg(m, n)
    hps = GRIDS["pame"]
    ba = ALG.get_algorithm("pame").bind_batched(grad_fn, topo, hps, seeds=[0, 1])
    _, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 8,
        objective_fn=objective, tol_std=0.0, chunk_size=4,
    )
    for lane in range(ba.lanes):
        c = int(hist["lane_config"][lane])
        bound = ALG.get_algorithm("pame").bind(grad_fn, topo, hps[c])
        assert hist["wire_bits_per_step"][lane] == pytest.approx(
            bound.wire_bits(n)
        )
    assert np.all(hist["wire_bits_total"]
                  == hist["wire_bits_per_step"] * hist["steps_run"])


def test_batched_dynamic_scenario_pairs_seeds():
    """Dynamic grids fold the lane's seed into the scenario key: the same
    seed under different configs sees the same network sample path
    (identical realized wire bits), different seeds see different ones."""
    m, n = 8, 24
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn, objective = _linreg(m, n)
    scen = Scenario(name="flaky", churn=0.1, edge_drop=0.2, seed=5)
    ba = ALG.get_algorithm("dpsgd").bind_batched(
        grad_fn, topo, GRIDS["dpsgd"], seeds=[0, 1], scenario=scen
    )
    _, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 12,
        objective_fn=objective, tol_std=0.0, chunk_size=6,
    )
    assert np.isfinite(hist["objective"]).all()
    wire = hist["wire_bits"]  # [steps, L], lanes = (c0s0, c0s1, c1s0, c1s1)
    np.testing.assert_array_equal(wire[:, 0], wire[:, 2])
    np.testing.assert_array_equal(wire[:, 1], wire[:, 3])
    assert (wire[:, 0] != wire[:, 1]).any()


def test_batched_temporal_threads_lane_aux():
    """TemporalScenario grids thread the Markov state + staleness ring as
    lane-stacked aux through the scan; per-lane histograms come back."""
    m, n = 8, 24
    topo = build_topology("erdos_renyi", m, p=0.5, seed=1)
    batch, grad_fn, objective = _linreg(m, n)
    scen = TemporalScenario(
        name="stale", straggler=0.4, staleness=2,
        burst_down=0.05, burst_up=0.3, seed=4,
    )
    ba = ALG.get_algorithm("pame").bind_batched(
        grad_fn, topo, [GRIDS["pame"][0]], seeds=[0, 1, 2], scenario=scen
    )
    _, hist = ba.run(
        jnp.zeros(n), m, lambda k: batch, 12,
        objective_fn=objective, tol_std=0.0, chunk_size=6,
    )
    assert np.isfinite(hist["objective"]).all()
    assert hist["staleness_hist"].shape == (3, scen.staleness + 1)
    # some participant-steps actually ran stale
    assert hist["staleness_hist"][:, 1:].sum() > 0


def test_batched_sweep_traces_step_once():
    """Compile-count regression guard: an S×C batched sweep traces the
    step function exactly as often as a single unbatched run — the lane
    count must never enter the trace count (that is the whole point of
    the batched engine)."""
    m, n = 6, 12
    topo = build_topology("ring", m)
    batch, grad_fn, objective = _linreg(m, n)

    def counting_spec(counter):
        def step(state, batch_, ctx):
            counter.append(1)  # python body runs only while tracing
            return B.dpsgd_step(
                state, batch_, ctx.grad_fn, ctx.mixer, ctx.hps.lr
            )

        return ALG.Algorithm(
            name="counting_dpsgd", hp_cls=ALG.DPSGDHp,
            init=lambda key, stacked, ctx, batch0: B.dpsgd_init(key, stacked),
            step=step,
            wire_bits=lambda topo_, hps, n_: 0.0,
        )

    traces = {}
    for tag, seeds, hps in (
        ("single", [0], [ALG.DPSGDHp(lr=0.1)]),
        ("grid", [0, 1, 2, 3], [ALG.DPSGDHp(lr=0.1), ALG.DPSGDHp(lr=0.05)]),
    ):
        counter = []
        spec = counting_spec(counter)
        ba = spec.bind_batched(grad_fn, topo, hps, seeds=seeds)
        # two chunks of the same length -> one compiled executable
        ba.run(
            jnp.zeros(n), m, lambda k: batch, 8,
            objective_fn=objective, tol_std=0.0, chunk_size=4,
        )
        traces[tag] = len(counter)
    assert traces["grid"] == traces["single"], traces
    assert traces["grid"] <= 4, traces  # a small tracing constant, not S·C


def test_engine_run_batched_per_lane_metrics():
    """engine.run_batched: per-lane metric buffers and steps_run."""

    def step(state, batch):
        new = state + jnp.arange(1.0, state.shape[0] + 1.0)[:, None]
        return new, {"loss_mean": new.mean(axis=1)}

    state0 = jnp.zeros((3, 2))  # 3 lanes
    state, metrics, info = engine.run_batched(
        step, state0, lambda k: None, 6, lanes=3, chunk_size=4,
        params_of=lambda s: s, donate=False,
    )
    assert metrics["loss_mean"].shape == (6, 3)
    np.testing.assert_allclose(
        metrics["loss_mean"][:, 2], 3.0 * np.arange(1, 7)
    )
    np.testing.assert_array_equal(info["steps_run"], [6, 6, 6])


# ---------------------------------------------------------------------------
# segment-sum vs slots gossip core on degenerate graphs
# ---------------------------------------------------------------------------
def _tree(m, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((m, 5, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((m,)), jnp.float32),
    }


@pytest.mark.parametrize("kind,m", [
    ("star", 8),      # hub with m-1 spokes vs degree-1 leaves
    ("complete", 2),  # minimal graph
    ("ring", 6),
    ("erdos_renyi", 10),
])
def test_segsum_matches_slots_on_graphs(kind, m):
    kwargs = dict(p=0.5, seed=2) if kind == "erdos_renyi" else {}
    topo = build_topology(kind, m, **kwargs)
    tree = _tree(m, seed=m)
    out_slots = make_mixer(topo, "sparse", impl="slots").mix(tree)
    out_seg = make_mixer(topo, "sparse", impl="segsum").mix(tree)
    for key in tree:
        np.testing.assert_allclose(
            np.asarray(out_seg[key]), np.asarray(out_slots[key]),
            rtol=1e-5, atol=1e-6,
        )
    # all Mixer variants, jitted too
    mx_sl = make_mixer(topo, "sparse", impl="slots")
    mx_sg = make_mixer(topo, "sparse", impl="segsum")
    for fn in ("mix", "mix_lazy", "mix_half"):
        a = jax.jit(getattr(mx_sl, fn))(tree)
        b = jax.jit(getattr(mx_sg, fn))(tree)
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(b[key]), np.asarray(a[key]),
                rtol=1e-5, atol=1e-6, err_msg=fn,
            )


def test_segsum_isolated_node_and_poisoned_padding():
    """An all-padding row (isolated node) must reduce to the self term
    under both impls, and the segment-sum path must ignore poisoned
    padding weights entirely (they route to the dead segment)."""
    m = 4
    # node 3 isolated: only the self slot carries weight
    nbrs = jnp.asarray([[1, 0], [0, 1], [0, 2], [3, 3]], jnp.int32)
    w = jnp.asarray([[0.5, 0.5], [0.5, 0.5], [1.0, 0.0], [1.0, 0.0]],
                    jnp.float32)
    is_self = jnp.asarray(
        [[False, True], [False, True], [False, True], [True, False]]
    )
    pad = jnp.asarray(
        [[False, False], [False, False], [False, False], [False, True]]
    )
    pm = PaddedMixing(nbrs, w, is_self, pad)
    x = {"v": jnp.asarray(np.random.default_rng(0).standard_normal((m, 3)),
                          jnp.float32)}
    out_slots = mix_padded(pm, x, impl="slots")
    out_seg = mix_padded(pm, x, impl="segsum")
    np.testing.assert_allclose(
        np.asarray(out_seg["v"]), np.asarray(out_slots["v"]),
        rtol=1e-6, atol=1e-7,
    )
    # isolated node keeps exactly its own value
    np.testing.assert_allclose(
        np.asarray(out_seg["v"][3]), np.asarray(x["v"][3]), rtol=1e-6
    )
    # poison the padding slot: dead-segment routing must be unaffected
    w_bad = jnp.where(pad, jnp.nan, w)
    out_bad = mix_padded(PaddedMixing(nbrs, w_bad, is_self, pad), x,
                         impl="segsum")
    np.testing.assert_array_equal(
        np.asarray(out_bad["v"]), np.asarray(out_seg["v"])
    )


def test_gather_terms_multi_term_single_walk():
    """PME-style two-term contraction (payload + mask counts) agrees with
    two independent single-term contractions, for both impls."""
    m, d, n = 6, 3, 5
    rng = np.random.default_rng(1)
    nbrs = jnp.asarray(rng.integers(0, m, (m, d)), jnp.int32)
    w = jnp.asarray(rng.random((m, d)), jnp.float32)
    x1 = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    x2 = jnp.asarray(rng.random((m, n)), jnp.float32)
    for impl in ("slots", "segsum"):
        a2, b2 = gather_terms(nbrs, [(w, x1), (w, x2)], impl=impl)
        (a1,) = gather_terms(nbrs, [(w, x1)], impl=impl)
        (b1,) = gather_terms(nbrs, [(w, x2)], impl=impl)
        np.testing.assert_array_equal(np.asarray(a2), np.asarray(a1))
        np.testing.assert_array_equal(np.asarray(b2), np.asarray(b1))


def test_pme_padded_segsum_matches_slots():
    """The padded PME exchange agrees across gossip impls (star hub
    included — the hub aggregates every spoke's partial message)."""
    from repro.core import pme
    from repro.core.pame import make_topology_arrays

    m = 8
    topo = build_topology("star", m)
    cfg = PaMEConfig(nu=0.9, p=0.4)
    arrs = make_topology_arrays(topo, cfg, seed=0)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((m, 6)), jnp.float32)}
    sel = pme.sample_neighbor_selection_padded(
        jax.random.PRNGKey(1), arrs.nbrs, arrs.valid, arrs.t,
        jnp.ones((m,), bool),
    )
    for mode in ("bernoulli", "exact"):
        outs = {
            impl: pme.pme_average_pytree_padded(
                jax.random.PRNGKey(2), params, arrs.nbrs, sel, cfg.p,
                mode=mode, pad=~arrs.valid, impl=impl,
            )
            for impl in ("slots", "segsum")
        }
        np.testing.assert_allclose(
            np.asarray(outs["segsum"]["w"]), np.asarray(outs["slots"]["w"]),
            rtol=1e-5, atol=1e-6, err_msg=mode,
        )


def test_neighbor_selection_scatter_matches_padded():
    """The dense selection matrix built by edge-list scatter equals the
    padded selection scattered by hand (the old one-hot semantics)."""
    from repro.core import pme

    m = 10
    topo = build_topology("erdos_renyi", m, p=0.5, seed=3)
    nbrs_np, valid_np = topo.neighbor_matrix_padded()
    nbrs, valid = jnp.asarray(nbrs_np), jnp.asarray(valid_np)
    t = jnp.asarray(np.maximum(1, (0.5 * topo.degrees)).astype(np.int32))
    comm = jnp.asarray(np.random.default_rng(0).random(m) < 0.7)
    key = jax.random.PRNGKey(7)
    a = pme.sample_neighbor_selection(key, nbrs, valid, t, comm)
    sel = pme.sample_neighbor_selection_padded(key, nbrs, valid, t, comm)
    ref = np.zeros((m, m), np.float32)
    for i in range(m):
        for slot in range(nbrs.shape[1]):
            if bool(sel[i, slot]):
                ref[int(nbrs[i, slot]), i] += 1.0
    np.testing.assert_array_equal(np.asarray(a), ref)
    # columns of non-communicating receivers are all-zero
    np.testing.assert_array_equal(
        np.asarray(a)[:, ~np.asarray(comm)], 0.0
    )
