"""Serving example: prefill a batch of prompts, then batched greedy decode
with ring-buffer KV caches — the serve_step that the decode_32k / long_500k
dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-14b \
        --prompt-len 32 --gen 16 --batch 4 [--window 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill
from repro.serve.serving import decode_greedy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding window (ring-buffer cache of this size)")
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke")
    if args.window:
        cfg = cfg.replace(window=args.window)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    total = args.prompt_len + args.gen
    offset = cfg.n_patches if cfg.arch_type == "vlm" else 0
    capacity = args.window or (total + offset)

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.vision_dim)),
            jnp.dtype(cfg.dtype),
        )

    pf = jax.jit(lambda p, b: prefill(p, cfg, b, capacity))
    dc = jax.jit(lambda p, t, pos, c: decode_step(p, cfg, t, pos, c))

    t0 = time.perf_counter()
    logits, caches = pf(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # tokens accumulate ON DEVICE and transfer once after the loop — the
    # old per-step np.asarray forced a device->host sync every token,
    # serializing dispatch and inflating the reported ms/tok
    t0 = time.perf_counter()
    out = decode_greedy(
        dc, params, tok, caches, args.prompt_len, args.gen, offset
    )
    gen = np.asarray(jax.block_until_ready(out))
    t_decode = time.perf_counter() - t0

    n_decoded = args.batch * (args.gen - 1)
    print(f"[serve] arch={cfg.name} batch={args.batch} window={args.window}")
    print(f"[serve] prefill {args.prompt_len} toks: {t_prefill*1e3:.1f} ms")
    print(
        f"[serve] decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
        f"({t_decode/(args.gen-1)*1e3:.1f} ms/tok, "
        f"{n_decoded/max(t_decode, 1e-9):.1f} tokens/s)"
    )
    print(f"[serve] generated ids (seq 0): {gen[0].tolist()}")


if __name__ == "__main__":
    main()
