"""End-to-end driver: decentralized federated training of a language model
with PaME across simulated nodes — the deliverable-(b) e2e example.

Default runs a reduced stablelm on CPU in a couple of minutes; on a real
slice pass --variant full (the launcher shards over the production mesh).
Scale the same command up to the ~100M-parameter class with e.g.:

    PYTHONPATH=src python examples/train_dfl_lm.py \
        --arch stablelm-1.6b --layers 6 --d-model 768 --steps 300

This wraps repro.launch.train and additionally reports per-round
communication volume (Eq. 8) for the chosen transmission rate.
"""
import argparse

from repro.configs import get_config
from repro.core.pme import message_bits


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--p", type=float, default=0.2, help="transmission rate s/n")
    ap.add_argument("--algo", default="pame",
                    help="any registered algorithm (see repro.core.algorithms)")
    ap.add_argument("--partition", default="flat", choices=["flat", "tree"],
                    help="PaME message format: flat vector vs per-leaf "
                         "segments (see repro.launch.train --partition)")
    ap.add_argument("--layers", type=int, default=None, help="override depth")
    ap.add_argument("--d-model", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke")
    n_params = cfg.param_count()
    s = int(args.p * n_params)
    print(
        f"[example] {args.arch} (smoke: {n_params/1e6:.1f}M params), "
        f"m={args.nodes} nodes, s/n={args.p}"
    )
    print(
        f"[example] PME message: {message_bits(s, n_params, 16)/8e6:.2f} MB "
        f"(vs dense {16*n_params/8e6:.2f} MB bf16) per neighbor per round"
    )

    from repro.launch import train as train_mod

    argv = [
        "--arch", args.arch, "--variant", "smoke", "--algo", args.algo,
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--nodes", str(args.nodes),
        "--p", str(args.p), "--sigma0", "50", "--log-every", "10",
    ]
    if args.algo == "pame":
        argv += ["--partition", args.partition]
    # pass the argv list straight through — clobbering sys.argv would leak
    # into any importing caller (and pytest collection)
    train_mod.main(argv)


if __name__ == "__main__":
    main()
