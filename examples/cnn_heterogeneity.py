"""Paper Example 3: DFL image classification under label-skew
heterogeneity (C classes per node), PaME vs D-PSGD.

    PYTHONPATH=src python examples/cnn_heterogeneity.py --classes 7
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame
from repro.core import baselines as B
from repro.data import NodeBatcher, SyntheticClassification, label_skew_partition
from repro.models.cnn import ce_loss, cnn_apply, cnn_init


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", type=int, default=7, help="C classes per node")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--partition", default="flat", choices=["flat", "tree"],
                    help="PaME message format: flat vector vs per-leaf "
                         "segments with per-leaf Eq.-(8) accounting")
    args = ap.parse_args(argv)

    m = args.nodes
    ds = SyntheticClassification.make(1024, (28, 28, 1), 10, seed=0, sep=3.0)
    parts = label_skew_partition(ds.labels, m, args.classes, seed=0)
    print(
        f"[hetero] m={m} nodes, C={args.classes} classes/node "
        f"(shard sizes: {[len(p) for p in parts]})"
    )
    nb = NodeBatcher({"x": ds.images, "y": ds.labels}, parts, batch_size=32, seed=0)
    topo = build_topology("complete", m)

    def grad_fn(params, batch, key):
        return jax.value_and_grad(
            lambda p: ce_loss(cnn_apply(p, batch["x"]), batch["y"])
        )(params)

    def batch_fn(k):
        b = nb.next()
        return {
            "x": jnp.asarray(b["x"], jnp.float32),
            "y": jnp.asarray(b["y"], jnp.int32),
        }

    def acc_of(params_mean):
        logits = cnn_apply(params_mean, jnp.asarray(ds.images[:512], jnp.float32))
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ds.labels[:512])))

    # --- PaME ---
    cfg = PaMEConfig(nu=0.7, p=0.3, gamma=1.002, sigma0=10.0, kappa_lo=2,
                     kappa_hi=4, partition=args.partition)
    state, hist = run_pame(
        jax.random.PRNGKey(0), cnn_init(jax.random.PRNGKey(1)), m,
        grad_fn, batch_fn, topo, cfg, num_steps=args.steps, tol_std=0.0,
    )
    mp = jax.tree_util.tree_map(lambda x: x.mean(0), state.params)
    print(
        f"[hetero] PaME   : loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f},"
        f" acc(mean model) = {acc_of(mp):.3f}"
        f"  [transmits {cfg.p:.0%} of coords, every ~3 rounds]"
    )

    # --- D-PSGD (dense gossip every round) ---
    bmat = jnp.asarray(topo.mixing)
    st = B.dpsgd_init(jax.random.PRNGKey(0), B.stack_params(cnn_init(jax.random.PRNGKey(1)), m))
    losses = []
    step = jax.jit(lambda s, b: B.dpsgd_step(s, b, grad_fn, bmat, 0.05))
    for k in range(args.steps):
        st, metrics = step(st, batch_fn(k))
        losses.append(float(metrics["loss_mean"]))
    mp2 = jax.tree_util.tree_map(lambda x: x.mean(0), st.params)
    print(
        f"[hetero] D-PSGD : loss {losses[0]:.3f} -> {losses[-1]:.3f},"
        f" acc(mean model) = {acc_of(mp2):.3f}"
        f"  [transmits 100% of coords, every round]"
    )


if __name__ == "__main__":
    main()
