"""Quickstart: PaME on the paper's Example 1 (decentralized linear
regression) in ~40 lines of user code.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API: build a topology, define a per-node loss,
run Algorithm 1, and inspect the Theorem-1 estimators.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PaMEConfig, build_topology, run_pame, pme
from repro.data.synthetic import make_linear_regression

M, N = 16, 200

# --- data: b = <a, w*> + 0.5 e, per-node shards (Example 1) ---------------
a, b, w_star = make_linear_regression(M, samples_per_node=64, n=N, seed=0)
a_j, b_j = jnp.asarray(a), jnp.asarray(b)


def grad_fn(w, batch, key):
    aa, yy = batch
    r = aa @ w - yy
    return 0.5 * jnp.mean(r**2), aa.T @ r / aa.shape[0]


def objective(w):
    r = jnp.einsum("mbn,n->mb", a_j, w) - b_j
    return jnp.sum(0.5 * jnp.mean(r**2, axis=1))


# --- run PaME over a random communication graph ---------------------------
topo = build_topology("erdos_renyi", M, p=0.4, seed=1)
print(f"graph: m={M}, max degree={topo.max_degree}, zeta={topo.zeta:.3f}")

cfg = PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0, kappa_lo=3, kappa_hi=7)
state, hist = run_pame(
    jax.random.PRNGKey(0), jnp.zeros(N), M, grad_fn, lambda k: (a_j, b_j),
    topo, cfg, num_steps=400, objective_fn=objective,
)
print(
    f"PaME: f went {hist['objective'][0]:.3f} -> {hist['objective'][-1]:.3f}"
    f" in {hist['steps_run']} iterations"
    f" (noise floor = {M * 0.5 * 0.25:.2f})"
)
w_mean = np.asarray(jax.tree_util.tree_map(lambda x: x.mean(0), state.params))
print(f"recovery error ||w_bar - w*|| = {np.linalg.norm(w_mean - w_star):.3f}")

# --- the same race through the algorithm registry --------------------------
from repro.core import algorithms as ALG

print("\nRegistry race (8 steps each, sparse neighbor-exchange gossip):")
for name, hps in [
    ("pame", PaMEConfig(nu=0.2, p=0.2, gamma=1.01, sigma0=8.0)),
    ("dpsgd", ALG.DPSGDHp(lr=0.1)),
]:
    bound = ALG.get_algorithm(name).bind(grad_fn, topo, hps, mixing="sparse")
    _, h = bound.run(
        jax.random.PRNGKey(0), jnp.zeros(N), M, lambda k: (a_j, b_j), 8,
        tol_std=0.0, chunk_size=8,
    )
    print(
        f"  {name:6s} loss {h['loss'][0]:8.3f} -> {h['loss'][-1]:8.3f}"
        f"   wire: {h['wire_bits_per_step']/8e3:8.1f} KB/step"
    )

# --- Theorem 1 in action ---------------------------------------------------
print("\nTheorem 1 demo (count-weighted vs naive averaging):")
w = jnp.asarray(np.random.default_rng(0).standard_normal((5, 8)), jnp.float32)
target = np.asarray(w[1:]).mean(axis=0)
sel = jnp.zeros((5, 5)).at[1:, 0].set(1.0)  # node 0 receives from 1..4
acc_bar = np.zeros(8)
acc_naive = np.zeros(8)
T = 2000
for t in range(T):
    masks = pme.sample_coordinate_masks(jax.random.PRNGKey(t), 5, 8, s=3)
    masks = masks.at[0].set(False)
    acc_bar += np.asarray(pme.pme_average(w, masks, sel)[0])
    acc_naive += np.asarray(pme.naive_average(w, masks, sel)[0])
print("  target mean     :", np.round(target, 3))
print("  count-weighted  :", np.round(acc_bar / T, 3), "(unbiased)")
print("  naive /t        :", np.round(acc_naive / T, 3), f"(biased ~ s/n = {3/8:.2f}x)")
